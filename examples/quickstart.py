"""Quickstart: map a loop onto a CGRA with SAT-MapIt (paper pipeline).

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's running example DFG (Fig. 2a), walks the Fig. 3 loop
(KMS -> CNF -> SAT -> register allocation), prints the mapping as
prolog/kernel/epilog tables, and verifies it against sequential execution.
"""
import sys

sys.path.insert(0, "src")

from repro.core.cgra import CGRA
from repro.core.dfg import running_example
from repro.core.mapper import MapperConfig, map_loop
from repro.core.schedule import asap_alap, mobility_schedule
from repro.core.simulator import emit_code, verify_mapping


def main() -> None:
    g = running_example()
    cgra = CGRA(2, 2, n_regs=4)
    print(f"DFG: {g.n} nodes, {len(g.edges())} edges on {cgra}")

    asap, alap, L = asap_alap(g)
    print(f"critical path {L}; mobility schedule:")
    for t, row in enumerate(mobility_schedule(g)):
        print(f"  t{t}: {[g.nodes[n].name for n in row]}")

    r = map_loop(g, cgra, MapperConfig(solver="auto"))
    assert r.success
    print(f"\nmapped at II={r.ii} (MII={r.mii}) in {r.total_time:.2f}s; "
          f"attempts: {[(a.ii, a.status) for a in r.attempts]}")
    print(f"register pressure: {r.regalloc.max_pressure} "
          f"(of {cgra.n_regs}); {len(r.regalloc.bypass)} output-reg bypasses")

    code = emit_code(g, cgra, r.placement, r.ii)
    print("\n" + code.render(g))

    chk = verify_mapping(g, cgra, r.placement, r.ii, n_iters=10)
    print(f"\nsimulator verification over 10 iterations: "
          f"{'OK' if chk.ok else chk.errors}")


if __name__ == "__main__":
    main()

"""TPU-native mapper portfolio: solve a batch of loop-mapping problems with
the JAX probSAT chains + complete-solver fallback — the accelerator-side
deployment mode of SAT-MapIt (DESIGN.md §3).

    PYTHONPATH=src python examples/portfolio_mapper.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.encode import EncoderSession
from repro.core.sat import SAT, solve
from repro.core.schedule import min_ii


def main() -> None:
    cgra = CGRA(3, 3)
    jobs = ["srand", "bitcount", "gsm", "nw"]
    print(f"portfolio-mapping {len(jobs)} kernels on {cgra}\n")
    for name in jobs:
        g = suite.get(name)
        session = EncoderSession(g, cgra)
        ii = min_ii(g, cgra)
        while True:
            enc = session.encode(ii)
            t0 = time.time()
            status, model = solve(enc.cnf, "portfolio", seed=ii)
            dt = time.time() - t0
            if status == SAT:
                print(f"{name:10s} II={ii:2d} vars={enc.cnf.n_vars:5d} "
                      f"clauses={enc.cnf.n_clauses:6d} ({dt:.2f}s)")
                break
            ii += 1


if __name__ == "__main__":
    main()

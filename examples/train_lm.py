"""End-to-end driver: train a reduced-config LM for a few hundred steps
with checkpointing, crash recovery, and loss tracking.

    PYTHONPATH=src python examples/train_lm.py --arch minitron_8b --steps 200

Uses the same train_loop as launch/train.py — this is the deliverable-(b)
end-to-end example; at pod scale the identical step function is what
launch/dryrun.py lowers against the 512-chip production mesh.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron_8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    print(f"training {cfg.name} ({cfg.family}) for {args.steps} steps")
    out = train_loop(cfg, steps=args.steps, global_batch=8, seq_len=64,
                     ckpt_dir=args.ckpt_dir, ckpt_every=50, resume=True,
                     log_every=20)
    out.pop("params", None)
    print({k: round(float(v), 4) for k, v in out.items()})


if __name__ == "__main__":
    main()

"""Map a JAX-defined loop body onto a CGRA — the jaxpr frontend in action,
including the beyond-paper routing-node insertion and the per-arch
"CGRA offload" demo (inner loops of the assigned LM architectures).

    PYTHONPATH=src python examples/map_jax_loop.py [--cgra 4x4]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.core.cgra import cgra_from_name
from repro.core.frontend import trace_loop_body
from repro.core.mapper import MapperConfig, map_loop

# scalar inner-loop bodies representative of the assigned architectures
# (DESIGN.md §4: SAT-MapIt is a kernel-compilation-layer tool; these are the
# elementwise loops a CGRA could offload — matmuls are not a modulo-
# scheduling target)


def rope_rotation(i, c, s):
    """RoPE-style fixed-point rotate pair (dense/GQA archs)."""
    x1 = (c * 13 - s * 7) >> 4
    x2 = (c * 7 + s * 13) >> 4
    return (x1, x2)


def router_argmax_step(i, best, bestv, x):
    """MoE router running argmax (llama4 / deepseek)."""
    take = x > bestv
    nb = jnp.where(take, i, best)
    nv = jnp.where(take, x, bestv)
    return (nb, nv)


def ssd_recurrence(i, state, x):
    """Integer SSD-flavoured state update (mamba2 / hymba)."""
    decayed = state - (state >> 3)
    return (decayed + x * 5,)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cgra", default="4x4")
    args = ap.parse_args()
    cgra = cgra_from_name(args.cgra)

    cases = [
        ("rope_rotation", rope_rotation, 2, 0),
        ("router_argmax", router_argmax_step, 2, 1),
        ("ssd_recurrence", ssd_recurrence, 1, 1),
    ]
    print(f"target: {cgra}\n")
    for name, fn, n_carry, loads in cases:
        g, _ = trace_loop_body(fn, n_carry=n_carry, loads=loads, name=name)
        base = map_loop(g, cgra, MapperConfig(solver="auto", timeout_s=60))
        routed = map_loop(g, cgra, MapperConfig(
            solver="auto", timeout_s=60, routing=True, max_route_nodes=4))
        print(f"{name:16s} nodes={g.n:2d} MII={base.mii}  "
              f"II(paper-faithful)={base.ii}  II(+routing)={routed.ii}"
              f"{'  <- routing helped' if (routed.ii or 99) < (base.ii or 99) else ''}")


if __name__ == "__main__":
    main()

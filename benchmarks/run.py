"""Master benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is the quick profile (a representative subset, minutes on 1 CPU
core); --full reproduces every benchmark x CGRA size cell with the paper's
budgets. CSV rows are ``name,us_per_call,derived``-style per section.
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import fig6_ii, kernel_bench, table_time

    print("# === Fig. 6: II comparison (SAT-MapIt vs heuristic SoA) ===")
    fig6_ii.main(quick=quick)
    print()
    print("# === Tables I-IV: mapping time ===")
    table_time.main(quick=quick)
    print()
    print("# === Kernel / solver microbenchmarks ===")
    kernel_bench.main()
    print()
    print("# === Roofline (from dry-run artifacts, if present) ===")
    for path in ("results/dryrun_final.jsonl", "results/dryrun.jsonl"):
        if os.path.exists(path):
            from . import roofline_report
            rows = roofline_report.load(path)
            print(roofline_report.roofline_table(rows))
            break
    else:
        print("no dry-run results found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")


if __name__ == "__main__":
    main()

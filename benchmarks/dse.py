"""CGRA design-space exploration with the SAT mapper (beyond-paper).

Because SAT-MapIt is exact within the KMS window, the II it returns is a
*property of the fabric*, not of heuristic luck — which makes it usable as
a DSE inner loop: sweep topology (paper mesh vs torus vs +diagonals) and
register-file size, and report the best II per kernel.

    PYTHONPATH=src python -m benchmarks.dse
"""
from __future__ import annotations

import time

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.mapper import MapperConfig, map_loop

KERNELS = ["sha", "sha2", "hotspot", "patricia", "srand"]
FABRICS = [
    ("2x2 mesh", CGRA(2, 2, topology="mesh")),
    ("2x2 torus", CGRA(2, 2, topology="torus")),
    ("2x2 diag", CGRA(2, 2, topology="diag")),
    ("2x3 mesh", CGRA(2, 3, topology="mesh")),
    ("3x3 mesh", CGRA(3, 3, topology="mesh")),
]


def main() -> None:
    print("(+r = with routing-node insertion; None = no mapping in budget)")
    print("kernel," + ",".join(n for n, _ in FABRICS) + ",3x3 mesh +r")
    for k in KERNELS:
        row = [k]
        for _, cgra in FABRICS:
            g = suite.get(k)
            r = map_loop(g, cgra, MapperConfig(solver="auto", timeout_s=60))
            row.append(str(r.ii))
        g = suite.get(k)
        r = map_loop(g, CGRA(3, 3), MapperConfig(
            solver="auto", timeout_s=120, routing=True, max_route_nodes=4))
        row.append(str(r.ii))
        print(",".join(row))


if __name__ == "__main__":
    main()

"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock there is meaningless; what IS meaningful on CPU:
  * the jnp oracle paths (XLA-compiled) at realistic sizes — these are the
    portable implementations the models actually run on non-TPU backends;
  * solver-backend timings on real KMS instances (paper's runtime claim).
Pallas kernels are timed at small sizes purely to prove the code path runs.
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_clause_eval() -> Tuple[str, float, str]:
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import encode
    from repro.core.sat.walksat_jax import pack_cnf, true_counts_batch
    enc = encode(running_example(), CGRA(4, 4), 3)
    packed = pack_cnf(enc.cnf)
    B = 64
    assign = jnp.asarray(np.random.rand(B, enc.cnf.n_vars + 1) > 0.5)
    fn = jax.jit(lambda a: true_counts_batch(packed, a, use_kernel=False))
    us = _time(fn, assign)
    per = us / (B * enc.cnf.n_clauses)
    return ("clause_eval_ref_jit", us,
            f"{per*1e3:.1f}ns/clause-chain C={enc.cnf.n_clauses} B={B}")


def bench_blockwise_attention() -> Tuple[str, float, str]:
    from repro.models.layers import blockwise_attention
    b, s, h, kv, d = 1, 1024, 8, 2, 64
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    fn = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, pos, pos))
    us = _time(fn, q, k, v)
    flops = 4 * b * h * s * s * d / 2
    return ("blockwise_attn_1k", us, f"{flops/us/1e3:.1f}GFLOP/s-equBk")


def bench_ssd() -> Tuple[str, float, str]:
    from repro.models.layers import ssd_chunked
    b, s, h, p, n = 1, 2048, 8, 64, 64
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5, jnp.float32)
    A = jnp.asarray(rng.rand(h), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    D = jnp.asarray(rng.rand(h), jnp.float32)
    fn = jax.jit(lambda *a: ssd_chunked(*a, chunk=256))
    us = _time(fn, x, dt, A, B, C, D)
    return ("ssd_chunked_2k", us, f"{b*s/(us/1e3):.1f}tok/ms")


def bench_pallas_interpret() -> Tuple[str, float, str]:
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.float32)
    us = _time(lambda *a: flash_attention(*a), q, k, v, iters=2, warmup=1)
    return ("flash_pallas_interpret_128", us, "interpret-mode (CPU)")


def bench_solvers() -> list:
    """Solver backends on one real KMS instance (paper's runtime claim)."""
    import time as _t
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import encode
    from repro.core.sat import solve
    enc = encode(running_example(), CGRA(2, 2), 3)
    rows = []
    for method in ("z3", "cdcl", "walksat"):
        t0 = _t.perf_counter()
        st, _ = solve(enc.cnf, method, walksat_steps=4096, walksat_batch=16)
        rows.append((f"solver_{method}", (_t.perf_counter() - t0) * 1e6,
                     f"status={st} vars={enc.cnf.n_vars} "
                     f"clauses={enc.cnf.n_clauses}"))
    return rows


def main() -> None:
    rows = [bench_clause_eval(), bench_blockwise_attention(), bench_ssd(),
            bench_pallas_interpret()] + bench_solvers()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

"""Synthetic serving load for the async compile front door.

Drives the full serving tier end to end — asyncio clients ->
:class:`repro.launch.serve.CompileFrontDoor` (micro-batching, coalescing,
deadlines, backpressure) -> :class:`repro.core.workers.WorkerPool`
(affinity-routed forked solver shards) -> shared
:class:`repro.core.store.MappingStore` — in four phases:

  1. **cold**: a fresh pool over a fresh store serves a corpus of suite
     kernels plus near-shape *variants* (one rewired edge: same node/edge
     counts and kinds, different exact wiring — exactly one lattice
     bucket apart), populating the disk store and measuring solve-path
     wall-clock. Variants land on the same affinity shard as their base
     kernel and must warm-seed from it (``near_hits``).
  2. **warm restart**: the pool is torn down and rebuilt over the *same*
     store directory — every corpus request must now be served from disk
     (``via="disk"``), and corpus wall-clock must drop >= 3x.
  3. **re-solve**: ``use_cache=False`` requests on the restarted pool
     force fresh solves; their sessions preload yesterday's proven-UNSAT
     cores from the store and prune IIs without solving
     (``cores_preloaded``/``iis_pruned``).
  4. **storm**: thousands of concurrent asyncio clients hammer the
     corpus through the front door with per-request deadlines; client-
     side latencies give p50/p99 and sustained req/s.

Writes ``BENCH_serve.json`` (p50/p99 latency, req/s, cache / disk /
near-shape / core-prune hit rates — the serving-throughput trajectory,
following ``BENCH_sweep.json``'s shape). ``--check`` additionally
asserts: served results bit-identical to a direct ``compile()`` of the
same requests, warm restart >= 3x cold, >= 1000 storm clients with zero
deadline violations, and near-shape hits > 0.

    PYTHONPATH=src python benchmarks/serve_load.py --quick --check
"""
from __future__ import annotations

import argparse
import asyncio
import copy
import json
import statistics
import tempfile
import time
from typing import Dict, List, Optional, Tuple

from repro.core import suite
from repro.core.cgra import cgra_from_name
from repro.core.mapper import MapperConfig
from repro.core.workers import WorkerPool
from repro.launch.serve import CompileFrontDoor

QUICK_KERNELS = ["sha", "gsm", "srand", "bitcount", "nw"]
QUICK_SIZES = ["3x3"]
FULL_SIZES = ["3x3", "4x4"]


def near_variant(g, v: int):
    """A near-shape sibling of ``g``: input ``v % sites`` of some
    two-input node is rewired onto the node's *other* producer. Node
    count, edge count, per-node indegree/kind, and the distance set are
    all preserved (same lattice bucket); the exact edge set is not (a
    different shape class, so a different CNF and pooled session)."""
    g2 = copy.deepcopy(g)
    sites = []
    for nid in sorted(g2.nodes):
        ins = g2.nodes[nid].ins
        if (len(ins) == 2 and ins[0][1] == 0 and ins[1][1] == 0
                and ins[0][0] != ins[1][0]):
            sites.append(nid)
    if not sites:
        return None
    nid = sites[v % len(sites)]
    node = g2.nodes[nid]
    keep = node.ins[v // len(sites) % 2][0]
    node.ins = ((keep, 0), (keep, 0))
    g2.touch()
    g2.name = f"{g.name}~v{v}"
    g2.validate()
    return g2


def build_corpus(names: List[str], sizes: List[str], n_variants: int,
                 cfg: MapperConfig) -> Tuple[List[Dict], List[Dict]]:
    """(base requests, near-variant requests); every entry is one unique
    (dfg, fabric) cell served through the door with ``cfg``."""
    base, variants = [], []
    for size in sizes:
        cgra = cgra_from_name(size)
        for name in names:
            g = suite.get(name)
            base.append({"name": f"{name}/{size}", "dfg": g, "cgra": cgra})
            for v in range(n_variants):
                gv = near_variant(g, v)
                if gv is not None:
                    variants.append({"name": f"{gv.name}/{size}", "dfg": gv,
                                     "cgra": cgra})
    return base, variants


async def serve_corpus(door: CompileFrontDoor, corpus: List[Dict],
                       cfg: MapperConfig, use_cache: bool = True,
                       deadline_s: float = 300.0) -> Tuple[List, float]:
    t0 = time.perf_counter()
    res = await asyncio.gather(*[
        door.compile(c["dfg"], c["cgra"], cfg, sweep_width=1,
                     use_cache=use_cache, deadline_s=deadline_s)
        for c in corpus])
    return list(res), time.perf_counter() - t0


async def storm(door: CompileFrontDoor, corpus: List[Dict],
                cfg: MapperConfig, n_clients: int,
                deadline_s: float) -> Dict:
    """``n_clients`` concurrent clients, one request each, drawn round-
    robin from the corpus. Returns client-side latency stats."""
    lat: List[float] = []
    violations = 0
    errors = 0

    async def client(i: int) -> None:
        nonlocal violations, errors
        c = corpus[i % len(corpus)]
        t0 = time.perf_counter()
        try:
            await door.compile(c["dfg"], c["cgra"], cfg, sweep_width=1,
                              deadline_s=deadline_s)
            lat.append(time.perf_counter() - t0)
        except Exception as exc:
            from repro.launch.serve import DeadlineExceeded
            if isinstance(exc, DeadlineExceeded):
                violations += 1
            else:
                errors += 1

    t0 = time.perf_counter()
    await asyncio.gather(*[client(i) for i in range(n_clients)])
    wall = time.perf_counter() - t0
    lat_ms = sorted(x * 1e3 for x in lat)

    def pct(p: float) -> float:
        if not lat_ms:
            return 0.0
        return lat_ms[min(len(lat_ms) - 1, int(p / 100.0 * len(lat_ms)))]

    return {
        "clients": n_clients,
        "served": len(lat),
        "deadline_violations": violations,
        "errors": errors,
        "wall_s": round(wall, 3),
        "req_per_s": round(len(lat) / max(wall, 1e-9), 1),
        "p50_ms": round(pct(50), 3),
        "p90_ms": round(pct(90), 3),
        "p99_ms": round(pct(99), 3),
        "mean_ms": round(statistics.fmean(lat_ms), 3) if lat_ms else 0.0,
    }


def direct_reference(corpus: List[Dict], cfg: MapperConfig) -> List:
    """The bit-identity oracle: the same requests through the plain
    ``compile()`` front door, no service, no store — the sequential
    deterministic path every served result must match exactly."""
    from repro.core.api import MapRequest, compile as compile_request
    out = []
    for c in corpus:
        out.append(compile_request(MapRequest(
            dfg=c["dfg"], arch=c["cgra"], config=cfg, sweep_width=1)))
    return out


def _bit_identical(a, b) -> bool:
    """Served-vs-reference identity on everything the client consumes:
    verdict, II bound pair, and the exact placement."""
    return (a.success == b.success and a.ii == b.ii and a.mii == b.mii
            and a.placement == b.placement)


async def run(quick: bool, workers: Optional[int], n_clients: int,
              store_dir: Optional[str], window_ms: float,
              deadline_s: float) -> Dict:
    names = QUICK_KERNELS if quick else suite.names()
    sizes = QUICK_SIZES if quick else FULL_SIZES
    # deterministic corpus config: sequential sweep (bit-reproducible
    # solver trajectory), explicit learnt cap matching the service default
    # so direct-reference sessions are constructed identically
    cfg = MapperConfig(solver="auto", timeout_s=120.0 if quick else 300.0,
                       max_learnt=100_000)
    base, variants = build_corpus(names, sizes, 2 if quick else 3, cfg)
    corpus = base + variants
    store_path = store_dir or tempfile.mkdtemp(prefix="satmapit-store-")
    out: Dict = {"quick": quick, "store": store_path,
                 "corpus_cells": len(corpus),
                 "base_cells": len(base), "variant_cells": len(variants)}

    # ---- phase 1: cold pool over a fresh store -------------------------
    with WorkerPool(workers=workers, store_path=store_path,
                    near_delta=1) as pool:
        async with CompileFrontDoor(pool, window_ms=window_ms,
                                    max_batch=64) as door:
            cold_base, t_base = await serve_corpus(door, base, cfg,
                                                   deadline_s=deadline_s)
            cold_var, t_var = await serve_corpus(door, variants, cfg,
                                                 deadline_s=deadline_s)
        cold = cold_base + cold_var
        t_cold = t_base + t_var
        cold_stats = pool.stats()
    out["cold_s"] = round(t_cold, 3)
    out["cold_workers"] = {k: v for k, v in cold_stats.items()
                           if isinstance(v, (int, float))}

    # ---- phase 2: warm restart over the same store ---------------------
    with WorkerPool(workers=workers, store_path=store_path,
                    near_delta=1) as pool:
        async with CompileFrontDoor(pool, window_ms=window_ms,
                                    max_batch=64) as door:
            warm, t_warm = await serve_corpus(door, corpus, cfg,
                                              deadline_s=deadline_s)

            # ---- phase 3: forced re-solves adopt persisted cores -------
            resolved, t_resolve = await serve_corpus(
                door, base, cfg, use_cache=False, deadline_s=deadline_s)

            # ---- phase 4: client storm --------------------------------
            storm_stats = await storm(door, corpus, cfg, n_clients,
                                      deadline_s)
            door_stats = door.stats.snapshot()
        warm_stats = pool.stats()

    out["warm_s"] = round(t_warm, 3)
    out["warm_speedup"] = round(t_cold / max(t_warm, 1e-9), 1)
    out["warm_via"] = sorted({r.service.via for r in warm})
    out["resolve_s"] = round(t_resolve, 3)
    out["storm"] = storm_stats
    out["front_door"] = door_stats
    out["warm_workers"] = {k: v for k, v in warm_stats.items()
                           if isinstance(v, (int, float))}

    req_cold = max(cold_stats.get("requests", 0), 1)
    req_warm = max(warm_stats.get("requests", 0), 1)
    out["hit_rates"] = {
        "near_shape": round(cold_stats.get("near_hits", 0)
                            / max(len(variants), 1), 3),
        "disk": round(warm_stats.get("disk_hits", 0) / req_warm, 3),
        "cache": round((cold_stats.get("cache_hits", 0)
                        + warm_stats.get("cache_hits", 0))
                       / (req_cold + req_warm), 3),
        "core_prune_iis": warm_stats.get("iis_pruned", 0),
        "cores_preloaded": warm_stats.get("cores_preloaded", 0),
        "near_hits": cold_stats.get("near_hits", 0),
    }
    out["summary"] = {
        "req_per_s": storm_stats["req_per_s"],
        "p50_ms": storm_stats["p50_ms"],
        "p99_ms": storm_stats["p99_ms"],
        "warm_speedup": out["warm_speedup"],
        "deadline_violations": storm_stats["deadline_violations"],
        "near_hits": cold_stats.get("near_hits", 0),
        "disk_hits": warm_stats.get("disk_hits", 0),
        "cores_preloaded": warm_stats.get("cores_preloaded", 0),
    }
    # stash result objects for --check (not serialised)
    out["_cold"] = cold
    out["_warm"] = warm
    out["_resolved"] = resolved
    out["_corpus"] = corpus
    out["_cfg"] = cfg
    return out


def check(out: Dict) -> None:
    bad: List[str] = []
    corpus, cfg = out["_corpus"], out["_cfg"]
    cold, warm = out["_cold"], out["_warm"]

    # served results must be bit-identical to a direct compile() of the
    # same requests (the sequential deterministic reference)
    ref = direct_reference(corpus, cfg)
    mismatch = [c["name"] for c, a, b in zip(corpus, cold, ref)
                if not _bit_identical(a, b)]
    if mismatch:
        bad.append(f"served != direct compile() on {mismatch}")
    # the warm (disk) restart must return the *same bits* it stored
    drift = [c["name"] for c, a, b in zip(corpus, warm, cold)
             if not _bit_identical(a, b)]
    if drift:
        bad.append(f"warm restart drifted from cold results on {drift}")
    not_disk = [c["name"] for c, r in zip(corpus, warm)
                if r.service.via != "disk"]
    if not_disk:
        bad.append(f"warm restart did not hit the disk store on {not_disk}")
    if out["warm_speedup"] < 3.0:
        bad.append(f"warm restart speedup {out['warm_speedup']}x < 3x")
    if out["hit_rates"]["near_hits"] < 1:
        bad.append("no near-shape warm admissions (near_hits == 0)")
    if out["hit_rates"]["cores_preloaded"] < 1:
        bad.append("restarted sessions preloaded no persisted UNSAT cores")
    st = out["storm"]
    if st["clients"] < 1000:
        bad.append(f"storm ran only {st['clients']} clients (< 1000)")
    if st["deadline_violations"] or st["errors"]:
        bad.append(f"storm: {st['deadline_violations']} deadline "
                   f"violations, {st['errors']} errors")
    if st["served"] != st["clients"]:
        bad.append(f"storm served {st['served']}/{st['clients']}")
    if bad:
        raise SystemExit("serve_load --check failed: " + "; ".join(bad))
    print("serve_load --check OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--store", default=None,
                    help="store directory (default: fresh tempdir)")
    ap.add_argument("--window-ms", type=float, default=4.0)
    ap.add_argument("--deadline-s", type=float, default=120.0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    out = asyncio.run(run(args.quick, args.workers, args.clients,
                          args.store, args.window_ms, args.deadline_s))
    public = {k: v for k, v in out.items() if not k.startswith("_")}
    print(json.dumps(public, indent=1, sort_keys=True))
    with open(args.out, "w") as f:
        json.dump(public, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    if args.check:
        check(out)


if __name__ == "__main__":
    main()

"""Mapping-campaign benchmark: the data flywheel end to end.

Thin driver over :func:`repro.launch.campaign.run` — corpus generation
(seeded grammar + mutants, isomorphism dedup), (DFG x fabric) cells fanned
through the :class:`~repro.core.workers.WorkerPool`, sharded dataset
append, guide training, and the soundness/efficiency gates — reported as
``BENCH_campaign.json``:

  * ``campaign.cells_per_sec`` — cells through the pool per second;
  * ``dedup_rate`` — fraction of generated DFGs collapsed by canonical-
    form dedup;
  * ``guide.hit1`` / ``guide.hit2`` — held-out predictor accuracy vs the
    ``guide.baseline_hit1`` always-start-at-MII baseline;
  * ``eval.attempts_saved`` — solver attempts the guided sweep avoided on
    held-out cells (guided vs unguided at the same ``sweep_width``);
  * ``suite_gate`` — guided final II == unguided final II on every suite
    cell (the soundness contract).

``--check`` gates (see :func:`repro.launch.campaign.check_gates`):
>= 200 cells mapped, dedup > 0, dataset round-trips, guided attempts <
unguided attempts, zero II mismatches anywhere.

    PYTHONPATH=src python benchmarks/campaign_bench.py --quick --check
"""
from __future__ import annotations

import argparse
import json
import tempfile

from repro.launch.campaign import check_gates, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (~250 cells, 2 workers)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every gate passes")
    ap.add_argument("--out", default="BENCH_campaign.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--keep", default=None, metavar="DIR",
                    help="keep campaign artifacts (dataset shards, store, "
                         "guide.npz) in DIR instead of a temp directory")
    args = ap.parse_args()

    if args.quick:
        knobs = dict(workers=2, n_random=64, n_mutants=40,
                     fabrics="2x2,3x3,4x4", eval_cells=40)
    else:
        knobs = dict(workers=None, n_random=256, n_mutants=128,
                     fabrics="2x2,3x3,4x4,3x3-torus,4x4-onehop,"
                             "4x4:mem2,4x4-torus:r8",
                     eval_cells=96)

    def go(outdir: str):
        return run(seed=args.seed, out=outdir, compact=True, **knobs)

    if args.keep:
        summary = go(args.keep)
    else:
        with tempfile.TemporaryDirectory() as d:
            summary = go(d)

    with open(args.out, "w") as f:
        json.dump(summary, f, indent=2, default=str)
    print(f"wrote {args.out}")
    if args.check:
        errs = check_gates(summary)
        if errs:
            raise SystemExit("campaign_bench --check failed: " +
                             "; ".join(errs))
        print("campaign_bench --check OK")


if __name__ == "__main__":
    main()

"""Render the §Roofline / §Dry-run tables from the dry-run JSONL."""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    seen = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # keep the LAST record per cell (reruns supersede)
            seen[(r["arch"], r["shape"], r["mesh"],
                  json.dumps(r.get("overrides")))] = r
    return list(seen.values())


def roofline_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | bottleneck | compute s | memory s | "
           "collective s | useful FLOP ratio | HBM GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16" or r.get("overrides"):
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                       f"{r['reason']} | | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {}).get("total_bytes", 0) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['bottleneck'][:-2]} | "
            f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | "
            f"{r.get('useful_flop_ratio', 0):.2f} | {mem:.1f} |")
    return "\n".join(out)


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | compile s | HLO GFLOPs/dev | "
           "wire GB/dev | HBM GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("overrides"):
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | | | | |")
            continue
        cc = r.get("cost_corrected") or {
            "flops": r["cost"].get("flops", 0),
            "wire_bytes": r["collectives"]["wire_bytes"]}
        mem = r.get("memory", {}).get("total_bytes", 0) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0):.1f} | {cc['flops']/1e9:.0f} | "
            f"{cc['wire_bytes']/1e9:.1f} | {mem:.1f} |")
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final.jsonl"
    rows = load(path)
    print("## Roofline (single-pod 16x16, per device)\n")
    print(roofline_table(rows))
    print("\n## Dry-run (all cells)\n")
    print(dryrun_table(rows))


if __name__ == "__main__":
    main()

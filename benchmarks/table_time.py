"""Paper Tables I-IV reproduction: mapping time (seconds) per benchmark for
each CGRA size, SAT-MapIt vs the heuristic baseline, plus the paper's
'faster when it matters' aggregate (mean delta split by who wins)."""
from __future__ import annotations

import json
import statistics
from typing import Dict

from . import fig6_ii


def main(quick: bool = False) -> None:
    names = ["sha", "gsm", "srand", "bitcount", "nw"] if quick else None
    res = fig6_ii.run(timeout_s=30 if quick else 120, names=names,
                      heuristic_restarts=10 if quick else 30,
                      service=False)   # only sat/heur timings are read
    print("benchmark/size,sat_time_s,heur_time_s,delta_s")
    sat_slower, sat_faster = [], []
    for k, v in res.items():
        d = v["sat_time"] - v["heur_time"]
        print(f"{k},{v['sat_time']},{v['heur_time']},{round(d,3)}")
        (sat_slower if d > 0 else sat_faster).append(abs(d))
    agg = {
        "sat_slower_cells": len(sat_slower),
        "sat_slower_mean_s": round(statistics.mean(sat_slower), 2)
        if sat_slower else 0.0,
        "sat_faster_cells": len(sat_faster),
        "sat_faster_mean_s": round(statistics.mean(sat_faster), 2)
        if sat_faster else 0.0,
    }
    print(json.dumps(agg))


if __name__ == "__main__":
    main()

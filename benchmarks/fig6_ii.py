"""Paper Fig. 6 reproduction + sweep-engine comparison.

Per benchmark x CGRA size (2x2 .. 5x5) this reports the II found by
  * the sequential SAT-MapIt Fig. 3 loop with the incremental
    assumption-based solver core (``map_loop``, sweep_width=1, the
    default ``incremental=True``),
  * the same loop with the core disabled (``incremental=False`` — the
    paper-faithful cold encode+solve per II, the PR 1 reference),
  * the parallel II-sweep engine (``map_loop`` with sweep_width=k),
  * the persistent ``MappingService`` (warm second pass over the suite:
    pooled sessions reuse learnt clauses and skip IIs refuted by
    failed-assumption cores on the first pass; the ``service_pruned`` and
    ``service_cache_hit`` columns report per-cell core prunes and
    canonical-DFG cache hits), and
  * the heuristic SoA stand-in,
with per-mode wall-clock, side-by-side. Lower II is better; None means no
mapping found within budget (the paper's black/red marks). ``summarize()``
additionally asserts the incremental core's II is never worse than the
cold path's (``inc_ii_le_cold_cells``) and aggregates per-kernel time for
all three SAT modes. ``--amo=sequential`` switches both modes to the Sinz
at-most-one encoding; the AMO clause-count table printed up front compares
its size against the paper's pairwise encoding.

The sweep engine must find an II <= the sequential mode's II on every cell
(they are equivalent searches; <= rather than == only because a timeout can
stop either mode early), and lower total mapping wall-clock on a majority
of kernels — ``summarize()`` reports both claims. The sequential baseline
is the paper-faithful Fig. 3 loop, which re-encodes from scratch at every
II; the sweep's win therefore combines one-shot incremental encoding with
process-parallel UNSAT proofs and the staged WalkSAT racer. Per-attempt
``encode_time`` in MappingResult.attempts isolates the encoding effect;
sweep-mode ``solve_time`` is delivery latency from window start (queueing
included), not the solver's own runtime.
"""
from __future__ import annotations

import json
import time
from typing import Dict, Optional

from repro.core import suite
from repro.core.baseline import BaselineConfig, map_heuristic
from repro.core.cgra import CGRA, cgra_from_name
from repro.core.mapper import MapperConfig, map_loop

# default Fig. 6 grid; override with --sizes=... using the full fabric
# grammar (RxC[-mesh|torus|diag|onehop][:rN][:clsK...]) to sweep other
# fabrics, e.g. --sizes=3x3,3x3-torus,3x3-onehop,4x4:r2,3x3:mul2:mem2
# (":mul2"/":mem2" = 2-cycle multipliers/memory ports; every mode's II is
# then checked against the latency-aware MII by summarize()/--check)
SIZES = ["2x2", "3x3", "4x4", "5x5"]


def _warmup(sweep_width: int) -> None:
    """Compile the batched-walksat window shapes once, outside the timed
    region (the XLA compile cache is keyed on bucketed clause-tensor
    shapes; see walksat_jax.pack_cnf_window)."""
    g = suite.get("nw")
    map_loop(g, CGRA(4, 4), MapperConfig(solver="auto", timeout_s=60),
             sweep_width=sweep_width)


def amo_clause_report(names=None) -> Dict[str, Dict[str, int]]:
    """Clause counts of both AMO encodings (the paper's pairwise vs the
    Sinz sequential) per kernel at MII on a 4x4 — the Sinz encoding turns
    the O(k^2) binary at-most-one clauses into O(k) ternary ones."""
    from repro.core.encode import encode
    from repro.core.schedule import min_ii
    out: Dict[str, Dict[str, int]] = {}
    cgra = CGRA(4, 4)
    for name in names or suite.names():
        g = suite.get(name)
        mii = max(min_ii(g, cgra), 1)
        out[name] = {amo: encode(g, cgra, mii, amo).stats["clauses"]
                     for amo in ("pairwise", "sequential")}
    return out


def run(timeout_s: float = 120.0, names=None, heuristic_restarts: int = 30,
        routing: bool = False, sweep_width: int = 4,
        amo: str = "pairwise", service: bool = True, sizes=None) -> Dict:
    """``service=False`` skips the three MappingService legs (cold pass +
    timed warm pass + cached call) and their columns — for callers like
    ``table_time.py`` that only consume the sat/heur timings. ``sizes``
    takes fabric names in the full ``RxC[-topology][:rN]`` grammar, so
    torus/one-hop/register-count variants benchmark from the CLI."""
    names = names or suite.names()
    _warmup(sweep_width)
    svc = None
    if service:
        from repro.core.service import MappingService
        svc = MappingService()
    out: Dict[str, Dict] = {}
    for size in (sizes or SIZES):
        cgra = cgra_from_name(size)
        for name in names:
            g = suite.get(name)
            t0 = time.time()
            rs = map_loop(g, cgra, MapperConfig(
                solver="auto", timeout_s=timeout_s, routing=routing,
                amo=amo))
            t_sat = time.time() - t0
            t0 = time.time()
            # the cold reference: same sequential Fig. 3 loop with the
            # incremental assumption-based core disabled (fresh encode +
            # cold solve per II — exactly the PR 1 path)
            rc = map_loop(suite.get(name), cgra, MapperConfig(
                solver="auto", timeout_s=timeout_s, routing=routing,
                amo=amo, incremental=False))
            t_cold = time.time() - t0
            g2 = suite.get(name)
            t0 = time.time()
            # routing must match the sequential config: with routing=True
            # map_loop keeps the (routed) sequential path for both calls,
            # so the sweep_ii <= sat_ii invariant is never an artefact of
            # comparing a routed search against an unrouted one
            rw = map_loop(g2, cgra, MapperConfig(
                solver="auto", timeout_s=timeout_s, routing=routing,
                amo=amo), sweep_width=sweep_width)
            t_sweep = time.time() - t0
            t0 = time.time()
            rh = map_heuristic(g, cgra, BaselineConfig(
                n_restarts=heuristic_restarts, timeout_s=timeout_s))
            t_heur = time.time() - t0
            cell = {
                "sat_ii": rs.ii, "cold_ii": rc.ii, "sweep_ii": rw.ii,
                "heur_ii": rh.ii,
                "sat_time": round(t_sat, 3),
                "cold_time": round(t_cold, 3),
                "sweep_time": round(t_sweep, 3),
                "heur_time": round(t_heur, 3),
                "mii": rs.mii,
                "sat_route_nodes": rs.n_route_nodes,
            }
            if svc is not None:
                # the mapping service: a first pass populates the pooled
                # session for this (topology, shape), the timed *warm*
                # second pass then reuses it — IIs refuted on the first
                # pass are skipped via their failed-assumption cores —
                # and a final cached call exercises the canonical-DFG
                # result cache
                svc_cfg = MapperConfig(solver="auto", timeout_s=timeout_s,
                                       routing=routing, amo=amo)
                t0 = time.time()
                svc.map(suite.get(name), cgra, svc_cfg)
                t_svc_first = time.time() - t0
                t0 = time.time()
                rv = svc.map(suite.get(name), cgra, svc_cfg,
                             use_cache=False)
                t_svc = time.time() - t0
                cached = svc.map(suite.get(name), cgra, svc_cfg)
                cell.update({
                    "service_ii": rv.ii,
                    "service_first_time": round(t_svc_first, 3),
                    "service_time": round(t_svc, 3),
                    "service_pruned": rv.service.iis_pruned,
                    "service_cache_hit": cached.service.cache_hit,
                })
            out[f"{name}/{size}"] = cell
    return out


def walksat_engine_bench(names=None, size: str = "3x3", steps: int = 4000,
                         batch: int = 12, seed: int = 0) -> Dict[str, Dict]:
    """Wall-clock of the three probSAT drive styles on each kernel's
    II window [MII, MII+2]:

      * ``seq``    — one ``solve_walksat`` call per CNF (no window
        batching; each instance walks alone),
      * ``host``   — the batched window with the per-chunk host loop
        (one jitted chunk per host iteration, flags polled every chunk),
      * ``device`` — the device-resident engine (the whole chunk schedule
        inside one jitted while_loop, host polls every few chunks).

    Engines are bit-compatible, so ``engines_agree`` (same statuses *and*
    models) must be True on every cell — ``--check`` asserts it. XLA
    compiles are paid in a warmup pass so the timings compare dispatch
    styles, not compilation.
    """
    from repro.core.encode import EncoderSession
    from repro.core.sat.walksat_jax import (solve_walksat,
                                            solve_walksat_window)
    from repro.core.schedule import min_ii
    out: Dict[str, Dict] = {}
    cgra = cgra_from_name(size)
    for name in names or suite.names():
        g = suite.get(name)
        mii = max(min_ii(g, cgra), 1)
        sess = EncoderSession(g, cgra)
        iis = [mii, mii + 1, mii + 2]
        cnfs = [sess.encode(ii).cnf for ii in iis]
        for engine in ("host", "device"):
            solve_walksat_window(cnfs, seed=seed, steps=64, batch=batch,
                                 engine=engine)
        t0 = time.time()
        rseq = [solve_walksat(c, seed=seed, steps=steps, batch=batch)
                for c in cnfs]
        t_seq = time.time() - t0
        t0 = time.time()
        rh = solve_walksat_window(cnfs, seed=seed, steps=steps, batch=batch,
                                  engine="host")
        t_host = time.time() - t0
        t0 = time.time()
        rd = solve_walksat_window(cnfs, seed=seed, steps=steps, batch=batch,
                                  engine="device")
        t_dev = time.time() - t0
        out[f"{name}/{size}"] = {
            "iis": iis,
            "seq_time": round(t_seq, 3),
            "host_time": round(t_host, 3),
            "device_time": round(t_dev, 3),
            "seq_statuses": [s for s, _ in rseq],
            "host_statuses": [s for s, _ in rh],
            "device_statuses": [s for s, _ in rd],
            "engines_agree": rh == rd,
        }
    return out


def _legacy_pack(cnf) -> tuple:
    """The PR 6 per-clause dense pack (pre-arena), pinned here as the
    microbenchmark baseline and identity oracle for the vectorised
    ``pack_cnf_np``: same padded clause matrix and occurrence lists, built
    one Python append at a time."""
    import numpy as np
    lmax = max((len(c) for c in cnf.clauses), default=1)
    C = cnf.n_clauses
    cvars = np.zeros((C, lmax), np.int32)
    csign = np.zeros((C, lmax), bool)
    occ = [[] for _ in range(cnf.n_vars + 1)]
    for ci, cl in enumerate(cnf.clauses):
        for j, lit in enumerate(cl):
            v = abs(lit)
            cvars[ci, j] = v
            csign[ci, j] = lit > 0
            occ[v].append((ci, lit > 0))
    omax = max((len(o) for o in occ), default=1)
    ovars = np.full((cnf.n_vars + 1, omax), -1, np.int32)
    osign = np.zeros((cnf.n_vars + 1, omax), bool)
    for v, lst in enumerate(occ):
        for j, (ci, s) in enumerate(lst):
            ovars[v, j] = ci
            osign[v, j] = s
    return cvars, csign, ovars, osign, cnf.n_vars, C


def encode_pack_bench(names=None, size: str = "4x4",
                      n_iis: int = 3, repeats: int = 3) -> Dict[str, Dict]:
    """Encode+pack microbenchmark: the pinned legacy per-clause emitters
    (``emitters="legacy"`` — the pre-arena loop generators kept as the
    test oracle) plus the pinned per-clause pack, vs the vectorised arena
    emitters plus the zero-copy arena pack, per kernel on ``size`` over
    the II window [MII, MII + n_iis).

    Every cell also *verifies* bit-identical clause streams and identical
    pack tensors between the two paths (``streams_match``/``packs_match``
    — --check asserts them), so the speedup is never measured against a
    divergent formula. Timings are best-of-``repeats`` of the per-II
    emit(+pack) work with the session layout prebuilt outside the loop:
    the layout/C1 build is one shared implementation (not forked by
    emitter mode), and a sweep pays it once while paying the per-II
    families at every candidate II.
    """
    import numpy as np
    from repro.core.encode import EncoderSession
    from repro.core.sat.walksat_jax import pack_cnf_np
    from repro.core.schedule import min_ii
    out: Dict[str, Dict] = {}
    cgra = cgra_from_name(size)
    for name in names or suite.names():
        g = suite.get(name)
        mii = max(min_ii(g, cgra), 1)
        iis = list(range(mii, mii + n_iis))
        # identity gate: legacy and vector paths must agree bit-for-bit
        sl = EncoderSession(g, cgra, emitters="legacy")
        sv = EncoderSession(g, cgra, emitters="vector")
        streams_match = packs_match = True
        for ii in iis:
            cl_, cv_ = sl.encode(ii).cnf, sv.encode(ii).cnf
            if not (cl_.n_vars == cv_.n_vars and cl_.clauses == cv_.clauses):
                streams_match = False
                continue
            ref, got = _legacy_pack(cv_), pack_cnf_np(cv_)
            if not all(np.array_equal(a, b) for a, b in zip(ref, got)):
                packs_match = False

        # sessions (and their shared layout/C1 build — code identical in
        # both modes) are prebuilt: the timed region is exactly the per-II
        # family emitters and the per-CNF pack, i.e. the work a sweep pays
        # per candidate II
        def pipeline(mode: str, with_pack: bool) -> float:
            s = EncoderSession(g, cgra, emitters=mode)
            s._ensure_layout()
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                cnfs = [s.encode(ii).cnf for ii in iis]
                if with_pack:
                    pack = _legacy_pack if mode == "legacy" else pack_cnf_np
                    for c in cnfs:
                        pack(c)
                best = min(best, time.perf_counter() - t0)
            return best

        def encode_only(mode: str) -> float:
            return pipeline(mode, with_pack=False)

        e_leg, e_vec = encode_only("legacy"), encode_only("vector")
        t_leg, t_vec = pipeline("legacy", True), pipeline("vector", True)
        out[f"{name}/{size}"] = {
            "iis": iis,
            "encode_legacy_s": round(e_leg, 5),
            "encode_vector_s": round(e_vec, 5),
            "total_legacy_s": round(t_leg, 5),
            "total_vector_s": round(t_vec, 5),
            "encode_speedup": round(e_leg / max(e_vec, 1e-9), 2),
            "total_speedup": round(t_leg / max(t_vec, 1e-9), 2),
            "streams_match": streams_match,
            "packs_match": packs_match,
        }
    return out


def summarize(results: Dict) -> Dict:
    """The paper's headline stats over all cells, plus sweep-vs-sequential
    equivalence and wall-clock comparison (aggregated per kernel)."""
    better = worse = equal = sat_only = heur_only = 0
    sweep_ii_le = sweep_ii_gt = 0
    inc_ii_le = inc_ii_gt = 0
    below_mii = 0
    svc_ii_eq = svc_ii_ne = svc_pruned = svc_cache_hits = svc_cells = 0
    per_kernel: Dict[str, Dict[str, float]] = {}
    for k, v in results.items():
        # no mode may ever report an II below the (latency-aware) MII —
        # on multi-cycle fabrics (--sizes=...:mul2) this is exactly the
        # RecMII-respects-latencies acceptance check; counted per *cell*
        if any(v.get(mode) is not None and v[mode] < v["mii"]
               for mode in ("sat_ii", "cold_ii", "sweep_ii", "heur_ii",
                            "service_ii")):
            below_mii += 1
        si, hi = v["sat_ii"], v["heur_ii"]
        if si is not None and hi is None:
            sat_only += 1
        elif si is None and hi is not None:
            heur_only += 1
        elif si is None and hi is None:
            equal += 1
        elif si < hi:
            better += 1
        elif si > hi:
            worse += 1
        else:
            equal += 1
        wi = v.get("sweep_ii")
        if si is None or (wi is not None and wi <= si):
            sweep_ii_le += 1
        else:
            sweep_ii_gt += 1
        # incremental (sat_ii) vs the cold reference: the assumption-based
        # core must never report a worse II than the cold path
        ci = v.get("cold_ii")
        if ci is None or (si is not None and si <= ci):
            inc_ii_le += 1
        else:
            inc_ii_gt += 1
        # the mapping service's warm pass must agree with the cold
        # reference on the minimal II (cores only replay proven UNSATs);
        # cells from run(service=False) carry no service columns
        if "service_ii" in v:
            svc_cells += 1
            if ci is None or v["service_ii"] == ci:
                svc_ii_eq += 1
            else:
                svc_ii_ne += 1
            svc_pruned += v.get("service_pruned", 0) or 0
            svc_cache_hits += 1 if v.get("service_cache_hit") else 0
        kernel = k.split("/")[0]
        agg = per_kernel.setdefault(kernel,
                                    {"sat": 0.0, "cold": 0.0, "sweep": 0.0,
                                     "service_first": 0.0, "service": 0.0})
        agg["sat"] += v["sat_time"]
        agg["cold"] += v.get("cold_time", 0.0)
        agg["sweep"] += v.get("sweep_time", 0.0)
        agg["service_first"] += v.get("service_first_time", 0.0)
        agg["service"] += v.get("service_time", 0.0)
    sweep_faster = [k for k, a in per_kernel.items() if a["sweep"] < a["sat"]]
    inc_faster = [k for k, a in per_kernel.items() if a["sat"] < a["cold"]]
    svc_warm_faster = [k for k, a in per_kernel.items()
                       if a["service"] < a["service_first"]]
    n = len(results)
    return {"cells": n, "sat_better": better, "sat_only_found": sat_only,
            "equal": equal, "sat_worse": worse, "heur_only_found": heur_only,
            "sat_better_or_only_pct": round(
                100.0 * (better + sat_only) / max(n, 1), 2),
            "sweep_ii_le_cells": sweep_ii_le,
            "sweep_ii_gt_cells": sweep_ii_gt,
            "inc_ii_le_cold_cells": inc_ii_le,
            "inc_ii_gt_cold_cells": inc_ii_gt,
            "ii_below_mii_cells": below_mii,
            "service_cells": svc_cells,
            "service_ii_eq_cold_cells": svc_ii_eq,
            "service_ii_ne_cold_cells": svc_ii_ne,
            "service_iis_pruned": svc_pruned,
            "service_cache_hit_cells": svc_cache_hits,
            "kernels": len(per_kernel),
            "sweep_faster_kernels": sorted(sweep_faster),
            "sweep_faster_kernel_count": len(sweep_faster),
            "inc_faster_kernels": sorted(inc_faster),
            "inc_faster_kernel_count": len(inc_faster),
            "service_warm_faster_kernels": sorted(svc_warm_faster),
            "service_warm_faster_kernel_count": len(svc_warm_faster),
            "per_kernel_time": {k: {m: round(t, 3) for m, t in a.items()}
                                for k, a in sorted(per_kernel.items())}}


def main(quick: bool = False, amo: str = "pairwise",
         check: bool = False, sizes=None,
         bench_out: str = "BENCH_sweep.json",
         encode_bench_out: str = "BENCH_encode.json") -> None:
    names = ["sha", "gsm", "srand", "bitcount", "nw"] if quick else None
    print("AMO clause counts (pairwise vs Sinz sequential, at MII on 4x4):")
    for name, counts in amo_clause_report(names).items():
        print(f"  {name:10s} pairwise={counts['pairwise']:6d} "
              f"sequential={counts['sequential']:6d}")
    epb = encode_pack_bench(names)
    print("encode+pack (pinned legacy emitters/pack vs vectorised arena):")
    for k, v in epb.items():
        print(f"  {k:16s} encode {v['encode_legacy_s']:7.4f}s ->"
              f" {v['encode_vector_s']:7.4f}s ({v['encode_speedup']:5.2f}x)"
              f"  +pack {v['total_legacy_s']:7.4f}s ->"
              f" {v['total_vector_s']:7.4f}s ({v['total_speedup']:5.2f}x)"
              f"  identical={v['streams_match'] and v['packs_match']}")
    # the encode-throughput trajectory artefact, next to BENCH_sweep.json
    agg_e = (sum(v["encode_legacy_s"] for v in epb.values())
             / max(sum(v["encode_vector_s"] for v in epb.values()), 1e-9))
    agg_t = (sum(v["total_legacy_s"] for v in epb.values())
             / max(sum(v["total_vector_s"] for v in epb.values()), 1e-9))
    with open(encode_bench_out, "w") as f:
        json.dump({"quick": quick, "cells": epb,
                   "aggregate_encode_speedup": round(agg_e, 2),
                   "aggregate_encode_pack_speedup": round(agg_t, 2)},
                  f, indent=1, sort_keys=True)
    print(f"wrote {encode_bench_out} (aggregate encode {agg_e:.2f}x, "
          f"encode+pack {agg_t:.2f}x)")
    engines = walksat_engine_bench(
        names, steps=2000 if quick else 4000, batch=8 if quick else 12)
    print("walksat engines (seq per-CNF vs host window vs device-resident):")
    for k, v in engines.items():
        print(f"  {k:16s} seq={v['seq_time']:7.3f}s "
              f"host={v['host_time']:7.3f}s device={v['device_time']:7.3f}s "
              f"agree={v['engines_agree']}")
    res = run(timeout_s=30 if quick else 120, names=names,
              heuristic_restarts=10 if quick else 30, amo=amo, sizes=sizes)
    print("benchmark/size,mii,sat_ii,cold_ii,sweep_ii,service_ii,heur_ii,"
          "sat_time_s,cold_time_s,sweep_time_s,service_warm_time_s,"
          "heur_time_s,service_pruned,service_cache_hit")
    for k, v in res.items():
        print(f"{k},{v['mii']},{v['sat_ii']},{v['cold_ii']},{v['sweep_ii']},"
              f"{v['service_ii']},{v['heur_ii']},{v['sat_time']},"
              f"{v['cold_time']},{v['sweep_time']},{v['service_time']},"
              f"{v['heur_time']},{v['service_pruned']},"
              f"{int(v['service_cache_hit'])}")
    summary = summarize(res)
    print(json.dumps(summary, indent=1))
    # the perf-trajectory artefact: per-kernel wall-clock of every mapping
    # mode plus the walksat engine comparison (seq / host window /
    # device-resident), machine-readable for run-over-run tracking
    with open(bench_out, "w") as f:
        json.dump({
            "quick": quick,
            "per_kernel_time": summary["per_kernel_time"],
            "walksat_engines": engines,
            "summary": {k: v for k, v in summary.items()
                        if k != "per_kernel_time"},
        }, f, indent=1, sort_keys=True)
    print(f"wrote {bench_out}")
    if check:
        # CI smoke assertions: the parallel sweep must never report a
        # worse II than the sequential loop, the service's warm pass must
        # agree with the cold reference everywhere, and every cell's
        # cached re-request must hit
        bad = []
        if summary["sweep_ii_gt_cells"]:
            bad.append(f"sweep worse on {summary['sweep_ii_gt_cells']} cells")
        if summary["inc_ii_gt_cold_cells"]:
            bad.append("incremental worse than cold on "
                       f"{summary['inc_ii_gt_cold_cells']} cells")
        if summary["ii_below_mii_cells"]:
            bad.append("II below the latency-aware MII on "
                       f"{summary['ii_below_mii_cells']} cells")
        if summary["service_ii_ne_cold_cells"]:
            bad.append("service II mismatch on "
                       f"{summary['service_ii_ne_cold_cells']} cells")
        if summary["service_cache_hit_cells"] != summary["service_cells"]:
            bad.append("cache misses on repeated requests")
        disagree = [k for k, v in engines.items()
                    if not v["engines_agree"]]
        if disagree:
            bad.append("walksat host/device engines disagree on "
                       f"{disagree}")
        stream_bad = [k for k, v in epb.items()
                      if not (v["streams_match"] and v["packs_match"])]
        if stream_bad:
            bad.append("vectorised emitters/pack diverge from the pinned "
                       f"legacy path on {stream_bad}")
        if agg_e < 1.5:
            bad.append(f"aggregate encode speedup {agg_e:.2f}x < 1.5x "
                       "vs the pinned legacy emitters")
        # static gate: the emitted encodings must audit clean (family
        # counts on the analytic formulas, no unsuppressed redundancy)
        from repro.analysis import audit_suite
        audit_reports = audit_suite(names=names, amo=amo)
        audit_bad = [r for r in audit_reports if not r.ok()]
        if audit_bad:
            bad.append("CNF audit unclean on "
                       + ", ".join(f"{r.cell}[{r.mode}]"
                                   for r in audit_bad))
        else:
            print(f"cnf audit OK ({len(audit_reports)} reports)")
        if bad:
            raise SystemExit("fig6 --check failed: " + "; ".join(bad))
        print("fig6 --check OK")


if __name__ == "__main__":
    import sys
    amo = "sequential" if "--amo=sequential" in sys.argv else "pairwise"
    sizes = None
    bench_out = "BENCH_sweep.json"
    encode_bench_out = "BENCH_encode.json"
    for a in sys.argv[1:]:
        if a.startswith("--sizes="):
            sizes = [s for s in a[len("--sizes="):].split(",") if s]
        elif a.startswith("--bench-out="):
            bench_out = a[len("--bench-out="):]
        elif a.startswith("--encode-bench-out="):
            encode_bench_out = a[len("--encode-bench-out="):]
    main(quick="--quick" in sys.argv, amo=amo,
         check="--check" in sys.argv, sizes=sizes, bench_out=bench_out,
         encode_bench_out=encode_bench_out)

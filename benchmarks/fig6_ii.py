"""Paper Fig. 6 reproduction: II found by SAT-MapIt vs the heuristic SoA
stand-in, per benchmark x CGRA size (2x2 .. 5x5). Lower is better; None
means no mapping found within budget (the paper's black/red marks)."""
from __future__ import annotations

import json
import time
from typing import Dict, Optional

from repro.core import suite
from repro.core.baseline import BaselineConfig, map_heuristic
from repro.core.cgra import CGRA
from repro.core.mapper import MapperConfig, map_loop

SIZES = ["2x2", "3x3", "4x4", "5x5"]


def run(timeout_s: float = 120.0, names=None, heuristic_restarts: int = 30,
        routing: bool = False) -> Dict:
    names = names or suite.names()
    out: Dict[str, Dict] = {}
    for size in SIZES:
        r, c = (int(x) for x in size.split("x"))
        cgra = CGRA(r, c)
        for name in names:
            g = suite.get(name)
            t0 = time.time()
            rs = map_loop(g, cgra, MapperConfig(
                solver="auto", timeout_s=timeout_s, routing=routing))
            t_sat = time.time() - t0
            t0 = time.time()
            rh = map_heuristic(g, cgra, BaselineConfig(
                n_restarts=heuristic_restarts, timeout_s=timeout_s))
            t_heur = time.time() - t0
            out[f"{name}/{size}"] = {
                "sat_ii": rs.ii, "heur_ii": rh.ii,
                "sat_time": round(t_sat, 3), "heur_time": round(t_heur, 3),
                "mii": rs.mii,
                "sat_route_nodes": rs.n_route_nodes,
            }
    return out


def summarize(results: Dict) -> Dict:
    """The paper's headline stats over all cells."""
    better = worse = equal = sat_only = heur_only = 0
    for k, v in results.items():
        si, hi = v["sat_ii"], v["heur_ii"]
        if si is not None and hi is None:
            sat_only += 1
        elif si is None and hi is not None:
            heur_only += 1
        elif si is None and hi is None:
            equal += 1
        elif si < hi:
            better += 1
        elif si > hi:
            worse += 1
        else:
            equal += 1
    n = len(results)
    return {"cells": n, "sat_better": better, "sat_only_found": sat_only,
            "equal": equal, "sat_worse": worse, "heur_only_found": heur_only,
            "sat_better_or_only_pct": round(
                100.0 * (better + sat_only) / max(n, 1), 2)}


def main(quick: bool = False) -> None:
    names = ["sha", "gsm", "srand", "bitcount", "nw"] if quick else None
    res = run(timeout_s=30 if quick else 120, names=names,
              heuristic_restarts=10 if quick else 30)
    print("benchmark/size,mii,sat_ii,heur_ii,sat_time_s,heur_time_s")
    for k, v in res.items():
        print(f"{k},{v['mii']},{v['sat_ii']},{v['heur_ii']},"
              f"{v['sat_time']},{v['heur_time']}")
    print(json.dumps(summarize(res)))


if __name__ == "__main__":
    main()

"""Sharded, elastic checkpointing.

Layout on disk (one directory per step):

    ckpt_000040/
      manifest.json     step, data cursor, PRNG key, mesh shape, leaf index
      <leaf>.<i>.npy    chunk i of the leaf (chunked on axis 0)

Properties needed at 1000-node scale, all implemented here:
  * atomic publish — written to a tmp dir, renamed only when complete, so a
    killed writer never leaves a half checkpoint visible;
  * elastic restore — leaves are stored as logical arrays in axis-0 chunks;
    restore() reassembles and device_puts against ANY mesh/spec, so the
    job can come back on a different pod count than it left on;
  * resumability — the manifest carries the data-pipeline cursor and PRNG
    key; `latest_step()` finds the newest complete checkpoint;
  * retention — keep_last trims old steps after a successful publish.

On a real multi-host pod each host writes only its addressable chunk set
(chunk boundary = shard boundary on axis 0 when divisible); this process
is the single-host instantiation of the same format.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for path, val in flat.items():
        ks = path.split("/")
        d = tree
        for k in ks[:-1]:
            d = d.setdefault(k, {})
        d[ks[-1]] = val
    return tree


def save(root: str, step: int, tree: Any, *, extra: Optional[Dict] = None,
         chunks: int = 1, keep_last: int = 3) -> str:
    """Write a checkpoint atomically. Returns the final directory."""
    final = os.path.join(root, f"ckpt_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    index: Dict[str, Dict] = {}
    for path, val in flat.items():
        arr = np.asarray(val)
        if arr.dtype.name == "bfloat16":  # npy-portable: store as u16 view
            arr = arr.view(np.uint16)
            logical = "bfloat16"
        else:
            logical = str(arr.dtype)
        safe = path.replace("/", ".")
        n = max(1, min(chunks, arr.shape[0] if arr.ndim else 1))
        parts = np.array_split(arr, n, axis=0) if arr.ndim else [arr]
        for i, part in enumerate(parts):
            np.save(os.path.join(tmp, f"{safe}.{i}.npy"), part)
        index[path] = {"dtype": logical, "shape": list(arr.shape),
                       "chunks": len(parts)}
    manifest = {"step": step, "index": index, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _trim(root, keep_last)
    return final


def _trim(root: str, keep_last: int) -> None:
    steps = sorted(all_steps(root))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(root, f"ckpt_{s:08d}"), ignore_errors=True)


def all_steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("ckpt_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore(root: str, step: Optional[int] = None, *,
            mesh: Optional[Mesh] = None, specs: Any = None,
            ) -> Tuple[Any, Dict]:
    """Load a checkpoint; optionally place leaves on ``mesh`` with
    ``specs`` (same pytree structure) — the elastic-rescale path: the mesh
    need not match the one the checkpoint was written from."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"ckpt_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, Any] = {}
    for path, info in manifest["index"].items():
        safe = path.replace("/", ".")
        parts = [np.load(os.path.join(d, f"{safe}.{i}.npy"))
                 for i in range(info["chunks"])]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        flat[path] = arr
    tree = _unflatten(flat)
    if mesh is not None and specs is not None:
        flat_specs = _flatten(jax.tree.map(
            lambda s: s, specs, is_leaf=lambda x: isinstance(x, P)))
        placed = {}
        for path, arr in flat.items():
            sp = flat_specs.get(path, P())
            placed[path] = jax.device_put(arr, NamedSharding(mesh, sp))
        tree = _unflatten(placed)
    return tree, manifest

from .ops import flip_update  # noqa: F401

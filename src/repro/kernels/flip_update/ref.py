"""Pure-jnp oracle for the fused flip + incremental true-count update.

Given one probSAT flip per chain (variable id, its new value, and the
pre-gathered occurrence row of that variable), apply the flip to the
assignment and bump the true count of every clause the variable occurs in:
+1 where the new value satisfies the literal, -1 where it un-satisfies it.
Integer-exact by construction — the walksat engines assert the carried
counts equal a fresh recount, so kernel and oracle must agree bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flip_update_ref(assign: jnp.ndarray, tc: jnp.ndarray,
                    v_flip: jnp.ndarray, occ_c: jnp.ndarray,
                    occ_s: jnp.ndarray, new_val: jnp.ndarray,
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """assign [K,B,V+1] bool; tc [K,B,C] int32; v_flip [K,B] int32
    (0 = dummy no-op var); occ_c [K,B,O] int32 clause ids (-1 = padding);
    occ_s [K,B,O] bool; new_val [K,B] bool. Returns (assign', tc')."""

    def one(a, t, vf, oc, os_, nv):
        a = a.at[jnp.arange(a.shape[0]), vf].set(nv)
        valid = oc >= 0
        delta = jnp.where(os_ == nv[:, None], 1, -1)
        delta = jnp.where(valid, delta, 0)
        t = t + jnp.zeros_like(t).at[
            jnp.arange(t.shape[0])[:, None], jnp.where(valid, oc, 0)
        ].add(delta)
        return a, t

    return jax.vmap(one)(assign, tc, v_flip, occ_c, occ_s, new_val)

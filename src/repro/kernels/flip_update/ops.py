"""jit'd public wrapper: padding + backend dispatch for flip_update."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..clause_eval.ops import resolve_interpret
from .kernel import flip_update_pallas
from .ref import flip_update_ref


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block_b", "block_c",
                                             "interpret"))
def flip_update(assign: jnp.ndarray, tc: jnp.ndarray, v_flip: jnp.ndarray,
                occ_c: jnp.ndarray, occ_s: jnp.ndarray,
                new_val: jnp.ndarray, *, block_b: int = 8,
                block_c: int = 256, interpret: bool | None = None,
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused flip + incremental true-count update over an II window.

    assign [K,B,V+1] bool; tc [K,B,C] int32; v_flip [K,B] int32 (0 = the
    dummy no-op var); occ_c [K,B,O] int32 clause ids of the flipped var
    (-1 = padding); occ_s [K,B,O] bool literal signs; new_val [K,B] bool.
    Returns (assign' bool, tc' int32). Compiled on TPU/GPU, interpret mode
    elsewhere (same policy as clause_eval).
    """
    interpret = resolve_interpret(interpret)
    k, b, v1 = assign.shape
    c = tc.shape[2]
    # shapes are static under jit, so this contract check runs at trace
    # time and survives `python -O` (a real raise, not an assert)
    leads = {"tc": tc.shape[:2], "v_flip": v_flip.shape[:2],
             "occ_c": occ_c.shape[:2], "occ_s": occ_s.shape[:2],
             "new_val": new_val.shape[:2]}
    bad = {n: s for n, s in leads.items() if tuple(s) != (k, b)}
    if bad or occ_c.shape != occ_s.shape:
        raise ValueError(f"flip_update: inputs must share leading [K,B]="
                         f"[{k},{b}] and occ_c/occ_s must match: "
                         f"mismatched {bad or {'occ_s': occ_s.shape}}")
    bp = _pad_to(max(b, 1), block_b)
    cp = _pad_to(max(c, 1), block_c)
    a8 = jnp.pad(assign.astype(jnp.int8), ((0, 0), (0, bp - b), (0, 0)))
    tcp = jnp.pad(tc, ((0, 0), (0, bp - b), (0, cp - c)))
    vf = jnp.pad(v_flip.astype(jnp.int32),
                 ((0, 0), (0, bp - b)))[..., None]
    # padded chain rows get occ_c == -1 so they touch no clause
    occ = jnp.pad(occ_c.astype(jnp.int32), ((0, 0), (0, bp - b), (0, 0)),
                  constant_values=-1)
    osn = jnp.pad(occ_s.astype(jnp.int8), ((0, 0), (0, bp - b), (0, 0)))
    nv = jnp.pad(new_val.astype(jnp.int8),
                 ((0, 0), (0, bp - b)))[..., None]
    a_out, tc_out = flip_update_pallas(a8, tcp, vf, occ, osn, nv,
                                       block_b=block_b, block_c=block_c,
                                       interpret=interpret)
    return a_out[:, :b].astype(bool), tc_out[:, :b, :c]


__all__ = ["flip_update", "flip_update_ref"]

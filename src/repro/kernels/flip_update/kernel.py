"""Pallas kernel: fused probSAT flip + incremental true-count update.

TPU/GPU have no efficient per-row scatter, so the occurrence-list update is
recast as a dense one-hot compare-accumulate: each grid cell owns a
[block_b, block_c] tile of the true-count matrix for one formula, rebases
the flipped variable's (pre-gathered) occurrence clause ids against the
tile origin, and accumulates ``sum_o onehot(rel_o) * delta_o`` — a
vectorized broadcast-compare-reduce the VPU handles natively. The
assignment flip itself is a one-hot select over the variable axis, emitted
once per (formula, chain-block) by the clause-tile-0 program.

Occurrence rows are tiny (Omax is bucketed to a few dozen for mapper
CNFs), so the [block_b, Omax, block_c] one-hot intermediate stays well
inside VMEM at the default tile sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flip_update_kernel(assign_ref, tc_ref, vflip_ref, occ_ref, osign_ref,
                        newval_ref, assign_out_ref, tc_out_ref):
    tc = tc_ref[0]                           # [bB, bC] int32
    oc = occ_ref[0]                          # [bB, O] int32, -1 = padding
    os_ = osign_ref[0]                       # [bB, O] int8
    nv = newval_ref[0]                       # [bB, 1] int8
    bb, bc = tc.shape
    o = oc.shape[1]
    cbase = pl.program_id(2) * bc
    rel = oc - cbase                         # [bB, O] tile-local clause ids
    valid = oc >= 0
    delta = jnp.where(os_ == nv, 1, -1) * valid.astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bb, o, bc), 2)
    onehot = (rel[:, :, None] == iota).astype(jnp.int32)
    tc_out_ref[0] = tc + jnp.sum(onehot * delta[:, :, None], axis=1)

    @pl.when(pl.program_id(2) == 0)
    def _flip_assign():
        a = assign_ref[0]                    # [bB, V+1] int8
        vf = vflip_ref[0]                    # [bB, 1] int32
        vidx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        assign_out_ref[0] = jnp.where(vidx == vf, nv, a)


def flip_update_pallas(assign: jnp.ndarray, tc: jnp.ndarray,
                       v_flip: jnp.ndarray, occ_c: jnp.ndarray,
                       occ_s: jnp.ndarray, new_val: jnp.ndarray, *,
                       block_b: int = 8, block_c: int = 256,
                       interpret: bool = False,
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """assign [K,B,V+1] int8; tc [K,B,C] int32; v_flip/new_val [K,B,1]
    int32/int8; occ_c/occ_s [K,B,O] int32/int8 (occ_c padded with -1,
    *including* any padded chain rows, so they update nothing).
    B % block_b == 0 and C % block_c == 0 (ops pads). Returns
    (assign' [K,B,V+1] int8, tc' [K,B,C] int32)."""
    k, b, v1 = assign.shape
    c = tc.shape[2]
    o = occ_c.shape[2]
    grid = (k, b // block_b, c // block_c)
    return pl.pallas_call(
        _flip_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b, v1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_b, block_c), lambda g, i, j: (g, i, j)),
            pl.BlockSpec((1, block_b, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_b, o), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_b, o), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_b, 1), lambda g, i, j: (g, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b, v1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_b, block_c), lambda g, i, j: (g, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, b, v1), jnp.int8),
            jax.ShapeDtypeStruct((k, b, c), jnp.int32),
        ],
        interpret=interpret,
    )(assign, tc, v_flip, occ_c, occ_s, new_val)

"""jit'd public wrapper for flash attention: padding + backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Flash attention, kernel layout [B, H, S, D]; pads S to block
    multiples and strips afterwards. GQA via Hq % Hkv == 0."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    sqp, skp = _pad_to(sq, block_q), _pad_to(sk, block_k)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
        sk_valid=sk, block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :, :sq, :]


__all__ = ["flash_attention", "attention_ref"]

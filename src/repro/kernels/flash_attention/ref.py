"""Pure-jnp oracle: full-materialization softmax attention.

Layout [B, H, S, D] (kernel layout). GQA by kv-head broadcast; causal and
sliding-window masks by absolute position.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0, q_offset: int = 0,
                  ) -> jnp.ndarray:
    """q: [B,Hq,Sq,D]; k,v: [B,Hkv,Sk,D]; Hq % Hkv == 0.
    q position i is absolute position q_offset + i; k position j is j."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(d)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)

"""Pallas TPU flash attention (GQA, causal, sliding window).

Grid (B, Hq, nQ, nK); the last dim is sequential ("arbitrary") — running
max / sum / accumulator live in VMEM scratch across the KV sweep, so HBM
traffic is O(S) per tile instead of O(S^2): the online-softmax rewrite of
the paper-agnostic attention bottleneck, tiled so q/k/v blocks are
MXU-aligned (block sizes multiples of 128 on the matmul dims).

GQA is handled in the k/v index_map (q head h reads kv head h // group) —
no repeated K/V materialization in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_NEG = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  sk_valid: int, block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
    kpos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    # block-level skip: nothing to do if every (q, k) pair is masked
    needed = jnp.asarray(True)
    if causal:
        needed &= kpos[0] <= qpos[-1]
    if window:
        needed &= kpos[-1] > qpos[0] - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [bQ, D]
        k = k_ref[0, 0].astype(jnp.float32)              # [bK, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        mask = kpos[None, :] < sk_valid
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, q_offset=0,
                           sk_valid=None, block_q=128, block_k=128,
                           interpret=False):
    """q: [B,Hq,Sq,D] (Sq % block_q == 0); k,v: [B,Hkv,Sk,D]
    (Sk % block_k == 0). sk_valid masks padded KV tail. -> [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    n_k = sk // block_k
    if sk_valid is None:
        sk_valid = sk
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal,
        window=window, q_offset=q_offset, sk_valid=sk_valid,
        block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running sum
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)

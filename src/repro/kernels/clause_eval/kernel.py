"""Pallas kernel: batched clause true-count evaluation.

Accelerator adaptation of the WalkSAT inner loop: the whole assignment
vector for a block of chains lives in VMEM/shared memory (V bits is tiny —
a 100k-var instance is 100KB as int8), the clause-literal table streams
through in [block_c, Lmax] tiles, and each grid cell evaluates a
[block_b x block_c] tile of the (chain, clause) matrix with a vectorized
gather. Grid dims are fully parallel — clause tiles are independent. The
same kernel body lowers via Mosaic on TPU and Triton on GPU; the window
variant adds a leading CNF grid axis for the II-sweep's stacked formulas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _clause_eval_kernel(assign_ref, cvars_ref, csign_ref, out_ref):
    a = assign_ref[...]                      # [bB, V+1] int8
    cv = cvars_ref[...]                      # [bC, L] int32
    cs = csign_ref[...]                      # [bC, L] int8
    bb = a.shape[0]
    bc, ll = cv.shape
    flat = cv.reshape(-1)                    # [bC*L]
    vals = jnp.take(a, flat, axis=1).reshape(bb, bc, ll)
    sat = (vals == cs[None]) & (cv[None] > 0)
    out_ref[...] = jnp.sum(sat, axis=-1, dtype=jnp.int32)


def clause_eval_pallas(assign: jnp.ndarray, cvars: jnp.ndarray,
                       csign: jnp.ndarray, *, block_b: int = 8,
                       block_c: int = 1024, interpret: bool = False,
                       ) -> jnp.ndarray:
    """assign: [B, V+1] int8 (0/1); cvars: [C, L] int32; csign: [C, L] int8.
    Returns tc [B, C] int32. B % block_b == 0 and C % block_c == 0
    (ops.true_counts pads)."""
    b, v1 = assign.shape
    c, l = cvars.shape
    grid = (b // block_b, c // block_c)
    return pl.pallas_call(
        _clause_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, v1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, l), lambda i, j: (j, 0)),
            pl.BlockSpec((block_c, l), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(assign, cvars, csign)


def _clause_eval_window_kernel(assign_ref, cvars_ref, csign_ref, out_ref):
    a = assign_ref[0]                        # [bB, V+1] int8
    cv = cvars_ref[0]                        # [bC, L] int32
    cs = csign_ref[0]                        # [bC, L] int8
    bb = a.shape[0]
    bc, ll = cv.shape
    flat = cv.reshape(-1)
    vals = jnp.take(a, flat, axis=1).reshape(bb, bc, ll)
    sat = (vals == cs[None]) & (cv[None] > 0)
    out_ref[0] = jnp.sum(sat, axis=-1, dtype=jnp.int32)


def clause_eval_window_pallas(assign: jnp.ndarray, cvars: jnp.ndarray,
                              csign: jnp.ndarray, *, block_b: int = 8,
                              block_c: int = 1024, interpret: bool = False,
                              ) -> jnp.ndarray:
    """Window variant for the II sweep's stacked formulas: assign
    [K, B, V+1] int8; cvars/csign [K, C, L]. Returns tc [K, B, C] int32.
    The CNF axis K is a leading (fully parallel) grid dimension — each grid
    cell sees one formula's clause tile against one batch tile of its
    chains. B % block_b == 0 and C % block_c == 0 (ops pads)."""
    k, b, v1 = assign.shape
    _, c, l = cvars.shape
    grid = (k, b // block_b, c // block_c)
    return pl.pallas_call(
        _clause_eval_window_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b, v1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_c, l), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_c, l), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_c),
                               lambda g, i, j: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((k, b, c), jnp.int32),
        interpret=interpret,
    )(assign, cvars, csign)

"""Pure-jnp oracle for clause evaluation.

tc[b, c] = number of literals of clause c satisfied by assignment b.
A clause is UNSAT under the assignment iff tc == 0 — the quantity the
WalkSAT portfolio evaluates for every chain every step (the mapper's
accelerator hot spot).
"""
from __future__ import annotations

import jax.numpy as jnp


def true_counts_ref(cvars: jnp.ndarray, csign: jnp.ndarray,
                    assign: jnp.ndarray) -> jnp.ndarray:
    """cvars: [C, L] int32 (1-based var ids, 0 = padding);
    csign: [C, L] bool; assign: [B, V+1] bool. Returns [B, C] int32."""
    mask = cvars > 0                                   # [C, L]
    vals = assign[:, cvars]                            # [B, C, L]
    sat = jnp.where(mask[None], vals == csign[None], False)
    return jnp.sum(sat, axis=-1).astype(jnp.int32)


def true_counts_window_ref(cvars: jnp.ndarray, csign: jnp.ndarray,
                           assign: jnp.ndarray) -> jnp.ndarray:
    """Window oracle: cvars/csign [K, C, L]; assign [K, B, V+1] bool.
    Returns [K, B, C] int32 — one formula per leading index."""
    import jax
    return jax.vmap(true_counts_ref)(cvars, csign, assign)

"""jit'd public wrapper: padding + backend dispatch for clause_eval."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import clause_eval_pallas
from .ref import true_counts_ref


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block_b", "block_c",
                                             "interpret"))
def true_counts(cvars: jnp.ndarray, csign: jnp.ndarray, assign: jnp.ndarray,
                *, block_b: int = 8, block_c: int = 1024,
                interpret: bool | None = None) -> jnp.ndarray:
    """Batched per-clause true counts. cvars [C,L] int32 (0-padded, 1-based);
    csign [C,L] bool; assign [B,V+1] bool -> [B,C] int32.

    On non-TPU backends the kernel runs in interpret mode (same code path,
    Python evaluation) unless ``interpret=False`` forces compilation.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, v1 = assign.shape
    c, l = cvars.shape
    bp = _pad_to(max(b, 1), block_b)
    cp = _pad_to(max(c, 1), block_c)
    a8 = jnp.pad(assign.astype(jnp.int8), ((0, bp - b), (0, 0)))
    cv = jnp.pad(cvars, ((0, cp - c), (0, 0)))
    cs = jnp.pad(csign.astype(jnp.int8), ((0, cp - c), (0, 0)))
    tc = clause_eval_pallas(a8, cv, cs, block_b=block_b, block_c=block_c,
                            interpret=interpret)
    return tc[:b, :c]


__all__ = ["true_counts", "true_counts_ref"]

"""jit'd public wrappers: padding + backend dispatch for clause_eval."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .kernel import clause_eval_pallas, clause_eval_window_pallas
from .ref import true_counts_ref, true_counts_window_ref


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def resolve_interpret(interpret: bool | None) -> bool:
    """Interpret-vs-compiled policy shared by the SAT kernels.

    Compiled by default on TPU (Mosaic) *and* GPU (Triton); interpret mode
    — same kernel body, Python evaluation — everywhere else, since Pallas
    has no CPU lowering. ``REPRO_PALLAS_INTERPRET=1/0`` overrides (CI uses
    it to force interpret-mode coverage on CPU runners and compiled mode
    where an accelerator is present); an explicit ``interpret=`` argument
    wins over everything.
    """
    if interpret is not None:
        return interpret
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    return jax.default_backend() not in ("tpu", "gpu")


@functools.partial(jax.jit, static_argnames=("block_b", "block_c",
                                             "interpret"))
def true_counts(cvars: jnp.ndarray, csign: jnp.ndarray, assign: jnp.ndarray,
                *, block_b: int = 8, block_c: int = 1024,
                interpret: bool | None = None) -> jnp.ndarray:
    """Batched per-clause true counts. cvars [C,L] int32 (0-padded, 1-based);
    csign [C,L] bool; assign [B,V+1] bool -> [B,C] int32.

    Compiled on TPU/GPU, interpret mode elsewhere (see
    :func:`resolve_interpret`); ``interpret=False`` forces compilation.
    """
    interpret = resolve_interpret(interpret)
    b, v1 = assign.shape
    c, l = cvars.shape
    bp = _pad_to(max(b, 1), block_b)
    cp = _pad_to(max(c, 1), block_c)
    a8 = jnp.pad(assign.astype(jnp.int8), ((0, bp - b), (0, 0)))
    cv = jnp.pad(cvars, ((0, cp - c), (0, 0)))
    cs = jnp.pad(csign.astype(jnp.int8), ((0, cp - c), (0, 0)))
    tc = clause_eval_pallas(a8, cv, cs, block_b=block_b, block_c=block_c,
                            interpret=interpret)
    return tc[:b, :c]


@functools.partial(jax.jit, static_argnames=("block_b", "block_c",
                                             "interpret"))
def true_counts_window(cvars: jnp.ndarray, csign: jnp.ndarray,
                       assign: jnp.ndarray, *, block_b: int = 8,
                       block_c: int = 1024,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Window variant: cvars [K,C,L] int32; csign [K,C,L] bool; assign
    [K,B,V+1] bool -> [K,B,C] int32. The sweep's padded window tensors are
    already bucketed, but arbitrary shapes are padded here too so the tests
    can drive odd sizes."""
    interpret = resolve_interpret(interpret)
    k, b, v1 = assign.shape
    _, c, l = cvars.shape
    bp = _pad_to(max(b, 1), block_b)
    cp = _pad_to(max(c, 1), block_c)
    a8 = jnp.pad(assign.astype(jnp.int8), ((0, 0), (0, bp - b), (0, 0)))
    cv = jnp.pad(cvars, ((0, 0), (0, cp - c), (0, 0)))
    cs = jnp.pad(csign.astype(jnp.int8), ((0, 0), (0, cp - c), (0, 0)))
    tc = clause_eval_window_pallas(a8, cv, cs, block_b=block_b,
                                   block_c=block_c, interpret=interpret)
    return tc[:, :b, :c]


__all__ = ["true_counts", "true_counts_window", "true_counts_ref",
           "true_counts_window_ref", "resolve_interpret"]

from .ops import true_counts  # noqa: F401

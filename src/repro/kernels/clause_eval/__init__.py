from .ops import resolve_interpret, true_counts, true_counts_window  # noqa: F401

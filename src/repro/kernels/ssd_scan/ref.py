"""Sequential-recurrence oracle for the Mamba2 SSD scan.

The strongest possible reference: the literal per-step recurrence

    S_t = exp(dt_t * A) * S_{t-1} + dt_t * B_t x_t^T
    y_t = C_t^T S_t + D * x_t

It independently validates BOTH the Pallas chunked kernel and the jnp
chunked dual form in repro.models.layers.ssd_chunked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray) -> jnp.ndarray:
    """x: [b,s,h,p]; dt: [b,s,h] (already softplus-ed); A_log: [h];
    B, C: [b,s,n]; D: [h]. Returns y: [b,s,h,p] (float32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                    # [b,h,p], [b,h], [b,n], [b,n]
        dA = jnp.exp(dtt * A[None, :])           # [b,h]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    xs = (x.astype(jnp.float32).swapaxes(0, 1),
          dt.astype(jnp.float32).swapaxes(0, 1),
          B.astype(jnp.float32).swapaxes(0, 1),
          C.astype(jnp.float32).swapaxes(0, 1))
    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init, xs)
    y = ys.swapaxes(0, 1)                        # [b,s,h,p]
    return y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]

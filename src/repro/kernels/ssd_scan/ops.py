"""jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_pallas
from .ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A_log, B, C, D, *, chunk: int = 128,
             interpret: bool | None = None):
    """Chunked SSD scan. x: [b,s,h,p]; dt: [b,s,h] (post-softplus);
    A_log: [h]; B, C: [b,s,n]; D: [h]. Pads s to a chunk multiple
    (dt=0 padding is a no-op for both state and output)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, p = x.shape
    sp = ((s + chunk - 1) // chunk) * chunk
    pad = sp - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_pallas(x, dt, A_log, B, C, D, chunk=chunk,
                        interpret=interpret)
    return y[:, :s]


__all__ = ["ssd_scan", "ssd_ref"]

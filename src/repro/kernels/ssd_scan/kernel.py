"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid (B, H, nChunks); the chunk dim is sequential ("arbitrary") and carries
the [P, N] inter-chunk state in VMEM scratch — the HBM-resident state
tensor of a naive scan never exists. Within a chunk the dual (quadratic)
form runs on the MXU: chunk x chunk decay matrix, [chunk, N] x [N, chunk]
contraction — all VMEM-resident with chunk=128..256, P,N <= 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [l, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [l]
    A = -jnp.exp(a_ref[0].astype(jnp.float32))         # scalar
    Bm = b_ref[0].astype(jnp.float32)                  # [l, N]
    Cm = c_ref[0].astype(jnp.float32)                  # [l, N]
    D = d_ref[0].astype(jnp.float32)

    dA = dt * A                                        # [l]
    seg = jnp.cumsum(dA)                               # [l]
    # intra-chunk: y_diag[l] = sum_{m<=l} exp(seg_l - seg_m) dt_m (C_l.B_m) x_m
    rel = seg[:, None] - seg[None, :]                  # [l, l]
    causal = jax.lax.iota(jnp.int32, chunk)[:, None] >= \
        jax.lax.iota(jnp.int32, chunk)[None, :]
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # [l, l]
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))      # [l, P]
    # carried-state contribution: C_l . (exp(seg_l) * S_prev)
    state = state_ref[...]                             # [P, N]
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())))           # [l, P]
    y += x * D
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update: S = exp(seg_last) S_prev + sum_l exp(seg_last-seg_l) dt_l x_l B_l^T
    w2 = (jnp.exp(seg[-1] - seg) * dt)[:, None] * x    # [l, P]
    state_new = jnp.exp(seg[-1]) * state + jax.lax.dot_general(
        w2, Bm, (((0,), (0,)), ((), ())))              # [P, N]
    state_ref[...] = state_new


def ssd_scan_pallas(x, dt, A_log, B, C, D, *, chunk: int = 128,
                    interpret: bool = False):
    """x: [b,s,h,p]; dt: [b,s,h]; A_log: [h]; B,C: [b,s,n]; D: [h].
    s % chunk == 0. Returns y [b,s,h,p] (x.dtype)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A_log, B, C, D)

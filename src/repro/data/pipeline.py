"""Deterministic, coordination-free synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) via counter-based
PRNG: a restarted or replaced host regenerates exactly its shard for any
step without talking to anyone — the data-side half of straggler/failure
tolerance. Resume state is a single integer cursor (the step), stored in
the checkpoint manifest.

For real corpora the same contract holds by construction when the reader
is (seed, step, shard) -> record ids (e.g. modulo-indexed shuffles); this
module implements the synthetic instantiation used by examples and tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128


class SyntheticLM:
    """Zipf-ish token stream with enough structure for loss to fall."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.vocab = model_cfg.vocab
        self.model_cfg = model_cfg

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1,
                 ) -> Dict[str, np.ndarray]:
        """The shard's slice of the global batch for ``step``. Stateless."""
        assert self.cfg.global_batch % n_shards == 0
        per = self.cfg.global_batch // n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step), shard)
        s = self.cfg.seq_len
        # structured stream: token_{t+1} depends on token_t (learnable)
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(k1, (per, 1), 0, self.vocab)
        steps = jax.random.randint(k2, (per, s), 0, 17)
        toks = (base + jnp.cumsum(steps, axis=1)) % self.vocab
        tokens = np.asarray(toks, np.int32)
        inputs = tokens[:, :-1] if s > 1 else tokens
        labels = tokens[:, 1:] if s > 1 else tokens
        out: Dict[str, np.ndarray] = {"labels": labels}
        fe = self.model_cfg.frontend
        if fe == "audio_frames":
            kf = jax.random.fold_in(key, 7)
            out["embeds"] = np.asarray(jax.random.normal(
                kf, (per, labels.shape[1], self.model_cfg.d_model)),
                np.float32)
        elif fe == "vision_patches":
            kf = jax.random.fold_in(key, 7)
            fl = self.model_cfg.frontend_len
            out["embeds"] = np.asarray(jax.random.normal(
                kf, (per, fl, self.model_cfg.d_model)), np.float32)
            out["tokens"] = inputs
        else:
            out["tokens"] = inputs
        return out

    def iterate(self, start_step: int = 0, shard: int = 0, n_shards: int = 1,
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, shard, n_shards)
            step += 1

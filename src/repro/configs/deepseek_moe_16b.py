"""DeepSeekMoE 16B: fine-grained experts, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16, MHA) per-expert d_ff=1408 vocab=102400.
[arXiv:2401.06066; hf]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
)

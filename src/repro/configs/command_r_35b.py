"""Command-R 35B: dense GQA, no biases.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    rope_theta=4000000.0,
)

"""Llama-4 Maverick 400B-A17B backbone (MoE, early fusion).

48L d_model=5120 40H (GQA kv=8, head_dim=128) expert d_ff=8192
vocab=202048, 128 routed experts top-1 + 1 shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    n_shared_experts=1,
    top_k=1,
    rope_theta=500000.0,
    # expert weights alone are ~1.5 TB bf16: pure EP leaves 96 GiB/chip on
    # 256 chips — FSDP-shard them over the data axes as well (Perf It. 8)
    fsdp_experts=True,
)

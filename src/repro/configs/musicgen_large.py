"""MusicGen-large backbone: decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32, MHA, head_dim=64) d_ff=8192 vocab=2048.
The EnCodec frontend is a stub: input_specs provides precomputed frame
embeddings [B,S,d_model]; targets are codebook token ids.
[arXiv:2306.05284; hf]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    frontend="audio_frames",
)

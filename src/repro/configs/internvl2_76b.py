"""InternVL2-76B backbone: InternViT (stub) + LLM decoder.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The vision
frontend is a stub: input_specs provides 256 precomputed patch embeddings
prepended to the text sequence. [arXiv:2404.16821; unverified]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    frontend="vision_patches",
    frontend_len=256,
)

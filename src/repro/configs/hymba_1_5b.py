"""Hymba-1.5B: hybrid — parallel attention + mamba heads in every block.

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
ssm_state=16. SSM branch: 32 heads x 100 = 3200 = 2*d_model inner width.
Sliding-window attention (1024) everywhere; the published model keeps 3
global-attention layers — we use uniform SWA so the layer stack stays
scan-homogeneous (noted in DESIGN.md). [arXiv:2411.13676; hf]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_heads=32,
    ssm_head_dim=100,
    attn_window=1024,
)

"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_config(name).smoke()`` the reduced same-family config used by CPU
smoke tests. ``ARCHS`` lists all assigned ids.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "llama4_maverick_400b_a17b",
    "deepseek_moe_16b",
    "yi_34b",
    "qwen1_5_32b",
    "command_r_35b",
    "minitron_8b",
    "hymba_1_5b",
    "musicgen_large",
    "internvl2_76b",
    "mamba2_370m",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return name


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}

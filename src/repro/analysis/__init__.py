"""Static analysis for the mapper stack: `python -m repro.analysis`.

Two engines, one gate:

* :mod:`repro.analysis.cnf_audit` — a vectorised numpy auditor for the
  emitted SAT encodings: duplicate / subsumed / tautological clauses,
  dead or out-of-range variables, AMO-family completeness and overlap,
  and per-family clause counts cross-checked against closed-form
  formulas re-derived from the KMS windows (an independent model of the
  encoder, not a call back into it).
* :mod:`repro.analysis.lint` — an AST / import-graph rule engine for the
  repo's load-bearing invariants: fork-clean worker imports,
  ``python -O`` assert safety, ``PYTHONHASHSEED``-independent canonical
  keys, and Pallas kernel constraints. Legacy violations live in a
  checked-in baseline file; anything new fails the gate.

CLI: ``python -m repro.analysis --check`` (lint gate),
``--audit`` (33-cell suite encoding audit), ``--write-baseline``.
"""
from .cnf_audit import (AuditError, AuditReport, Finding, audit_encoding,
                        audit_projection, audit_suite)
from .lint import LintConfig, LintFinding, load_baseline, run_lint

__all__ = [
    "AuditError", "AuditReport", "Finding", "audit_encoding",
    "audit_projection", "audit_suite",
    "LintConfig", "LintFinding", "load_baseline", "run_lint",
]

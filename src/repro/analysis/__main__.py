"""CLI: ``python -m repro.analysis [--check] [--audit] ...``.

Modes (combinable; with no mode flags, ``--check`` is implied):

* ``--check``          run the repo-invariant linter; exit nonzero on any
                       finding not in the baseline file.
* ``--audit``          run the CNF encoding auditor over the suite cells
                       (cold + incremental projections); exit nonzero on
                       any unsuppressed finding.  ``--quick`` audits a
                       4-kernel subset; default is all 11 kernels x 3
                       fabrics (33 cells, ~4 s).
* ``--write-baseline`` rewrite the lint baseline from current findings.

Options: ``--root DIR`` lints a different tree (used by the fixture
tests), ``--baseline PATH`` overrides the suppression file,
``--rules a,b`` restricts the rule set, ``--report PATH`` writes the
audit report JSON (the CI artifact), ``--emitters``/``--amo`` select
encoder modes for the audit.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from .lint import LintConfig, load_baseline, run_lint, write_baseline

_QUICK_NAMES = ("sha", "nw", "srand", "hotspot")


def _do_check(args: argparse.Namespace) -> int:
    cfg = LintConfig(root=Path(args.root),
                     baseline_path=(Path(args.baseline)
                                    if args.baseline else None),
                     rules=(args.rules.split(",") if args.rules else None))
    findings = run_lint(cfg)
    if args.write_baseline:
        path = cfg.baseline_path or (cfg.root / "src" / "repro"
                                     / "analysis" / "lint_baseline.txt")
        write_baseline(path, findings)
        print(f"lint: wrote {len(findings)} fingerprint(s) to {path}")
        return 0
    baseline = load_baseline(cfg.baseline_path)
    fresh = [f for f in findings if f.fingerprint not in baseline]
    stale = baseline - {f.fingerprint for f in findings}
    for f in fresh:
        print(f.render())
    if stale:
        print(f"lint: note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (fixed or moved):")
        for fp in sorted(stale):
            print(f"    {fp}")
    n_base = len(findings) - len(fresh)
    print(f"lint: {len(findings)} finding(s), {n_base} baselined, "
          f"{len(fresh)} new -> {'FAIL' if fresh else 'OK'}")
    return 1 if fresh else 0


def _do_audit(args: argparse.Namespace) -> int:
    # late import: the auditor pulls in the encoder stack (numpy etc.),
    # which a lint-only invocation should not need.
    from .cnf_audit import audit_suite, reports_to_json

    names = list(_QUICK_NAMES) if args.quick else None
    progress = (lambda r: print(r.summary())) if args.verbose else None
    t0 = time.perf_counter()
    reports = audit_suite(names=names, amo=args.amo,
                          emitters=args.emitters, progress=progress)
    dt = time.perf_counter() - t0
    payload = reports_to_json(reports)
    if args.report:
        Path(args.report).write_text(json.dumps(payload, indent=1,
                                                sort_keys=True))
        print(f"audit: report written to {args.report}")
    bad = [r for r in reports if not r.ok()]
    for r in bad:
        print(r.summary())
    print(f"audit: {len(reports)} report(s) over {len(payload['cells'])} "
          f"cell(s), {payload['n_suppressed']} suppressed, "
          f"{payload['n_unsuppressed']} unsuppressed "
          f"({dt:.1f}s) -> {'FAIL' if bad else 'OK'}")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__.split("\n")[0])
    ap.add_argument("--check", action="store_true",
                    help="run the repo-invariant linter gate")
    ap.add_argument("--audit", action="store_true",
                    help="run the CNF encoding auditor over the suite")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the lint baseline from current findings")
    ap.add_argument("--root", default=".",
                    help="tree to lint (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="lint suppression file override")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset")
    ap.add_argument("--quick", action="store_true",
                    help="audit a 4-kernel subset instead of all 11")
    ap.add_argument("--report", default=None,
                    help="write the audit report JSON here")
    ap.add_argument("--emitters", default="vector",
                    choices=("vector", "legacy"))
    ap.add_argument("--amo", default="pairwise",
                    choices=("pairwise", "sequential"))
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if not (args.check or args.audit or args.write_baseline):
        args.check = True
    rc = 0
    if args.check or args.write_baseline:
        rc |= _do_check(args)
    if args.audit:
        rc |= _do_audit(args)
    return rc


if __name__ == "__main__":
    sys.exit(main())

"""Static auditor for the emitted SAT encodings (`repro.analysis`).

Independently re-derives the variable layout and the closed-form clause
counts of every family (C1 / C2 / C2W / C3) from the *inputs* of an
:class:`~repro.core.encode.EncoderSession` — the ASAP/ALAP windows, the
allowed-PE sets, the per-node latencies, and the fabric's reachability —
then cross-checks the actual clause stream (via
``ClauseArena.padded_rows()`` and the family ranges recorded in
``Encoding.families`` / ``IncrementalEncoding.projection_families``)
against that model with whole-array numpy passes:

* per-family clause counts vs the closed forms (pairwise ``C(k,2)``,
  Sinz ``3k-4``, fold classes, the per-edge ``ntd * |PEs(dst)|`` C3 rows);
* AMO completeness and overlap: the multiset of emitted ``(¬u, ¬w)``
  pairs must equal the model's pair multiset per family (pairwise mode);
* C3 row alignment: head literal, row length ``1 + ntim*npsel``, and the
  closed-form support sum, row by row in emission order;
* tautological rows, duplicate rows, subsumed rows, and dead variables —
  each detected globally and compared against the *expected* benign
  classes below; a finding is suppressed only when the observed rows
  match the model's prediction exactly (set- or count-exact).

Known benign redundancy classes (suppressed when exact):

* ``dup:c1*c2`` — a pairwise C1 pair of one node duplicates a C2 fold
  pair when the node occupies one PE at two times ``t1 ≡ t2 (mod II)``;
* ``dup:c2*c2w`` — a write-port pair duplicates a C2 fold pair when the
  two completion times *and* the two issue times fold together;
* ``dup:c2s*c2`` — sequential-AMO incremental layers re-encode small
  folded groups pairwise, duplicating the base within-slot skeleton;
* ``dup:c3`` — parallel DFG edges whose clamped windows coincide;
* ``taut:c3-self`` — a self-edge row is tautological when its window
  contains 0 (the head variable supports itself; accumulators);
* ``subsume:unit-alo`` / ``subsume:unit-c3`` — a single-candidate node's
  unit ALO (or an empty-support C3 unit) subsumes longer rows that
  contain its literal;
* ``subsume:c3-full`` — a C3 row whose support covers the producer's
  whole candidate set is subsumed by that producer's ALO;
* ``dead:projection`` — in ``IncrementalEncoding.project(ii)`` the
  selector variables and other layers' aux variables occur in no clause
  (by construction; checked against ``layer_var_ranges()``).

Scope note: subsumption is checked for the classes that can structurally
arise in this encoding — units vs longer rows, and ALO ⊆ C3 row. Binary
AMO clauses cannot subsume anything but each other (that is the
duplicate check): C3 rows carry exactly one negative literal and ALO
rows none. Sinz (sequential-AMO) groups are count- and shape-checked
only; their pair content involves ladder aux variables and is covered by
the legacy-vs-vector bit-parity property tests instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.encode import Encoding, EncoderSession, IncrementalEncoding

_PAIRWISE_LIMIT = 4   # at_most_one's pairwise fallback threshold


class AuditError(RuntimeError):
    """The encoding lacks audit metadata or is structurally unanalysable
    (missing/overlapping family ranges, literals out of range). Distinct
    from a :class:`Finding`: findings describe the *formula*, an
    AuditError means the auditor itself cannot proceed."""


@dataclass
class Finding:
    code: str            # e.g. "dup:c1*c2", "family-count:c3"
    family: str          # family the finding anchors to ("*" = global)
    count: int           # rows / pairs / variables involved
    suppressed: bool     # True = known-benign class, matched exactly
    detail: str = ""

    def to_dict(self) -> Dict[str, object]:
        return {"code": self.code, "family": self.family,
                "count": self.count, "suppressed": self.suppressed,
                "detail": self.detail}


@dataclass
class AuditReport:
    cell: str            # "<kernel>/<fabric>"
    mode: str            # "cold" | "projection"
    ii: int
    n_vars: int
    n_clauses: int
    family_counts: Dict[str, Tuple[int, int]]   # fam -> (actual, expected)
    findings: List[Finding] = field(default_factory=list)

    def ok(self) -> bool:
        return not any(not f.suppressed for f in self.findings)

    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    def to_dict(self) -> Dict[str, object]:
        return {"cell": self.cell, "mode": self.mode, "ii": self.ii,
                "n_vars": self.n_vars, "n_clauses": self.n_clauses,
                "ok": self.ok(),
                "family_counts": {k: list(v)
                                  for k, v in self.family_counts.items()},
                "findings": [f.to_dict() for f in self.findings]}

    def summary(self) -> str:
        fams = " ".join(f"{k}={a}" + ("" if a == e else f"!={e}")
                        for k, (a, e) in self.family_counts.items())
        sup = sum(f.count for f in self.findings if f.suppressed)
        bad = self.unsuppressed()
        tail = (f" UNSUPPRESSED {[f.code for f in bad]}" if bad
                else f" suppressed={sup}")
        return (f"{self.cell} [{self.mode} ii={self.ii}] "
                f"{self.n_clauses}cl {fams}{tail}")


def _comb2(m: np.ndarray) -> np.ndarray:
    m = np.asarray(m, dtype=np.int64)
    return m * (m - 1) // 2


def _group_sizes(keys: np.ndarray) -> np.ndarray:
    """Sizes of the equal-key classes of ``keys``."""
    if keys.size == 0:
        return np.zeros(0, dtype=np.int64)
    _, counts = np.unique(keys, return_counts=True)
    return counts.astype(np.int64)


class _Model:
    """Independent re-derivation of the variable layout and of every
    closed-form family property for one (session, ii). Built from the
    session's *window/PE inputs* only — never from ``_Layout`` internals
    or the emitted arena."""

    def __init__(self, session: EncoderSession, ii: int):
        self.session = session
        self.ii = int(ii)
        self.amo = session.amo
        dfg, cgra = session.dfg, session.cgra
        # ---------------------------------------------- variable layout
        base0: Dict[int, int] = {}
        kvars: Dict[int, int] = {}
        v_node: List[np.ndarray] = []
        v_pe: List[np.ndarray] = []
        v_t: List[np.ndarray] = []
        v_lat: List[np.ndarray] = []
        top = 0
        for nid in dfg.nodes:
            a, b = session.asap[nid], session.alap[nid]
            pes = session.allowed_pes[nid]
            nt, npn = b - a + 1, len(pes)
            base0[nid] = top
            kvars[nid] = nt * npn
            top += nt * npn
            if npn:
                v_node.append(np.full(nt * npn, nid, dtype=np.int64))
                v_pe.append(np.tile(np.asarray(pes, np.int64), nt))
                v_t.append(np.repeat(np.arange(a, b + 1, dtype=np.int64),
                                     npn))
                v_lat.append(np.full(nt * npn, session.lat[nid],
                                     dtype=np.int64))
        empty = np.zeros(0, dtype=np.int64)
        self.base0, self.kvars = base0, kvars
        self.n_layout = top
        self.v_node = np.concatenate(v_node) if v_node else empty
        self.v_pe = np.concatenate(v_pe) if v_pe else empty
        self.v_t = np.concatenate(v_t) if v_t else empty
        self.v_lat = np.concatenate(v_lat) if v_lat else empty
        self.mixed_lat = len(set(session.lat.values())) > 1
        # node AMO emitted pairwise? (pairwise mode, or Sinz fallback)
        self.c1_pairwise = {n: self.amo == "pairwise"
                            or kvars[n] <= _PAIRWISE_LIMIT
                            for n in dfg.nodes}
        self.c1_aux = sum(kvars[n] - 1 for n in dfg.nodes
                          if not self.c1_pairwise[n] and kvars[n] > 1)
        # ------------------------------------------------- fold classes
        # issue-slot classes (C2): key = (pe, t % ii); slot classes
        # (incremental base C2S): key = (pe, t)
        nv = self.v_pe.size
        t_max = int(self.v_t.max()) + 1 if nv else 1
        self.issue_key = self.v_pe * ii + self.v_t % ii
        self.slot_key = self.v_pe * t_max + self.v_t
        uk, self.issue_inv, self.issue_counts = np.unique(
            self.issue_key, return_inverse=True, return_counts=True)
        self.issue_m = self.issue_counts[self.issue_inv] if nv else empty
        # distinct slot keys per issue class (sequential incremental:
        # single-slot folded groups are skipped entirely)
        if nv:
            slot_u, slot_first = np.unique(self.slot_key,
                                           return_index=True)
            cls_of_slot = self.issue_inv[slot_first]
            self.issue_nslots = np.bincount(cls_of_slot,
                                            minlength=uk.size)
        else:
            self.issue_nslots = empty
        # C2 class emitted pairwise? (vector mode is pairwise-only; the
        # legacy sequential path falls back to pairwise for m <= 4)
        self.c2_class_pairwise = (self.amo == "pairwise") | \
            (self.issue_counts <= _PAIRWISE_LIMIT)
        # ------------------------------------------------- C3 row model
        self._build_c3_rows()

    # ------------------------------------------------------------ C3 rows
    def _build_c3_rows(self) -> None:
        s, ii = self.session, self.ii
        cgra = s.cgra
        reach = [frozenset(ps for ps in range(cgra.n_pes)
                           if cgra.reachable(ps, pd))
                 for pd in range(cgra.n_pes)]
        cols = {k: [] for k in ("src", "dst", "td", "head", "ts0", "ntim",
                                "npsel", "selstart", "const", "ps",
                                "selfedge")}
        sel_parts: List[np.ndarray] = []
        sel_top = 0
        for src, dst, delta in s.dfg.edges():
            p_d, p_s = len(s.allowed_pes[dst]), len(s.allowed_pes[src])
            if p_d == 0:
                continue
            a_s, b_s = s.asap[src], s.alap[src]
            a_d, b_d = s.asap[dst], s.alap[dst]
            lat_s = s.lat[src]
            lo = lat_s - delta * ii
            hi = (1 - delta) * ii + lat_s - 1
            src_pes = s.allowed_pes[src]
            sels = [np.asarray([i for i, ps in enumerate(src_pes)
                                if ps in reach[pd]], dtype=np.int64)
                    for pd in s.allowed_pes[dst]]
            npsel = np.asarray([x.size for x in sels], dtype=np.int64)
            selstart = sel_top + np.cumsum(npsel) - npsel
            sel_parts.extend(sels)
            sel_top += int(npsel.sum())
            ntd = b_d - a_d + 1
            td = np.repeat(np.arange(a_d, b_d + 1, dtype=np.int64), p_d)
            n_rows = ntd * p_d
            ts0 = np.maximum(a_s, td - hi)
            ntim = np.maximum(np.minimum(b_s, td - lo) - ts0 + 1, 0)
            cols["src"].append(np.full(n_rows, src, dtype=np.int64))
            cols["dst"].append(np.full(n_rows, dst, dtype=np.int64))
            cols["td"].append(td)
            cols["head"].append(
                self.base0[dst] + 1 + (td - a_d) * p_d
                + np.tile(np.arange(p_d, dtype=np.int64), ntd))
            cols["ts0"].append(ts0)
            cols["ntim"].append(ntim)
            cols["npsel"].append(np.tile(npsel, ntd))
            cols["selstart"].append(np.tile(selstart, ntd))
            cols["const"].append(
                np.full(n_rows, self.base0[src] + 1 - a_s * p_s,
                        dtype=np.int64))
            cols["ps"].append(np.full(n_rows, p_s, dtype=np.int64))
            cols["selfedge"].append(
                np.full(n_rows, src == dst and lo <= 0 <= hi, dtype=bool))
        empty = np.zeros(0, dtype=np.int64)

        def cat(key):
            return (np.concatenate(cols[key]) if cols[key]
                    else (np.zeros(0, bool) if key == "selfedge"
                          else empty))

        self.r_src, self.r_dst = cat("src"), cat("dst")
        self.r_td, self.r_head = cat("td"), cat("head")
        self.r_ts0, self.r_ntim = cat("ts0"), cat("ntim")
        self.r_npsel, self.r_selstart = cat("npsel"), cat("selstart")
        self.r_const, self.r_ps = cat("const"), cat("ps")
        self.r_taut = cat("selfedge")
        self.sel = np.concatenate(sel_parts) if sel_parts else empty
        self.r_sup = self.r_ntim * self.r_npsel
        # per-row sel sums (for the closed-form support sum): sum of the
        # row's sel slice, via a cumulative sum over the concat table
        if self.sel.size:
            cs = np.concatenate([[0], np.cumsum(self.sel)])
            self.r_selsum = cs[self.r_selstart + self.r_npsel] \
                - cs[self.r_selstart]
        else:
            self.r_selsum = np.zeros(self.r_head.size, dtype=np.int64)
        # closed-form support sum: sum_{k<ntim} sum_{j} (const +
        # (ts0+k)*ps + sel_j)
        n, t0, j, c, p = (self.r_ntim, self.r_ts0, self.r_npsel,
                          self.r_const, self.r_ps)
        self.r_supsum = np.where(
            self.r_sup > 0,
            n * (j * c + self.r_selsum)
            + p * j * (n * t0 + n * (n - 1) // 2), 0)
        # full-support rows: the clamped window covers the producer's
        # whole candidate set -> subsumed by the producer's ALO
        nt_src = np.zeros(self.r_head.size, dtype=np.int64)
        a_src = np.zeros(self.r_head.size, dtype=np.int64)
        for nid in self.session.dfg.nodes:
            m = self.r_src == nid
            if m.any():
                a, b = self.session.asap[nid], self.session.alap[nid]
                nt_src[m] = b - a + 1
                a_src[m] = a
        self.r_full = ((self.r_sup > 0) & (self.r_ts0 == a_src)
                       & (self.r_ntim == nt_src)
                       & (self.r_npsel == self.r_ps))

    # ----------------------------------------------------- family counts
    def c1_count(self) -> int:
        total = 0
        for n, k in self.kvars.items():
            if k == 0:
                total += 1          # empty clause: node has no candidates
            elif k == 1:
                total += 1          # unit ALO, no AMO
            elif self.c1_pairwise[n]:
                total += 1 + k * (k - 1) // 2
            else:
                total += 1 + 3 * k - 4
        return total

    def c2_cold_count(self) -> int:
        m = self.issue_counts
        if self.amo == "pairwise":
            return int(_comb2(m).sum())
        pw = m <= _PAIRWISE_LIMIT
        return int(_comb2(m[pw]).sum()
                   + np.where(m[~pw] > 1, 3 * m[~pw] - 4, 0).sum())

    def c2s_count(self) -> int:
        return int(_comb2(_group_sizes(self.slot_key)).sum())

    def c2_delta_count(self) -> int:
        if self.amo == "pairwise":
            return self.c2_cold_count() - self.c2s_count()
        m, nk = self.issue_counts, self.issue_nslots
        multi = nk > 1
        mm = m[multi]
        return int(np.where(mm <= _PAIRWISE_LIMIT, _comb2(mm),
                            3 * mm - 4).sum())

    def c2w_count(self) -> int:
        if not self.mixed_lat or self.v_t.size == 0:
            return 0
        comp = self.v_pe * self.ii + (self.v_t + self.v_lat) % self.ii
        total = int(_comb2(_group_sizes(comp)).sum())
        lat_span = int(self.v_lat.max()) + 1
        same = int(_comb2(_group_sizes(comp * lat_span + self.v_lat)).sum())
        return total - same

    def c3_count(self) -> int:
        return int(self.r_head.size)

    def c2_aux_cold(self) -> int:
        """Sinz register variables allocated by the cold C2 fold (zero in
        pairwise mode, where no family creates per-II variables)."""
        if self.amo == "pairwise":
            return 0
        m = self.issue_counts
        big = m[m > _PAIRWISE_LIMIT]
        return int((big - 1).sum())

    # ------------------------------------------------- expected pair sets
    def _class_pairs(self, keys: np.ndarray, nv: int,
                     lat_filter: bool = False,
                     class_filter: Optional[np.ndarray] = None,
                     ) -> np.ndarray:
        """Canonical i64 keys ``u*(nv+1)+w`` (u<w, layout var ids) of all
        within-class pairs; ``lat_filter`` keeps only mixed-latency pairs,
        ``class_filter`` (bool per class, in sorted-unique-key order)
        drops whole classes (Sinz-emitted groups have no textual pairs)."""
        if keys.size == 0:
            return np.zeros(0, dtype=np.int64)
        order = np.argsort(keys, kind="stable")
        sk = keys[order]
        starts = np.flatnonzero(np.concatenate([[True], sk[1:] != sk[:-1]]))
        ends = np.concatenate([starts[1:], [sk.size]])
        out: List[np.ndarray] = []
        for ci, (a, b) in enumerate(zip(starts, ends)):
            if b - a < 2:
                continue
            if class_filter is not None and not class_filter[ci]:
                continue
            mem = np.sort(order[a:b]) + 1      # var ids, ascending
            iu, ju = np.triu_indices(b - a, 1)
            if lat_filter:
                lat = self.v_lat[mem - 1]
                keep = lat[iu] != lat[ju]
                iu, ju = iu[keep], ju[keep]
            out.append(mem[iu] * (nv + 1) + mem[ju])
        return (np.concatenate(out) if out
                else np.zeros(0, dtype=np.int64))

    def c1_pairs(self, nv: int) -> np.ndarray:
        out: List[np.ndarray] = []
        for n, k in self.kvars.items():
            if k < 2 or not self.c1_pairwise[n]:
                continue
            mem = np.arange(self.base0[n] + 1, self.base0[n] + k + 1,
                            dtype=np.int64)
            iu, ju = np.triu_indices(k, 1)
            out.append(mem[iu] * (nv + 1) + mem[ju])
        return (np.concatenate(out) if out
                else np.zeros(0, dtype=np.int64))

    def c2_pairs(self, nv: int) -> np.ndarray:
        return self._class_pairs(self.issue_key, nv)

    def c2s_pairs(self, nv: int) -> np.ndarray:
        return self._class_pairs(self.slot_key, nv)

    def c2_delta_pairs(self, nv: int) -> np.ndarray:
        full = self.c2_pairs(nv)
        slot = self.c2s_pairs(nv)
        return np.setdiff1d(full, slot, assume_unique=False)

    def c2w_pairs(self, nv: int) -> np.ndarray:
        if not self.mixed_lat or self.v_t.size == 0:
            return np.zeros(0, dtype=np.int64)
        comp = self.v_pe * self.ii + (self.v_t + self.v_lat) % self.ii
        return self._class_pairs(comp, nv, lat_filter=True)

    def _c2_pairs_gated(self, nv: int, incremental: bool) -> np.ndarray:
        """The pairs *textually present* in the C2 family: everything in
        pairwise mode; in sequential mode only the pairwise-fallback
        groups (cold: m <= limit; incremental layers additionally skip
        single-slot groups but re-emit within-slot pairs)."""
        if not incremental:
            if self.amo == "pairwise":
                return self._class_pairs(self.issue_key, nv)
            return self._class_pairs(
                self.issue_key, nv,
                class_filter=self.issue_counts <= _PAIRWISE_LIMIT)
        if self.amo == "pairwise":
            return self.c2_delta_pairs(nv)
        filt = (self.issue_nslots > 1) \
            & (self.issue_counts <= _PAIRWISE_LIMIT)
        return self._class_pairs(self.issue_key, nv, class_filter=filt)

    # ------------------------------------------------ expected redundancy
    def expected_dup_patterns(self, incremental: bool, nv: int,
                              ) -> Dict[Tuple[str, ...], int]:
        """Predicted duplicate groups, keyed by the sorted family tuple of
        the group's members — e.g. ``("c1","c2"): 18`` means 18 canonical
        clauses each appearing once in C1 and once in C2. Binary families
        are intersected as exact pair-key sets (each family emits a given
        pair at most once), so every cross-family overlap — C1 vs the
        fold, the fold vs write-port pairs, a sequential layer vs the
        within-slot skeleton — falls out of one grouping pass."""
        out: Dict[Tuple[str, ...], int] = {}
        sets = {"c1": self.c1_pairs(nv),
                "c2": self._c2_pairs_gated(nv, incremental),
                "c2w": self.c2w_pairs(nv)}
        if incremental:
            sets["c2s"] = self.c2s_pairs(nv)
        keys = np.concatenate([v for v in sets.values()])
        tags = np.concatenate([np.full(v.size, i, dtype=np.int64)
                               for i, v in enumerate(sets.values())])
        names = list(sets)
        if keys.size:
            order = np.argsort(keys, kind="stable")
            sk, st = keys[order], tags[order]
            starts = np.flatnonzero(np.concatenate(
                [[True], sk[1:] != sk[:-1]]))
            ends = np.concatenate([starts[1:], [sk.size]])
            for a, b in zip(starts, ends):
                if b - a < 2:
                    continue
                pat = tuple(sorted(names[t] for t in st[a:b]))
                out[pat] = out.get(pat, 0) + 1
        # c3: rows with identical content (parallel edges / coinciding
        # clamped windows; empty-support rows collapse to the bare head)
        if self.r_head.size:
            key = np.stack([
                self.r_head,
                np.where(self.r_sup > 0, self.r_src + 1, -1),
                np.where(self.r_sup > 0, self.r_ts0, 0),
                np.where(self.r_sup > 0, self.r_ntim, 0)], axis=1)
            _, counts = np.unique(key, axis=0, return_counts=True)
            for c in counts[counts > 1]:
                pat = ("c3",) * int(c)
                out[pat] = out.get(pat, 0) + 1
        return out

    def expected_units(self) -> Dict[int, str]:
        """lit -> class for every predicted unit clause: ``+v`` pinned-node
        ALOs, ``-w`` empty-support C3 heads."""
        out: Dict[int, str] = {}
        for n, k in self.kvars.items():
            if k == 1:
                out[self.base0[n] + 1] = "unit-alo"
        for h in self.r_head[self.r_sup == 0]:
            out[-int(h)] = "unit-c3"
        return out

    def expected_unit_subsumed(self, lit: int, incremental: bool) -> int:
        """Rows (len > 1) the unit clause ``lit`` subsumes, per the model."""
        if lit > 0:
            # pinned node's ALO: subsumes C3 rows whose support contains
            # the (single) candidate variable
            v = lit
            nid = int(self.v_node[v - 1])
            t0, p0 = int(self.v_t[v - 1]), int(self.v_pe[v - 1])
            pes = self.session.allowed_pes[nid]
            pidx = pes.index(p0)
            n = 0
            rows = np.flatnonzero((self.r_src == nid) & (self.r_sup > 0)
                                  & (self.r_ts0 <= t0)
                                  & (t0 < self.r_ts0 + self.r_ntim))
            for r in rows:
                s0 = int(self.r_selstart[r])
                if pidx in self.sel[s0:s0 + int(self.r_npsel[r])]:
                    n += 1
            return n
        # empty-support C3 head: subsumes every longer row containing -w
        w = -lit
        nid = int(self.v_node[w - 1])
        n = 0
        k = self.kvars[nid]
        if k > 1 and self.c1_pairwise[nid]:
            n += k - 1
        elif k > 1:
            pos = w - (self.base0[nid] + 1)
            n += 1 if pos in (0, k - 1) else 2
        m = int(self.issue_m[w - 1])
        cls = self.issue_inv[w - 1]

        def sinz_occ() -> int:
            # occurrences of -w in a Sinz ladder depend on the member's
            # position in the concatenated group (ascending var order)
            mem = np.sort(np.flatnonzero(self.issue_inv == cls))
            pos = int(np.searchsorted(mem, w - 1))
            return 1 if pos in (0, m - 1) else 2

        if not incremental:
            if self.c2_class_pairwise[cls]:
                n += m - 1
            else:
                n += sinz_occ()
        else:
            # base within-slot skeleton (always pairwise)
            slot_sz = int((self.slot_key == self.slot_key[w - 1]).sum())
            n += slot_sz - 1
            if self.issue_nslots[cls] > 1:
                if self.amo == "pairwise":
                    # delta layer: cross-time pairs only
                    n += (m - 1) - (slot_sz - 1)
                elif m <= _PAIRWISE_LIMIT:
                    # sequential fallback re-encodes the whole group
                    n += m - 1
                else:
                    n += sinz_occ()
        if self.mixed_lat:
            comp = self.v_pe * self.ii + (self.v_t + self.v_lat) % self.ii
            peers = np.flatnonzero(comp == comp[w - 1])
            n += int((self.v_lat[peers] != self.v_lat[w - 1]).sum())
        n += int(((self.r_head == w) & (self.r_sup > 0)).sum())
        return n


# ---------------------------------------------------------------- checking
def _sorted_families(families: Dict[str, Tuple[int, int]], n_clauses: int,
                     ) -> List[Tuple[str, int, int]]:
    """Families sorted by start; must tile [0, n_clauses) exactly."""
    fams = sorted(((name, a, b) for name, (a, b) in families.items()),
                  key=lambda x: x[1])
    pos = 0
    for name, a, b in fams:
        if a != pos or b < a:
            raise AuditError(f"family ranges do not tile the arena "
                             f"(at {name}: [{a},{b}) after {pos})")
        pos = b
    if pos != n_clauses:
        raise AuditError(f"family ranges cover {pos} of {n_clauses} clauses")
    return fams


def _extract_pairs(lits: np.ndarray, offs: np.ndarray, s: int, e: int,
                   nv: int, skip_rows: Optional[np.ndarray] = None,
                   ) -> Tuple[Optional[np.ndarray], str]:
    """Canonical keys of the (¬u, ¬w) binary rows in family rows [s, e),
    or (None, why) if the slice is not all negative binary clauses.
    ``skip_rows`` excludes absolute row indices (C1's ALO/empty rows)."""
    rows = np.arange(s, e)
    if skip_rows is not None and skip_rows.size:
        rows = rows[~np.isin(rows, skip_rows)]
    if rows.size == 0:
        return np.zeros(0, dtype=np.int64), ""
    lens = offs[rows + 1] - offs[rows]
    if not (lens == 2).all():
        return None, f"{int((lens != 2).sum())} non-binary rows"
    a = -lits[offs[rows]].astype(np.int64)
    b = -lits[offs[rows] + 1].astype(np.int64)
    if (a <= 0).any() or (b <= 0).any():
        return None, "positive literal in an AMO pair"
    if (a == b).any():
        return None, f"{int((a == b).sum())} self-pairs (¬v ∨ ¬v)"
    return np.minimum(a, b) * (nv + 1) + np.maximum(a, b), ""


def _audit(cell: str, mode: str, model: _Model, cnf, families,
           expected_counts: Dict[str, int],
           expected_dead: Optional[set] = None,
           incremental: bool = False) -> AuditReport:
    arena = cnf.arena
    n_vars, n_clauses = cnf.n_vars, len(arena)
    fams = _sorted_families(families, n_clauses)
    offs = arena.offs_view().astype(np.int64)
    lits = arena.lits_view().astype(np.int64)
    lens = np.diff(offs)
    rep = AuditReport(cell=cell, mode=mode, ii=model.ii, n_vars=n_vars,
                      n_clauses=n_clauses, family_counts={})
    add = rep.findings.append

    # ------------------------------------------------------- literal range
    if lits.size and ((lits == 0).any()
                      or (np.abs(lits) > n_vars).any()):
        bad = int(((lits == 0) | (np.abs(lits) > n_vars)).sum())
        add(Finding("litrange", "*", bad, False,
                    "zero or out-of-range literals"))
        return rep   # nothing downstream is trustworthy

    # ------------------------------------------------------- family counts
    counts_ok: Dict[str, bool] = {}
    for name, a, b in fams:
        exp = expected_counts.get(name)
        if exp is None:
            raise AuditError(f"no closed form for family {name!r}")
        rep.family_counts[name] = (b - a, exp)
        counts_ok[name] = (b - a) == exp
        if not counts_ok[name]:
            add(Finding(f"family-count:{name}", name, abs(b - a - exp),
                        False, f"actual {b - a} != closed-form {exp}"))
    fam_ranges = {name: (a, b) for name, a, b in fams}

    # --------------------------------------------- cold n_vars closed form
    if mode == "cold":
        exp_nv = model.n_layout + model.c1_aux + model.c2_aux_cold()
        if n_vars != exp_nv:
            add(Finding("nvars", "*", abs(n_vars - exp_nv), False,
                        f"n_vars {n_vars} != closed-form {exp_nv}"))

    # ---------------------------------------------------- C1 structure walk
    alo_rows: List[int] = []
    s1, e1 = fam_ranges["c1"]
    if counts_ok["c1"]:
        idx = s1
        bad_alo = 0
        for nid, k in model.kvars.items():
            if k == 0:
                if lens[idx] != 0:
                    bad_alo += 1
                idx += 1
                continue
            base = model.base0[nid]
            row = lits[offs[idx]:offs[idx + 1]]
            if lens[idx] != k or not np.array_equal(
                    row, np.arange(base + 1, base + k + 1)):
                bad_alo += 1
            alo_rows.append(idx)
            if k == 1:
                idx += 1
            elif model.c1_pairwise[nid]:
                idx += 1 + k * (k - 1) // 2
            else:
                idx += 1 + 3 * k - 4
        if bad_alo:
            add(Finding("c1-alo", "c1", bad_alo, False,
                        "ALO rows diverge from the node's variable range"))
        if idx != e1:
            add(Finding("c1-walk", "c1", abs(idx - e1), False,
                        "per-node C1 block walk does not close the family"))

    # ------------------------------------------------- AMO pair multisets
    def check_pairs(name: str, expected: np.ndarray,
                    skip: Optional[np.ndarray] = None) -> None:
        if name not in fam_ranges or not counts_ok.get(name):
            return
        a, b = fam_ranges[name]
        got, why = _extract_pairs(lits, offs, a, b, n_vars, skip)
        if got is None:
            add(Finding(f"amo-shape:{name}", name, 1, False, why))
            return
        got, expected = np.sort(got), np.sort(expected)
        if not np.array_equal(got, expected):
            diff = int(np.setdiff1d(got, expected).size
                       + np.setdiff1d(expected, got).size)
            add(Finding(f"amo-pairs:{name}", name, max(diff, 1), False,
                        "emitted pair multiset != model (completeness/"
                        "overlap violation)"))

    if model.amo == "pairwise" and counts_ok.get("c1"):
        check_pairs("c1", model.c1_pairs(n_vars),
                    skip=np.asarray(alo_rows, dtype=np.int64))
    if "c2s" in fam_ranges:
        check_pairs("c2s", model.c2s_pairs(n_vars))
    if model.amo == "pairwise":
        if incremental:
            check_pairs("c2", model.c2_delta_pairs(n_vars))
        else:
            check_pairs("c2", model.c2_pairs(n_vars))
    check_pairs("c2w", model.c2w_pairs(n_vars))

    # --------------------------------------------------- C3 aligned checks
    c3_aligned = counts_ok.get("c3", False)
    s3, e3 = fam_ranges["c3"]
    emp_full = None
    if c3_aligned and e3 > s3:
        ro = offs[s3:e3 + 1]
        heads = lits[ro[:-1]]
        if not np.array_equal(heads, -model.r_head):
            add(Finding("c3-head", "c3",
                        int((heads != -model.r_head).sum()), False,
                        "row head literals diverge from the model"))
            c3_aligned = False
        if c3_aligned and not np.array_equal(np.diff(ro),
                                             1 + model.r_sup):
            add(Finding("c3-lens", "c3",
                        int((np.diff(ro) != 1 + model.r_sup).sum()),
                        False, "row lengths != 1 + ntim*npsel"))
            c3_aligned = False
        if c3_aligned:
            cs = np.concatenate([[0], np.cumsum(lits)])
            rowsum = cs[ro[1:]] - cs[ro[:-1]]
            supsum = rowsum + model.r_head     # head lit is -head
            if not np.array_equal(supsum, model.r_supsum):
                add(Finding("c3-supsum", "c3",
                            int((supsum != model.r_supsum).sum()), False,
                            "support sums diverge from the closed form"))
                c3_aligned = False
        if c3_aligned:
            # support min/max per row (head slot masked out) -> exact
            # full-support detection; support literals are distinct by
            # construction, so min/max/len pin the contiguous range
            buf = lits[ro[0]:ro[-1]].copy()
            starts_rel = ro[:-1] - ro[0]
            big = 2 * n_vars + 3
            buf_min = buf.copy()
            buf_min[starts_rel] = big
            minv = np.minimum.reduceat(buf_min, starts_rel)
            maxv = np.maximum.reduceat(buf, starts_rel)
            k_src = np.asarray([model.kvars[int(n)] for n in model.r_src],
                               dtype=np.int64)
            b_src = np.asarray([model.base0[int(n)] for n in model.r_src],
                               dtype=np.int64)
            emp_full = ((model.r_sup > 0) & (minv == b_src + 1)
                        & (maxv == b_src + k_src)
                        & (model.r_sup == k_src))
            if not np.array_equal(emp_full, model.r_full):
                add(Finding("subsume:c3-full-mismatch", "c3",
                            int((emp_full != model.r_full).sum()), False,
                            "full-support rows diverge from the model"))
            elif emp_full.any():
                add(Finding("subsume:c3-full", "c3",
                            int(emp_full.sum()), True,
                            "C3 rows whose support covers the producer's "
                            "whole candidate set (subsumed by its ALO)"))

    # --------------------------------------------------------- tautologies
    row_of = np.repeat(np.arange(n_clauses), lens)
    pos = lits > 0
    kp = row_of[pos] * (n_vars + 1) + lits[pos]
    kn = row_of[~pos] * (n_vars + 1) - lits[~pos]
    taut_rows = np.unique(np.intersect1d(kp, kn) // (n_vars + 1))
    exp_taut = (s3 + np.flatnonzero(model.r_taut) if c3_aligned
                else np.zeros(0, dtype=np.int64))
    if np.array_equal(taut_rows, exp_taut):
        if taut_rows.size:
            add(Finding("taut:c3-self", "c3", int(taut_rows.size), True,
                        "self-edge rows whose window contains 0 "
                        "(accumulator supports itself)"))
    else:
        add(Finding("taut", "*",
                    int(np.setdiff1d(taut_rows, exp_taut).size
                        + np.setdiff1d(exp_taut, taut_rows).size), False,
                    "tautological rows do not match the self-edge model"))

    # ---------------------------------------------------------- duplicates
    if (lens == 0).any():
        add(Finding("empty-clause", "*", int((lens == 0).sum()), False,
                    "empty clauses (trivially UNSAT input)"))
    pad, _ = arena.padded_rows()
    if pad.size:
        pad = pad.copy()
        pad[pad == 0] = 2 * n_vars + 3
        pad.sort(axis=1)
        _, inv, cnt = np.unique(pad, axis=0, return_inverse=True,
                                return_counts=True)
        fam_starts = np.asarray([a for _, a, _ in fams])
        fam_names = [name for name, _, _ in fams]

        def fam_of(r: int) -> str:
            return fam_names[int(np.searchsorted(fam_starts, r, "right")) - 1]

        actual: Dict[Tuple[str, ...], int] = {}
        if (cnt > 1).any():
            order = np.argsort(inv, kind="stable")
            ginv = inv[order]
            gstarts = np.flatnonzero(np.concatenate(
                [[True], ginv[1:] != ginv[:-1]]))
            gends = np.concatenate([gstarts[1:], [ginv.size]])
            for a, b in zip(gstarts, gends):
                if b - a < 2:
                    continue
                pat = tuple(sorted(fam_of(r) for r in order[a:b]))
                actual[pat] = actual.get(pat, 0) + 1
        expected = model.expected_dup_patterns(incremental, n_vars)
        if actual == expected:
            for pat, n in sorted(actual.items()):
                add(Finding("dup:" + "*".join(pat), "*", n, True,
                            f"{n} clause(s) emitted {len(pat)}x — known "
                            "benign overlap, count matches the model"))
        else:
            add(Finding("dup:mismatch", "*",
                        sum(actual.values()) + sum(expected.values()), False,
                        f"duplicate groups {actual} != model {expected}"))

    # ------------------------------------------------- unit subsumption
    unit_rows = np.flatnonzero(lens == 1)
    unit_lits = {int(lits[offs[r]]) for r in unit_rows}
    exp_units = model.expected_units()
    if unit_lits != set(exp_units):
        add(Finding("unit:unexpected", "*",
                    len(unit_lits.symmetric_difference(exp_units)), False,
                    f"unit clauses {sorted(unit_lits)} != model "
                    f"{sorted(exp_units)}"))
    else:
        for lit, cls in sorted(exp_units.items()):
            occ_rows = row_of[lits == lit]
            got = int((lens[occ_rows] > 1).sum())
            exp = model.expected_unit_subsumed(lit, incremental)
            if got == exp:
                if got:
                    add(Finding(f"subsume:{cls}", "*", got, True,
                                f"unit {lit} subsumes {got} longer rows "
                                "(count matches the model)"))
            else:
                add(Finding(f"subsume:{cls}-mismatch", "*",
                            abs(got - exp), False,
                            f"unit {lit}: {got} subsumed rows != model "
                            f"{exp}"))

    # ----------------------------------------------------------- dead vars
    occ = np.bincount(np.abs(lits), minlength=n_vars + 1)
    dead = set((np.flatnonzero(occ[1:] == 0) + 1).tolist())
    exp_dead = expected_dead or set()
    if dead == exp_dead:
        if dead:
            add(Finding("dead:projection", "*", len(dead), True,
                        "selector/other-layer variables stripped by "
                        "project() (matches layer_var_ranges)"))
    else:
        add(Finding("dead:unexpected", "*",
                    len(dead.symmetric_difference(exp_dead)), False,
                    f"dead vars {sorted(dead - exp_dead)[:8]} / missing "
                    f"{sorted(exp_dead - dead)[:8]}"))
    return rep


# ----------------------------------------------------------- entry points
def audit_encoding(session: EncoderSession, ii: int,
                   enc: Optional[Encoding] = None,
                   cell: str = "?") -> AuditReport:
    """Audit one cold per-II encoding against the independent model."""
    if enc is None:
        enc = session.encode(ii)
    if not enc.families:
        raise AuditError("Encoding.families is empty — encode() must "
                         "record the family ranges")
    model = _Model(session, ii)
    expected = {"c1": model.c1_count(), "c2": model.c2_cold_count(),
                "c2w": model.c2w_count(), "c3": model.c3_count()}
    return _audit(cell, "cold", model, enc.cnf, enc.families, expected)


def audit_projection(inc: IncrementalEncoding, ii: int,
                     cell: str = "?") -> AuditReport:
    """Audit ``IncrementalEncoding.project(ii)`` — the guard-stripped
    base+delta CNF — including the expected-dead selector/aux variables
    of the other layers."""
    inc.ensure_ii(ii)
    cnf = inc.project(ii)
    model = _Model(inc.session, ii)
    expected = {"c1": model.c1_count(), "c2s": model.c2s_count(),
                "c2": model.c2_delta_count(), "c2w": model.c2w_count(),
                "c3": model.c3_count()}
    exp_dead: set = set()
    for key, (sel, vs, ve) in inc.inc.layer_var_ranges().items():
        if key == ii:
            exp_dead.add(sel)
        else:
            exp_dead.update(range(vs, ve + 1))
    return _audit(cell, "projection", model, cnf,
                  inc.projection_families(ii), expected,
                  expected_dead=exp_dead, incremental=True)


def suite_fabrics() -> List[Tuple[str, object]]:
    """The 3-fabric audit grid: the paper's homogeneous mesh, a
    multi-cycle (mixed-latency) fabric exercising C2W, and a restricted
    heterogeneous one-hop fabric (memory ops pinned to column 0)."""
    from ..core.arch import arch
    from ..core.cgra import cgra_from_name
    return [("3x3", cgra_from_name("3x3")),
            ("4x4:mul2:mem2", cgra_from_name("4x4:mul2:mem2")),
            ("4x4-onehop:r2+memcol0", arch("4x4-onehop:r2", mem="col0"))]


def audit_suite(names: Optional[Sequence[str]] = None,
                fabrics: Optional[List[Tuple[str, object]]] = None,
                amo: str = "pairwise", emitters: str = "vector",
                incremental: bool = True,
                progress=None) -> List[AuditReport]:
    """Audit every suite cell (kernel x fabric) at its minimal II: the
    cold encoding always, plus — with ``incremental=True`` — the layered
    projection with a second (II+1) layer encoded so the expected-dead
    variable check is non-trivial."""
    from ..core import suite
    from ..core.schedule import min_ii
    fabrics = fabrics if fabrics is not None else suite_fabrics()
    reports: List[AuditReport] = []
    for name in (names or suite.names()):
        g = suite.get(name)
        for label, fab in fabrics:
            cell = f"{name}/{label}"
            session = EncoderSession(g, fab, amo=amo, emitters=emitters)
            ii0 = max(min_ii(g, fab), 1)
            reports.append(audit_encoding(session, ii0, cell=cell))
            if incremental:
                inc = IncrementalEncoding(session)
                inc.ensure_ii(ii0)
                inc.ensure_ii(ii0 + 1)
                reports.append(audit_projection(inc, ii0, cell=cell))
            if progress is not None:
                progress(reports[-1])
    return reports


def reports_to_json(reports: Sequence[AuditReport]) -> Dict[str, object]:
    """Machine-readable audit artifact (CI uploads this as AUDIT_cnf.json)."""
    return {
        "cells": sorted({r.cell for r in reports}),
        "ok": all(r.ok() for r in reports),
        "n_reports": len(reports),
        "n_suppressed": sum(f.count for r in reports
                            for f in r.findings if f.suppressed),
        "n_unsuppressed": sum(1 for r in reports
                              for f in r.findings if not f.suppressed),
        "reports": [r.to_dict() for r in reports],
    }

"""Repo-invariant linter: AST / import-graph rules over the source tree.

The rules encode the stack's load-bearing conventions — things that are
*correct today* only because every PR so far has been careful:

* ``fork-safety`` — no module-scope ``jax``/``optax``/``jaxlib`` import
  reachable from the worker shard entrypoints (``core/workers.py``).
  Shards fork; a forked XLA runtime deadlocks or corrupts the client.
* ``opt-safety`` — no bare ``assert`` guarding runtime behaviour under
  ``src/``: ``python -O`` strips asserts, so guards must raise real
  exceptions.
* ``hash-determinism`` — no builtin ``hash()`` and no raw iteration over
  unordered sets inside the campaign canonicalizer or any
  ``*signature*`` function: canonical keys must be byte-stable across
  processes (``PYTHONHASHSEED``).
* ``pallas-constraints`` — kernel files must keep static shapes (no
  data-dependent ``nonzero``/``unique``/one-arg ``where``) and never
  touch ``float64``.

Legacy violations live in a checked-in baseline file
(``analysis/lint_baseline.txt``); :func:`run_lint` reports *all*
findings and the CLI (``python -m repro.analysis --check``) fails only
on findings whose fingerprint is not in the baseline.

The engine is self-contained stdlib (``ast`` + ``pathlib``): it never
imports the modules it scans, so it is safe to run in any environment,
including ones without jax.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["LintConfig", "LintFinding", "ParsedFile", "LintContext",
           "load_baseline", "run_lint", "write_baseline"]


# ---------------------------------------------------------------- config


@dataclass
class LintConfig:
    """Where to scan and how the rules bind to it.

    ``root`` is the repository root (or a fixture tree).  The scan base
    is ``root/src`` when that directory exists, else ``root`` itself —
    so fixture trees under ``tests/fixtures/lint/`` need no ``src/``
    nesting.  Rule scoping is *pattern-based* (module-name suffixes,
    path fragments) for the same reason: the defaults bind to both the
    real tree and the fixtures without per-tree configuration.
    """

    root: Path
    baseline_path: Optional[Path] = None
    rules: Optional[Sequence[str]] = None  # None -> all registered
    # fork-safety: entry modules are any module whose dotted name ends
    # with one of these suffixes; the closure over *module-scope*
    # imports must not reach a forbidden root.
    fork_entry_suffixes: Tuple[str, ...] = ("workers",)
    fork_forbidden_roots: Tuple[str, ...] = ("jax", "jaxlib", "optax",
                                             "flax")
    # hash-determinism: whole modules whose name ends with these
    # suffixes, plus any function whose name matches *signature* /
    # *canonical* anywhere in the tree.
    hash_module_suffixes: Tuple[str, ...] = ("campaign",)
    hash_func_fragments: Tuple[str, ...] = ("signature", "canonical")
    # pallas-constraints: files whose scan-relative path contains this
    # fragment; the dynamic-shape checks additionally only bind to
    # ``kernel.py`` / ``ops.py`` (reference implementations in
    # ``ref.py`` may use host numpy freely).
    pallas_path_fragment: str = "kernels/"
    pallas_shape_files: Tuple[str, ...] = ("kernel.py", "ops.py")

    def __post_init__(self) -> None:
        self.root = Path(self.root)
        if self.baseline_path is None:
            cand = (self.root / "src" / "repro" / "analysis"
                    / "lint_baseline.txt")
            if cand.is_file():
                self.baseline_path = cand

    @property
    def scan_root(self) -> Path:
        src = self.root / "src"
        return src if src.is_dir() else self.root


# -------------------------------------------------------------- findings


@dataclass
class LintFinding:
    """One rule violation at one site."""

    rule: str
    path: str          # scan-root-relative, posix separators
    line: int
    message: str
    token: str = ""    # stable detail used for the fingerprint
    # disambiguator when (rule, path, token) repeats in one file; set
    # by run_lint() in file order so fingerprints stay stable.
    ordinal: int = 0

    @property
    def fingerprint(self) -> str:
        fp = f"{self.rule}:{self.path}:{self.token or self.line}"
        if self.ordinal:
            fp += f"#{self.ordinal}"
        return fp

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.message}\n"
                f"    fingerprint: {self.fingerprint}")


@dataclass
class ParsedFile:
    path: Path
    rel: str                 # posix relative path under scan root
    module: str              # dotted module name
    tree: ast.AST
    source: str


@dataclass
class LintContext:
    """Everything a rule sees: parsed files + the module-scope import graph."""

    config: LintConfig
    files: Dict[str, ParsedFile]            # rel -> parsed
    modules: Dict[str, str] = field(default_factory=dict)  # module -> rel
    # module -> [(imported dotted name, lineno)] for imports executed at
    # import time (module scope and class bodies; not inside functions).
    module_scope_imports: Dict[str, List[Tuple[str, int]]] = \
        field(default_factory=dict)


# ------------------------------------------------------------- collection


def _module_name(rel: str) -> str:
    parts = rel[:-3].split("/")  # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _iter_py(scan_root: Path) -> Iterable[Path]:
    for p in sorted(scan_root.rglob("*.py")):
        if "__pycache__" in p.parts:
            continue
        yield p


class _ImportScan(ast.NodeVisitor):
    """Collect imports executed at module import time.

    Function bodies are skipped (they run later, post-fork guards live
    there on purpose); class bodies are *not* skipped — they execute at
    import.
    """

    def __init__(self, module: str, is_pkg: bool) -> None:
        self.module = module
        self.is_pkg = is_pkg
        self.out: List[Tuple[str, int]] = []

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # do not descend

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        return

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.out.append((alias.name, node.lineno))

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        base = node.module or ""
        if node.level:
            # resolve relative import against this module's package
            parts = self.module.split(".")
            # for a package __init__, level 1 is the package itself
            up = node.level - 1 if self.is_pkg else node.level
            if up:
                parts = parts[:-up] if up < len(parts) else []
            prefix = ".".join(parts)
            base = f"{prefix}.{base}" if base and prefix else (prefix or base)
        if base:
            self.out.append((base, node.lineno))
            # ``from pkg import sub`` may bind a submodule: record the
            # joined name too so the graph edge exists if it is one.
            for alias in node.names:
                if alias.name != "*":
                    self.out.append((f"{base}.{alias.name}", node.lineno))
        else:
            for alias in node.names:
                self.out.append((alias.name, node.lineno))


def build_context(config: LintConfig) -> LintContext:
    scan_root = config.scan_root
    files: Dict[str, ParsedFile] = {}
    for path in _iter_py(scan_root):
        rel = path.relative_to(scan_root).as_posix()
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as exc:  # surface as a finding, not a crash
            files[rel] = ParsedFile(path, rel, _module_name(rel),
                                    ast.Module(body=[], type_ignores=[]),
                                    source)
            files[rel].tree.lint_syntax_error = exc  # type: ignore[attr-defined]
            continue
        files[rel] = ParsedFile(path, rel, _module_name(rel), tree, source)

    ctx = LintContext(config=config, files=files)
    for rel, pf in files.items():
        ctx.modules[pf.module] = rel
        scan = _ImportScan(pf.module, rel.endswith("__init__.py"))
        scan.visit(pf.tree)
        ctx.module_scope_imports[pf.module] = scan.out
    return ctx


# ---------------------------------------------------------------- driver


def run_lint(config: LintConfig) -> List[LintFinding]:
    """Run every configured rule; return all findings (baselined or not)."""
    from .rules import ALL_RULES  # late import: rules import this module

    ctx = build_context(config)
    names = list(config.rules) if config.rules else list(ALL_RULES)
    findings: List[LintFinding] = []
    for name in names:
        if name not in ALL_RULES:
            raise KeyError(f"unknown lint rule: {name!r} "
                           f"(known: {sorted(ALL_RULES)})")
        findings.extend(ALL_RULES[name](ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.token))
    # assign ordinals so repeated (rule, path, token) fingerprints are
    # unique and stable in file order
    seen: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        key = (f.rule, f.path, f.token)
        f.ordinal = seen.get(key, 0)
        seen[key] = f.ordinal + 1
    return findings


# --------------------------------------------------------------- baseline


def load_baseline(path: Optional[Path]) -> Set[str]:
    """Read the suppression file: one fingerprint per line, ``#`` comments."""
    if path is None or not Path(path).is_file():
        return set()
    out: Set[str] = set()
    for raw in Path(path).read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        out.add(line)
    return out


def write_baseline(path: Path, findings: Sequence[LintFinding]) -> None:
    lines = ["# repro.analysis lint baseline — legacy violations only.",
             "# Each line is a finding fingerprint; new findings (not",
             "# listed here) fail `python -m repro.analysis --check`.",
             "# Regenerate with: python -m repro.analysis --write-baseline",
             ""]
    lines += sorted(f.fingerprint for f in findings)
    Path(path).write_text("\n".join(lines) + "\n")

"""Shared helpers for lint rules."""
from __future__ import annotations

import ast


def snippet(node: ast.AST, limit: int = 60) -> str:
    """Stable short rendering of a node for fingerprints/messages."""
    try:
        text = ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is total on 3.10+
        text = type(node).__name__
    text = " ".join(text.split())
    return text if len(text) <= limit else text[: limit - 3] + "..."

"""hash-determinism: canonical keys must be ``PYTHONHASHSEED``-stable.

The campaign canonicalizer (``core/campaign.py``) and every
``*signature*`` / ``*canonical*`` function produce keys that must be
byte-for-byte identical across processes — they name cells in the
sharded dataset and route requests to warm shards.  Builtin ``hash()``
is salted per process, and iteration order over ``set``/``frozenset``
depends on it; either one silently forks the keyspace.  This rule flags,
inside the scoped code only:

* any call to builtin ``hash(...)``;
* any ``for`` loop or comprehension iterating a raw unordered set
  expression (a set literal, set comprehension, or direct
  ``set(...)``/``frozenset(...)`` call) — wrap in ``sorted(...)``.

Scope: whole modules whose last dotted component matches
``config.hash_module_suffixes``, plus the body of any function whose
name contains one of ``config.hash_func_fragments`` anywhere in the
tree.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator, List, Set, Tuple

from ..lint import LintContext, LintFinding
from ._util import snippet

NAME = "hash-determinism"

_SET_CTORS = {"set", "frozenset"}


def _is_unordered(expr: ast.expr) -> bool:
    if isinstance(expr, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(expr, ast.Call)
            and isinstance(expr.func, ast.Name)
            and expr.func.id in _SET_CTORS)


def _scan(scope: ast.AST) -> Iterator[Tuple[int, str, str]]:
    """Yield (lineno, token, message) for violations inside ``scope``."""
    for node in ast.walk(scope):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            yield (node.lineno, f"hash:{snippet(node, 40)}",
                   f"builtin `hash()` is salted per process: "
                   f"{snippet(node)}")
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            if _is_unordered(node.iter):
                yield (node.lineno, f"set-iter:{snippet(node.iter, 40)}",
                       f"iteration over unordered set `{snippet(node.iter)}`"
                       f" — wrap in sorted(...)")
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_unordered(gen.iter):
                    yield (gen.iter.lineno,
                           f"set-iter:{snippet(gen.iter, 40)}",
                           f"comprehension over unordered set "
                           f"`{snippet(gen.iter)}` — wrap in sorted(...)")


def check(ctx: LintContext) -> Iterable[LintFinding]:
    cfg = ctx.config
    for rel, pf in sorted(ctx.files.items()):
        scopes: List[ast.AST] = []
        if pf.module.split(".")[-1] in cfg.hash_module_suffixes:
            scopes.append(pf.tree)
        else:
            for node in ast.walk(pf.tree):
                if (isinstance(node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                        and any(frag in node.name
                                for frag in cfg.hash_func_fragments)):
                    scopes.append(node)
        seen: Set[Tuple[int, str]] = set()
        for scope in scopes:
            for lineno, token, message in _scan(scope):
                if (lineno, token) in seen:
                    continue
                seen.add((lineno, token))
                yield LintFinding(rule=NAME, path=rel, line=lineno,
                                  token=token, message=message)

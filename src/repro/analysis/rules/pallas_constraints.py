"""pallas-constraints: kernel files keep static shapes and stay off f64.

The accelerator kernels (``kernels/*/kernel.py`` and their jit'd
``ops.py`` drivers) run under ``jax.jit`` / Pallas, where:

* output shapes must be static — ``nonzero``/``flatnonzero``/
  ``unique``/``compress``/``extract`` and one-argument ``where`` have
  data-dependent output shapes and fail (or silently fall back) under
  tracing;
* ``float64`` is unavailable on the target and double-precision
  constants silently downcast (or upcast the whole kernel when x64 is
  force-enabled), so any ``float64`` mention is a bug.

The float64 check applies to every file under the kernels tree
(including ``ref.py`` — references must compare in the dtype the kernel
actually uses); the dynamic-shape checks bind only to
``config.pallas_shape_files`` since host-side reference code may use
numpy's dynamic ops freely.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..lint import LintContext, LintFinding
from ._util import snippet

NAME = "pallas-constraints"

_DYN_SHAPE = {"nonzero", "flatnonzero", "unique", "compress", "extract",
              "argwhere"}
_F64_ATTRS = {"float64", "double", "complex128"}


def check(ctx: LintContext) -> Iterable[LintFinding]:
    cfg = ctx.config
    for rel, pf in sorted(ctx.files.items()):
        if cfg.pallas_path_fragment not in rel:
            continue
        shape_scope = rel.rsplit("/", 1)[-1] in cfg.pallas_shape_files
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Attribute) and node.attr in _F64_ATTRS:
                yield LintFinding(
                    rule=NAME, path=rel, line=node.lineno,
                    token=f"f64:{node.attr}",
                    message=f"`{snippet(node)}`: float64/double is "
                            f"unavailable in kernels",
                )
            elif (isinstance(node, ast.Constant)
                  and node.value in ("float64", "complex128")):
                yield LintFinding(
                    rule=NAME, path=rel, line=node.lineno,
                    token=f"f64:{node.value}",
                    message=f"dtype string {node.value!r} in kernel file",
                )
            elif shape_scope and isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                attr = node.func.attr
                if attr in _DYN_SHAPE:
                    yield LintFinding(
                        rule=NAME, path=rel, line=node.lineno,
                        token=f"dyn:{attr}",
                        message=f"`{snippet(node)}`: data-dependent "
                                f"output shape is not traceable",
                    )
                elif (attr == "where" and len(node.args) == 1
                      and not node.keywords):
                    yield LintFinding(
                        rule=NAME, path=rel, line=node.lineno,
                        token="dyn:where1",
                        message=f"one-argument `where` "
                                f"(`{snippet(node)}`) has data-dependent "
                                f"shape; use the three-argument form",
                    )

"""opt-safety: no bare ``assert`` guarding runtime behaviour.

``python -O`` compiles asserts away, so an ``assert`` that guards a
runtime invariant (queue started, worker initialised, shape contract)
silently stops guarding.  Guards must raise real exceptions
(``RuntimeError`` / ``ValueError``).  Every ``assert`` statement under
the scan root is reported; genuinely debug-only ones are suppressed via
the baseline file, which keeps them *explicit* instead of tribal.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..lint import LintContext, LintFinding
from ._util import snippet

NAME = "opt-safety"


def check(ctx: LintContext) -> Iterable[LintFinding]:
    for rel, pf in sorted(ctx.files.items()):
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Assert):
                yield LintFinding(
                    rule=NAME, path=rel, line=node.lineno,
                    token=snippet(node.test),
                    message=("bare `assert` is stripped under `python -O`"
                             f": assert {snippet(node.test)}"),
                )

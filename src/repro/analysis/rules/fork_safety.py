"""fork-safety: worker shard entrypoints must have a jax-free import chain.

``core/workers.py`` forks solver shards with ``multiprocessing``; a
module-scope ``jax``/``jaxlib``/``optax`` import anywhere in its import
closure would initialise XLA in the parent and fork a corrupted runtime
into every shard (see the fork-safety note in ``core/sat/portfolio.py``).
This rule walks the *module-scope* import graph (imports inside function
bodies are post-fork by construction and therefore fine) from every
entry module — any module whose last dotted component matches
``config.fork_entry_suffixes`` — and reports each edge through which a
forbidden root becomes reachable, with the offending chain.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from ..lint import LintContext, LintFinding

NAME = "fork-safety"


def _resolve_internal(ctx: LintContext, name: str) -> Optional[str]:
    """Longest known module prefix of ``name``, if any."""
    parts = name.split(".")
    for k in range(len(parts), 0, -1):
        cand = ".".join(parts[:k])
        if cand in ctx.modules:
            return cand
    return None


def check(ctx: LintContext) -> Iterable[LintFinding]:
    cfg = ctx.config
    entries = [m for m in sorted(ctx.modules)
               if m.split(".")[-1] in cfg.fork_entry_suffixes]
    for entry in entries:
        # BFS over module-scope imports, remembering how we got there
        parent: Dict[str, Tuple[str, int]] = {}  # module -> (importer, line)
        queue: List[str] = [entry]
        visited = {entry}
        while queue:
            mod = queue.pop(0)
            for imp, lineno in ctx.module_scope_imports.get(mod, ()):
                root = imp.split(".")[0]
                if root in cfg.fork_forbidden_roots:
                    chain = _chain(entry, mod, parent)
                    rel = ctx.modules[mod]
                    yield LintFinding(
                        rule=NAME, path=rel, line=lineno,
                        token=f"{mod}->{root}",
                        message=(f"module-scope `{imp}` import reachable "
                                 f"from fork entry `{entry}` via "
                                 f"{' -> '.join(chain)}"),
                    )
                    continue
                internal = _resolve_internal(ctx, imp)
                if internal and internal not in visited:
                    visited.add(internal)
                    parent[internal] = (mod, lineno)
                    queue.append(internal)


def _chain(entry: str, mod: str, parent: Dict[str, Tuple[str, int]]
           ) -> List[str]:
    chain = [mod]
    while chain[-1] != entry and chain[-1] in parent:
        chain.append(parent[chain[-1]][0])
    return list(reversed(chain))

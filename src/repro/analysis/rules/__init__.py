"""Lint rule registry.

Each rule module exposes ``NAME: str`` and ``check(ctx) ->
Iterable[LintFinding]``.  Register new rules here; the CLI and
:func:`repro.analysis.lint.run_lint` pick them up by name.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable

from ..lint import LintContext, LintFinding
from . import (fork_safety, hash_determinism, opt_safety,
               pallas_constraints)

Rule = Callable[[LintContext], Iterable[LintFinding]]

ALL_RULES: Dict[str, Rule] = {
    mod.NAME: mod.check
    for mod in (fork_safety, opt_safety, hash_determinism,
                pallas_constraints)
}

"""AdamW with ZeRO-1-style optimizer-state sharding.

Optimizer state (m, v, fp32 where params are bf16) is sharded like the
parameter PLUS the first divisible unsharded tensor axis split over the
data axes — the partitioner then materializes the reduce-scatter /
all-gather pattern of ZeRO stage 1 automatically from the sharding
mismatch between grads (param-sharded) and states (data-sharded).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models.sharding import batch_axes, dp_size

Params = Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init(params: Params) -> Dict[str, Any]:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if hasattr(p, "shape") else jnp.zeros((), jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes(param_shapes: Params) -> Dict[str, Any]:
    f32 = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_shapes)
    return {"m": f32, "v": jax.tree.map(lambda x: x, f32),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def zero1_spec(param_spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Shard the first unsharded, divisible tensor axis over the data axes."""
    dps = batch_axes(mesh)
    dp = dp_size(mesh)
    if dp == 1 or not shape:
        return param_spec
    axes = list(param_spec) + [None] * (len(shape) - len(param_spec))
    # already data-sharded (fsdp weights): nothing more to shard over data
    for ax in axes:
        used = ax if isinstance(ax, tuple) else (ax,)
        if any(a in dps for a in used if a):
            return param_spec
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if ax is None and dim % dp == 0 and dim > 0:
            axes[i] = dps if len(dps) > 1 else dps[0]
            return P(*axes)
    return param_spec


def state_specs(param_specs: Params, param_shapes: Params, mesh: Mesh,
                zero1: bool = True) -> Dict[str, Any]:
    if zero1:
        sharded = jax.tree.map(
            lambda sp, sh: zero1_spec(sp, sh.shape, mesh),
            param_specs, param_shapes,
            is_leaf=lambda x: isinstance(x, P))
    else:
        sharded = param_specs
    return {"m": sharded, "v": jax.tree.map(lambda x: x, sharded,
                                            is_leaf=lambda x: isinstance(x, P)),
            "step": P()}


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def update(cfg: AdamWConfig, grads: Params, state: Dict[str, Any],
           params: Params) -> Tuple[Params, Dict[str, Any], Dict[str, Any]]:
    step = state["step"] + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}

"""ShapeDtypeStruct input stand-ins + shardings for every (arch, shape).

``input_specs`` returns (kind, shapes-pytree, specs-pytree) where the
pytrees match the step function's batch argument. No device allocation —
exactly the shannon/kernels dry-run pattern. Modality frontends are stubs:
audio provides precomputed EnCodec frame embeddings, VLM provides
precomputed ViT patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from ..models.model import LM
from ..models.sharding import spec


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    shapes: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.frontend == "audio_frames":
        shapes["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), _dt(cfg))
        specs["embeds"] = spec(mesh, "batch", None, None)
        shapes["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["labels"] = spec(mesh, "batch", None)
    elif cfg.frontend == "vision_patches":
        fl = cfg.frontend_len
        shapes["embeds"] = jax.ShapeDtypeStruct((b, fl, cfg.d_model), _dt(cfg))
        specs["embeds"] = spec(mesh, "batch", None, None)
        shapes["tokens"] = jax.ShapeDtypeStruct((b, s - fl), i32)
        specs["tokens"] = spec(mesh, "batch", None)
        shapes["labels"] = jax.ShapeDtypeStruct((b, s - fl), i32)
        specs["labels"] = spec(mesh, "batch", None)
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["tokens"] = spec(mesh, "batch", None)
        shapes["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["labels"] = spec(mesh, "batch", None)
    return shapes, specs


def decode_inputs(lm: LM, shape: ShapeConfig, mesh: Mesh):
    """(cache, tokens, t) shapes+specs for one decode step at position
    seq_len (KV cache holding seq_len context)."""
    cfg = lm.cfg
    b = shape.global_batch
    window = shape.seq_len
    if cfg.attn_window:
        window = min(window, cfg.attn_window)
    cache_shapes = lm.cache_shapes(b, window) if (
        not cfg.is_attention_free or cfg.has_ssm) else {}
    cache_specs = lm.cache_specs(batch=b)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_spec = spec(mesh, "batch", None, batch_size=b)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return (cache_shapes, cache_specs), (tok, tok_spec), (t, P())


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    b, s = shape.global_batch, shape.seq_len
    shapes: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.frontend == "audio_frames":
        shapes["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), _dt(cfg))
        specs["embeds"] = spec(mesh, "batch", None, None)
    elif cfg.frontend == "vision_patches":
        fl = cfg.frontend_len
        shapes["embeds"] = jax.ShapeDtypeStruct((b, fl, cfg.d_model), _dt(cfg))
        specs["embeds"] = spec(mesh, "batch", None, None)
        shapes["tokens"] = jax.ShapeDtypeStruct((b, s - fl), jnp.int32)
        specs["tokens"] = spec(mesh, "batch", None)
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["tokens"] = spec(mesh, "batch", None)
    return shapes, specs

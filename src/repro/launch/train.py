"""End-to-end training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch minitron_8b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 10

Fault tolerance model (scales to real pods):
  * checkpoints are atomic + elastic (see repro.checkpoint);
  * --resume restarts from the newest complete checkpoint, bitwise-exact
    (asserted in tests) because the data pipeline is stateless in step;
  * --fail-at simulates a hard crash mid-run (tests use it to prove
    restart equivalence);
  * stragglers: batches are (seed, step, shard)-pure so replacement hosts
    need no catch-up coordination; optional --skip-anomalous-grads drops
    steps whose global grad-norm explodes (the usual large-fleet guard
    against a corrupting host).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import checkpoint as ckpt
from ..configs import get_config
from ..data.pipeline import DataConfig, SyntheticLM
from ..models.model import LM
from ..optim import adamw
from .mesh import make_host_mesh
from .steps import make_train_step


def train_loop(cfg, *, steps: int = 20, global_batch: int = 8,
               seq_len: int = 64, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 0, resume: bool = False,
               fail_at: Optional[int] = None, seed: int = 0,
               skip_anomalous_grads: bool = False, grad_norm_limit: float = 1e3,
               mesh=None, log_every: int = 5) -> Dict[str, Any]:
    mesh = mesh or make_host_mesh()
    lm = LM(cfg, mesh)
    data = SyntheticLM(DataConfig(seed=seed, global_batch=global_batch,
                                  seq_len=seq_len), cfg)
    opt_cfg = adamw.AdamWConfig()
    step_fn = make_train_step(lm, opt_cfg)

    pspecs = jax.tree.map(lambda sp: NamedSharding(mesh, sp), lm.param_specs(),
                          is_leaf=lambda x: isinstance(x, P))
    with mesh:
        start = 0
        if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            state, manifest = ckpt.restore(ckpt_dir)
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            start = manifest["extra"]["data_cursor"]
            print(f"resumed from step {start}")
        else:
            params = lm.init(jax.random.PRNGKey(seed))
            opt_state = adamw.init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        metrics: Dict[str, Any] = {}
        skipped = 0
        for s in range(start, steps):
            if fail_at is not None and s == fail_at:
                raise RuntimeError(f"injected failure at step {s}")
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            new_params, new_opt, metrics = jit_step(params, opt_state, batch)
            if skip_anomalous_grads and float(
                    metrics["grad_norm"]) > grad_norm_limit:
                skipped += 1           # drop the update, keep going
                params, opt_state = new_params, new_opt  # donated; re-adopt
            else:
                params, opt_state = new_params, new_opt
            if log_every and (s % log_every == 0 or s == steps - 1):
                print(f"step {s}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if ckpt_dir and ckpt_every and (s + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, s + 1,
                          {"params": params, "opt": opt_state},
                          extra={"data_cursor": s + 1, "seed": seed,
                                 "arch": cfg.name,
                                 "mesh": list(mesh.devices.shape)})
        final = {k: float(v) for k, v in metrics.items()}
        final["skipped_steps"] = skipped
        if ckpt_dir and ckpt_every:
            ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state},
                      extra={"data_cursor": steps, "seed": seed,
                             "arch": cfg.name,
                             "mesh": list(mesh.devices.shape)})
        final["params"] = params
        return final


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron_8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-anomalous-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    out = train_loop(cfg, steps=args.steps, global_batch=args.global_batch,
                     seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, resume=args.resume,
                     fail_at=args.fail_at, seed=args.seed,
                     skip_anomalous_grads=args.skip_anomalous_grads)
    out.pop("params", None)
    print(json.dumps(out))


if __name__ == "__main__":
    main()

"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state. The production target is TPU v5e: 16x16 = 256 chips per pod,
2 pods = 512 chips multi-pod. On the CPU container the dry-run forces 512
host platform devices (see dryrun.py) before calling this.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return _mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Small mesh over whatever devices exist (smoke tests / examples)."""
    n = jax.device_count()
    model = model or 1
    return _mesh((n // model, model), ("data", "model"))


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count before any jax import")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:n])
    except TypeError:  # older make_mesh without devices kwarg
        return Mesh(np.array(devs[:n]).reshape(shape), axes)

"""Per-architecture CGRA offload report (DESIGN.md §4).

    PYTHONPATH=src python -m repro.launch.map_cgra --arch yi_34b --cgra 4x4

Extracts the architecture's representative scalar inner loops (norm
accumulation, RoPE rotation, router argmax, SSD recurrence — the loops a
CGRA sidecar could offload), maps each with SAT-MapIt, and prints II +
verification per loop. Matmul-shaped compute is intentionally absent: it
is not a modulo-scheduling target (it goes to the MXU / systolic array).

``--cgra`` takes the full fabric grammar
(``RxC[-topology][:rN][:clsK...]``, e.g. ``4x4-torus``, ``8x8:r8``,
``4x4-onehop``, ``4x4:mul2:mem2`` for 2-cycle multipliers and memory
ports), and ``--mem`` / ``--mul`` restrict those op classes to a region
(``col0``, ``row1``, ``corners``, ``border``, ``even``/``odd``) — so
heterogeneous fabrics sweep from the CLI. A structurally infeasible
combination (a loop needs an op class the fabric disables everywhere) is
reported as INFEASIBLE with the reason, not as an exhausted sweep. ``--check`` turns the report into a CI smoke: exit non-zero unless
every loop maps *and* every node landed on a capability-compatible PE.
Every mapping is served through the unified ``compile(MapRequest(...))``
front door (``repro.core.api``).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from ..configs import get_config
from ..core.api import MapRequest, compile as compile_request
from ..core.arch import arch
from ..core.mapper import MapperConfig
from ..core.frontend import trace_loop_body
from ..core.schedule import Infeasible


def _norm_acc(i, acc, x):
    return (acc + x * x,)


def _rope_pair(i, c, s):
    x1 = (c * 13 - s * 7) >> 4
    x2 = (c * 7 + s * 13) >> 4
    return (x1, x2)


def _router_argmax(i, best, bestv, x):
    take = x > bestv
    return (jnp.where(take, i, best), jnp.where(take, x, bestv))


def _ssd_step(i, state, x):
    decayed = state - (state >> 3)
    return (decayed + x * 5,)


def loops_for(cfg):
    loops = [("rmsnorm_acc", _norm_acc, 1, 1)]
    if not cfg.is_attention_free:
        loops.append(("rope_rotation", _rope_pair, 2, 0))
    if cfg.n_experts:
        loops.append(("router_argmax", _router_argmax, 2, 1))
    if cfg.has_ssm:
        loops.append(("ssd_recurrence", _ssd_step, 1, 1))
    return loops


def _amo_clause_counts(g, cgra, mii: int) -> str:
    """Clause counts of the pairwise vs Sinz-sequential AMO at MII."""
    from ..core.encode import encode
    counts = {amo: encode(g, cgra, max(mii, 1), amo).stats["clauses"]
              for amo in ("pairwise", "sequential")}
    return (f"clauses@MII pairwise={counts['pairwise']} "
            f"sequential={counts['sequential']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cgra", default="4x4", metavar="FABRIC",
                    help="fabric name RxC[-mesh|torus|diag|onehop][:rN] "
                         "(e.g. 4x4, 4x4-torus, 8x8:r8)")
    ap.add_argument("--mem", default=None, metavar="REGION",
                    help="restrict load/store-capable PEs to a region "
                         "(colK/rowK/corners/border/even/odd/none)")
    ap.add_argument("--mul", default=None, metavar="REGION",
                    help="restrict mul/div/rem-capable PEs to a region")
    ap.add_argument("--regs", type=int, default=None,
                    help="local registers per PE (overrides the :rN suffix)")
    ap.add_argument("--check", action="store_true",
                    help="CI smoke: exit non-zero unless every loop maps "
                         "and every node sits on a capability-compatible PE")
    ap.add_argument("--routing", action="store_true")
    ap.add_argument("--amo", choices=["pairwise", "sequential"],
                    default="pairwise",
                    help="at-most-one encoding: the paper's pairwise or the "
                         "Sinz sequential (O(k) ternary clauses)")
    ap.add_argument("--cold", action="store_true",
                    help="disable the incremental assumption-based solver "
                         "core (fresh encode+solve per II, the paper-"
                         "faithful reference)")
    ap.add_argument("--service", action="store_true",
                    help="route every mapping through the process-wide "
                         "MappingService (solver pool + mapping cache) and "
                         "run a second warm pass: repeated loops hit the "
                         "cache, same-shape loops reuse warm sessions and "
                         "skip core-refuted IIs")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print per-II attempt lines with solver reuse "
                         "stats (learned clauses retained, conflicts, "
                         "warm-start hamming distance)")
    ap.add_argument("--sweep", type=int, default=0, metavar="K",
                    help="also run the parallel II-sweep engine with window "
                         "width K and report both modes side-by-side")
    ap.add_argument("--guide", default=None, metavar="NAME_OR_NPZ",
                    help="learned II guidance for the sweep runs: a "
                         "registered guide name or an .npz checkpoint from "
                         "repro.launch.campaign (window seeding only — "
                         "never changes the final II)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    cgra = arch(args.cgra, regs=args.regs, mem=args.mem, mul=args.mul)
    mode = "cold" if args.cold else "incremental"
    service = None
    if args.service:
        from ..core.service import get_service
        service = get_service()
        mode += "+service"
    print(f"CGRA offload report: {cfg.name} on {cgra} "
          f"[amo={args.amo}, {mode}]")
    failures = []
    for name, fn, n_carry, loads in loops_for(cfg):
        g, _ = trace_loop_body(fn, n_carry=n_carry, loads=loads, name=name)
        try:
            r = compile_request(MapRequest(
                dfg=g, arch=cgra, config=MapperConfig(
                    solver="auto", timeout_s=60, routing=args.routing,
                    amo=args.amo, incremental=not args.cold),
                service=service))
        except Infeasible as e:
            # structural infeasibility — the fabric cannot run this loop's
            # op mix at any II; report the reason instead of a doomed sweep
            print(f"  {name:16s} nodes={g.n:2d}  INFEASIBLE: {e}")
            if args.check:
                failures.append(f"{name}: INFEASIBLE on {cgra} ({e})")
            continue
        if args.check:
            if not r.success:
                failures.append(f"{name}: NO MAPPING on {cgra}")
            else:
                for n, (p, _c, _it) in r.placement.items():
                    op = r.dfg.nodes[n].op
                    if not cgra.can_execute(p, op):
                        failures.append(
                            f"{name}: {op} node {n} on incapable PE {p}")
        status = f"II={r.ii} (MII={r.mii})" if r.success else "NO MAPPING"
        line = (f"  {name:16s} nodes={g.n:2d}  {status}  "
                f"[seq {r.total_time:.2f}s, {len(r.attempts)} attempts]")
        if r.service is not None:
            line += (f"  [svc via={r.service.via}"
                     f" pruned={r.service.iis_pruned}"
                     f" evicted={r.service.clauses_evicted}]")
        if args.sweep > 1:
            g2, _ = trace_loop_body(fn, n_carry=n_carry, loads=loads,
                                    name=name)
            rs = compile_request(MapRequest(
                dfg=g2, arch=cgra, config=MapperConfig(
                    solver="auto", timeout_s=60, amo=args.amo,
                    incremental=not args.cold, guide=args.guide),
                sweep_width=args.sweep))
            sstat = f"II={rs.ii}" if rs.success else "NO MAPPING"
            line += f"  | sweep(k={args.sweep}) {sstat} [{rs.total_time:.2f}s]"
            guid = getattr(rs, "guidance", None)
            if guid and guid.get("used"):
                line += (f" [guide offset={guid['offset']}"
                         f" spans={guid['spans']}]")
            if rs.success and r.success and rs.ii != r.ii:
                line += "  !! sweep/sequential II mismatch"
        print(line)
        if args.verbose:
            print(f"      {_amo_clause_counts(g, cgra, r.mii)}")
            for a in r.attempts:
                reuse = ""
                if a.learned_retained is not None:
                    reuse += f" retained={a.learned_retained}"
                if a.conflicts is not None:
                    reuse += f" conflicts={a.conflicts}"
                if a.warm_hamming is not None:
                    reuse += f" warm_hamming={a.warm_hamming}"
                via = f" via={a.via}" if a.via else ""
                print(f"      II={a.ii} {a.status}{via} "
                      f"vars={a.n_vars} clauses={a.n_clauses} "
                      f"enc={a.encode_time*1e3:.1f}ms "
                      f"solve={a.solve_time*1e3:.1f}ms{reuse}")
    if service is not None:
        # warm pass: identical requests — every loop should come back from
        # the mapping cache without touching a solver
        import time as _time
        t0 = _time.time()
        for name, fn, n_carry, loads in loops_for(cfg):
            g, _ = trace_loop_body(fn, n_carry=n_carry, loads=loads,
                                   name=name)
            try:
                r = compile_request(MapRequest(
                    dfg=g, arch=cgra, config=MapperConfig(
                        solver="auto", timeout_s=60, routing=args.routing,
                        amo=args.amo, incremental=not args.cold),
                    service=service))
            except Infeasible:
                continue   # already reported in the first pass
            print(f"  warm {name:16s} II={r.ii} via={r.service.via} "
                  f"[{r.service.request_time*1e3:.1f}ms]")
        print(f"  warm pass total {_time.time()-t0:.2f}s; "
              f"service: {service.describe()}")
    if args.check:
        if failures:
            raise SystemExit("map_cgra --check failed: " +
                             "; ".join(failures))
        print("map_cgra --check OK")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements of this module — jax
locks the device count at first initialization, and the production meshes
(16x16 single-pod, 2x16x16 multi-pod) need 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_34b \
        --shape train_4k --mesh pod --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell

Each cell is one JSON record: memory_analysis, cost_analysis, collective
wire bytes, roofline terms — appended to the JSONL so the run is resumable.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config
from ..models.config import SHAPES, shape_applicable
from ..models.model import LM
from ..optim import adamw
from . import roofline, specs as specs_mod, steps
from .mesh import make_production_mesh


def _ns(mesh, tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    lm = LM(cfg, mesh)
    pshapes = lm.param_shapes()
    pspecs = _ns(mesh, lm.param_specs())

    with mesh:
        if shape.kind == "train":
            oshapes = adamw.state_shapes(pshapes)
            ospecs = _ns(mesh, adamw.state_specs(
                lm.param_specs(), pshapes, mesh, zero1=cfg.zero1))
            bshapes, bspecs = specs_mod.train_batch_specs(cfg, shape, mesh)
            bspecs = _ns(mesh, bspecs)
            fn = steps.make_train_step(lm)
            mspec = _ns(mesh, {"ce": P(), "aux": P(), "loss": P(),
                               "grad_norm": P(), "lr": P()})
            jitted = jax.jit(fn,
                             in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, mspec),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pshapes, oshapes, bshapes)
        elif shape.kind == "prefill":
            bshapes, bspecs = specs_mod.prefill_inputs(cfg, shape, mesh)
            bspecs = _ns(mesh, bspecs)
            fn = steps.make_prefill_step(lm)
            out_spec = NamedSharding(
                mesh, specs_mod.spec(mesh, "batch", None, "model"))
            jitted = jax.jit(fn, in_shardings=(pspecs, bspecs),
                             out_shardings=out_spec)
            lowered = jitted.lower(pshapes, bshapes)
        else:  # decode
            (cshapes, cspecs), (tok, tok_spec), (t, t_spec) = \
                specs_mod.decode_inputs(lm, shape, mesh)
            cspecs = _ns(mesh, cspecs)
            fn = steps.make_decode_step(lm)
            out_specs = (NamedSharding(
                mesh, specs_mod.spec(mesh, "batch", None, "model",
                                     batch_size=shape.global_batch)), cspecs)
            jitted = jax.jit(
                fn,
                in_shardings=(pspecs, cspecs, NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, t_spec)),
                out_shardings=out_specs,
                donate_argnums=(1,))
            lowered = jitted.lower(pshapes, cshapes, tok, t)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)

    # ---- memory analysis (proves the cell fits per-device HBM)
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        rec["memory"]["total_bytes"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"])
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)[:200]}

    # ---- cost analysis (per-device partitioned module)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        bytes_ = float(ca.get("bytes accessed", 0.0))
        rec["cost"] = {"flops": flops, "bytes_accessed": bytes_}
    except Exception as e:  # pragma: no cover
        flops = bytes_ = 0.0
        rec["cost"] = {"error": str(e)[:200]}

    # ---- collective bytes from the partitioned HLO
    hlo = compiled.as_text()
    coll = roofline.parse_collectives(hlo)
    rec["collectives"] = {
        "wire_bytes": coll.wire_bytes,
        "count": coll.count,
        "by_kind": coll.by_kind,
        "top": coll.top[:6],
    }

    # ---- roofline terms
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = roofline.model_flops(cfg, shape.kind, tokens)
    rec["roofline"] = roofline.terms(flops, bytes_, coll.wire_bytes)
    rec["model_flops_global"] = mf
    rec["model_flops_per_chip"] = mf / n_chips
    if flops:
        rec["useful_flop_ratio"] = (mf / n_chips) / flops
    rec["status"] = "ok"
    return rec


def run_cell_with_probes(arch: str, shape_name: str, multi_pod: bool,
                         overrides: Optional[Dict[str, Any]] = None,
                         ) -> Dict[str, Any]:
    """Full-depth compile (memory proof) + two unrolled shallow compiles to
    reconstruct exact per-device costs: XLA's cost_analysis counts a while
    (scan) body ONCE, so per-layer cost = probe(L=2) - probe(L=1) and
    total = probe(L=1) + (L-1) * per_layer. Collective wire bytes parsed
    from HLO text have the same body-once property and get the same fix."""
    rec = run_cell(arch, shape_name, multi_pod, overrides)
    if rec.get("status") != "ok":
        return rec
    cfg = get_config(arch)
    L = (overrides or {}).get("n_layers", cfg.n_layers)
    probes = {}
    for l in (1, 2):
        po = dict(overrides or {})
        po.update(n_layers=l, scan_layers=False)
        probes[l] = run_cell(arch, shape_name, multi_pod, po)
    if any(probes[l].get("status") != "ok" for l in (1, 2)):
        rec["probe_error"] = {l: probes[l].get("error", probes[l].get("status"))
                              for l in (1, 2)}
        return rec

    def corrected(path_get):
        v1, v2 = path_get(probes[1]), path_get(probes[2])
        return v1 + (L - 1) * (v2 - v1)

    flops = corrected(lambda r: r["cost"]["flops"])
    bytes_ = corrected(lambda r: r["cost"]["bytes_accessed"])
    wire = corrected(lambda r: r["collectives"]["wire_bytes"])
    rec["cost_corrected"] = {
        "flops": flops, "bytes_accessed": bytes_, "wire_bytes": wire,
        "per_layer_flops": (probes[2]["cost"]["flops"]
                            - probes[1]["cost"]["flops"]),
    }
    rec["roofline"] = roofline.terms(flops, bytes_, wire)
    if flops:
        rec["useful_flop_ratio"] = rec["model_flops_per_chip"] / flops
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf exps)")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the L=1/L=2 cost-correction probes")
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    overrides = json.loads(args.override) if args.override else None

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}"
                t0 = time.time()
                try:
                    # cost probes only on the single-pod mesh (the roofline
                    # table is single-pod; multi-pod proves sharding)
                    if mp or args.no_probes:
                        rec = run_cell(arch, shape, mp, overrides)
                    else:
                        rec = run_cell_with_probes(arch, shape, mp, overrides)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": str(e)[:500],
                           "trace": traceback.format_exc()[-2000:]}
                rec["wall_s"] = round(time.time() - t0, 1)
                if overrides:
                    rec["overrides"] = overrides
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                print(f"[{rec.get('status'):7s}] {key} "
                      f"({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()

"""Step-function factories shared by train.py, serve.py and dryrun.py."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.model import LM
from ..optim import adamw


def make_train_step(lm: LM, opt_cfg: adamw.AdamWConfig | None = None,
                    ) -> Callable:
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    # constrain grads to the ZeRO-1 (data-sharded) optimizer-state layout:
    # the partitioner then emits reduce-scatter of grads over the data axes
    # instead of full all-reduce + local slice (measured on yi_34b, §Perf)
    grad_specs = None
    if lm.cfg.zero1:
        grad_specs = adamw.state_specs(
            lm.param_specs(), lm.param_shapes(), lm.mesh, zero1=True)["m"]

    def _grad(params, batch):
        a = lm.cfg.accum_steps
        if a <= 1:
            return jax.value_and_grad(lm.loss_fn, has_aux=True)(params, batch)
        # microbatch accumulation: scan over A slices of the global batch;
        # activations exist for one microbatch at a time (A-fold smaller
        # temps), grads accumulate in f32
        def slice_batch(i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // a), x.shape[0] // a, axis=0), batch)

        def body(carry, i):
            acc, loss_sum, aux_sum = carry
            (loss, metrics), g = jax.value_and_grad(
                lm.loss_fn, has_aux=True)(params, slice_batch(i))
            acc = jax.tree.map(
                lambda s, x: s + x.astype(jnp.float32) / a, acc, g)
            return (acc, loss_sum + loss / a,
                    aux_sum + metrics["aux"] / a), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss, aux), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)),
            jnp.arange(a))
        return (loss, {"ce": loss, "aux": aux}), grads

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = _grad(params, batch)
        if lm.cfg.grad_barrier:
            # keep the DP grad reduction in the grads' own (bf16) dtype:
            # without the barrier XLA hoists the optimizer's f32 convert
            # above the all-reduce (2x wire)
            grads = jax.lax.optimization_barrier(grads)
        if grad_specs is not None:
            from jax.sharding import PartitionSpec as P
            flat_g, treedef = jax.tree.flatten(grads)
            flat_s = jax.tree.leaves(
                grad_specs, is_leaf=lambda x: isinstance(x, P))
            grads = treedef.unflatten([
                jax.lax.with_sharding_constraint(g, sp)
                for g, sp in zip(flat_g, flat_s)])
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(lm: LM) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, batch.get("tokens"), batch.get("embeds"))
    return prefill_step


def make_decode_step(lm: LM) -> Callable:
    def decode_step(params, cache, tokens, t):
        return lm.decode_step(params, cache, tokens, t)
    return decode_step

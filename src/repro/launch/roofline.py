"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOPs            (per device)
    memory term     = HLO_bytes / HBM_bw                (per device)
    collective term = wire_bytes_per_device / link_bw

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the SPMD-
partitioned per-device module). Collective wire bytes are parsed from the
compiled HLO text with ring-algorithm cost models:

    all-reduce          2 * (g-1)/g * result_bytes
    all-gather          (g-1)/g * result_bytes        (result = gathered)
    reduce-scatter      (g-1)   * result_bytes        (result = scattered)
    all-to-all          (g-1)/g * result_bytes
    collective-permute  result_bytes

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[total]
        return int(m.group(2))
    return 1


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: Dict[str, float] = field(default_factory=dict)
    count: int = 0
    top: List[Tuple[str, float]] = field(default_factory=list)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    tops: List[Tuple[str, float]] = []
    for line in hlo_text.splitlines():
        if "-done" in line and "fusion" not in line:
            # -start carries the type; -done duplicates it
            if re.search(r"(all-reduce|all-gather|reduce-scatter|"
                         r"all-to-all|collective-permute)-done", line):
                continue
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        size = _type_bytes(type_str)
        g = _group_size(line)
        if kind == "all-reduce":
            wire = 2.0 * (g - 1) / max(g, 1) * size
        elif kind == "all-gather":
            wire = (g - 1) / max(g, 1) * size
        elif kind == "reduce-scatter":
            wire = float(g - 1) * size
        elif kind == "all-to-all":
            wire = (g - 1) / max(g, 1) * size
        else:  # collective-permute
            wire = float(size)
        st.wire_bytes += wire
        st.by_kind[kind] = st.by_kind.get(kind, 0.0) + wire
        st.count += 1
        tops.append((f"{kind} g={g} {type_str[:60]}", wire))
    tops.sort(key=lambda t: -t[1])
    st.top = tops[:12]
    return st


def terms(hlo_flops: float, hlo_bytes: float, wire_bytes: float,
          ) -> Dict[str, float]:
    t = {
        "compute_s": hlo_flops / PEAK_FLOPS,
        "memory_s": hlo_bytes / HBM_BW,
        "collective_s": wire_bytes / ICI_BW,
    }
    t["bottleneck"] = max(t, key=lambda k: t[k])  # type: ignore[assignment]
    t["step_s"] = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return t


# ------------------------------------------------------- model FLOP count
def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Analytic parameter counts: total, active (MoE top-k), embedding."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    per_layer = 0.0
    per_layer_active = 0.0
    if not cfg.is_attention_free:
        attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        per_layer += attn
        per_layer_active += attn
    if cfg.has_ssm:
        di = cfg.ssm_heads * cfg.ssm_head_dim
        ssm = 2 * d * di + 2 * d * cfg.ssm_state + d * cfg.ssm_heads + di * d
        per_layer += ssm
        per_layer_active += ssm
    if cfg.n_experts:
        router = d * cfg.n_experts
        experts = cfg.n_experts * 3 * d * f
        shared = cfg.n_shared_experts * 3 * d * f
        per_layer += router + experts + shared
        per_layer_active += router + cfg.top_k * 3 * d * f + shared
    elif f:
        per_layer += 3 * d * f
        per_layer_active += 3 * d * f
    embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return {
        "total": cfg.n_layers * per_layer + embed,
        "active": cfg.n_layers * per_layer_active,  # excl. embed/lm_head
        "embed": embed,
    }


def model_flops(cfg: ModelConfig, kind: str, tokens: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference, with N the
    active non-embedding parameters (lm_head matmul added separately)."""
    n = param_counts(cfg)["active"]
    head = cfg.d_model * cfg.vocab  # lm_head matmul params
    mult = 6.0 if kind == "train" else 2.0
    return mult * (n + head) * tokens

"""Batched serving driver: greedy decode for a batch of requests.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen_large \
        --smoke --batch 4 --steps 16

The same decode_step is what launch/dryrun.py lowers for the decode_32k /
long_500k shapes on the 512-chip production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.model import LM
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen_large")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--window", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    prompt_len = 8
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        # prefill the prompt batch, then decode continuations from the cache
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, prompt_len), 0, cfg.vocab)
        lg, cache = jax.jit(lambda p, t: lm.prefill_with_cache(
            p, t, window=args.window))(params, prompt)
        dec = jax.jit(lm.decode_step, donate_argnums=(1,))
        tok = jnp.argmax(lg[:, :, :cfg.vocab], -1).astype(jnp.int32)
        t0 = time.time()
        outs = []
        for t in range(prompt_len, prompt_len + args.steps):
            lg, cache = dec(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(lg[:, :, :cfg.vocab], -1).astype(jnp.int32)
            outs.append(tok[:, 0])
        dt = time.time() - t0
    seqs = jnp.stack(outs, axis=1)
    print(f"decoded {args.steps} tokens x {args.batch} requests "
          f"in {dt:.2f}s ({args.batch*args.steps/dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  req{b}: {list(map(int, seqs[b][:12]))}...")


if __name__ == "__main__":
    main()

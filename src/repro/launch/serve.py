"""Batched serving driver: greedy decode for a batch of requests.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen_large \
        --smoke --batch 4 --steps 16

The same decode_step is what launch/dryrun.py lowers for the decode_32k /
long_500k shapes on the 512-chip production meshes.

``--offload-cgra SIZE`` additionally maps the architecture's
representative scalar inner loops onto a CGRA sidecar at startup through
the process-wide :class:`repro.core.service.MappingService` — the same
pool/cache every other driver in this process shares, so repeated serve
launches (and the map_cgra report) reuse warm solver sessions instead of
re-solving from scratch.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.model import LM
from .mesh import make_host_mesh


def offload_report(cfg, cgra_name: str) -> None:
    """Map the arch's offloadable inner loops via the shared service —
    one ``compile(MapRequest(...))`` per loop, ``service="default"``
    resolving to the same process-wide pool every driver shares. The
    fabric name takes the full grammar (``4x4``, ``4x4-torus:r8``, ...)."""
    from ..core.api import MapRequest, compile as compile_request
    from ..core.arch import arch
    from ..core.frontend import trace_loop_body
    from ..core.service import get_service
    from .map_cgra import loops_for

    fabric = arch(cgra_name)
    print(f"CGRA offload ({fabric}) via MappingService:")
    for name, fn, n_carry, loads in loops_for(cfg):
        g, _ = trace_loop_body(fn, n_carry=n_carry, loads=loads, name=name)
        r = compile_request(MapRequest(dfg=g, arch=fabric, timeout_s=60,
                                       service="default"))
        status = f"II={r.ii}" if r.success else "NO MAPPING"
        print(f"  {name:16s} {status} via={r.service.via} "
              f"pruned={r.service.iis_pruned} "
              f"[{r.service.request_time*1e3:.1f}ms]")
    print(f"  service: {get_service().describe()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen_large")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--offload-cgra", default=None, metavar="RxC",
                    help="also map this arch's scalar inner loops onto a "
                         "CGRA sidecar (e.g. 4x4) through the shared "
                         "MappingService before serving")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.offload_cgra:
        offload_report(cfg, args.offload_cgra)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    prompt_len = 8
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        # prefill the prompt batch, then decode continuations from the cache
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, prompt_len), 0, cfg.vocab)
        lg, cache = jax.jit(lambda p, t: lm.prefill_with_cache(
            p, t, window=args.window))(params, prompt)
        dec = jax.jit(lm.decode_step, donate_argnums=(1,))
        tok = jnp.argmax(lg[:, :, :cfg.vocab], -1).astype(jnp.int32)
        t0 = time.time()
        outs = []
        for t in range(prompt_len, prompt_len + args.steps):
            lg, cache = dec(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(lg[:, :, :cfg.vocab], -1).astype(jnp.int32)
            outs.append(tok[:, 0])
        dt = time.time() - t0
    seqs = jnp.stack(outs, axis=1)
    print(f"decoded {args.steps} tokens x {args.batch} requests "
          f"in {dt:.2f}s ({args.batch*args.steps/dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  req{b}: {list(map(int, seqs[b][:12]))}...")


if __name__ == "__main__":
    main()

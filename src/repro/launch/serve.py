"""Serving front doors: the async batched *compile* server and the
batched LM decode driver.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen_large \
        --smoke --batch 4 --steps 16

The same decode_step is what launch/dryrun.py lowers for the decode_32k /
long_500k shapes on the 512-chip production meshes.

``--offload-cgra SIZE`` additionally maps the architecture's
representative scalar inner loops onto a CGRA sidecar at startup through
the process-wide :class:`repro.core.service.MappingService` — the same
pool/cache every other driver in this process shares, so repeated serve
launches (and the map_cgra report) reuse warm solver sessions instead of
re-solving from scratch.

:class:`CompileFrontDoor` is the mapping-as-a-service tier (tentpole of
the serving PR): an asyncio front door that accepts ``compile``-shaped
requests from thousands of concurrent clients, micro-batches them in a
short window, coalesces identical requests, routes each family to its
affinity shard in a :class:`repro.core.workers.WorkerPool` (JetStream-
style continuous batching: the event loop keeps admitting requests while
the worker processes grind), enforces per-request deadlines, and exerts
backpressure through a bounded queue. Drive it with
``benchmarks/serve_load.py``; jax is imported lazily so the compile tier
works in jax-free (and fork-happy) processes.
"""
from __future__ import annotations

import argparse
import asyncio
import time
from dataclasses import astuple, dataclass
from typing import Dict, Hashable, List, Optional


class DeadlineExceeded(Exception):
    """A request's per-request deadline elapsed before its result."""


@dataclass
class ServeStats:
    """Front-door counters (client latency percentiles live in
    ``benchmarks/serve_load.py`` — the server only counts what it alone
    can see: batching, coalescing, backpressure, deadlines)."""
    submitted: int = 0
    served: int = 0
    failed: int = 0
    batches: int = 0
    coalesced: int = 0           # requests served by another's solve
    deadline_violations: int = 0
    queue_peak: int = 0
    max_batch_seen: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dict(self.__dict__)


@dataclass
class _Pending:
    key: Hashable
    dfg: object
    cgra: object
    cfg: object
    sweep_width: int
    use_cache: bool
    future: "asyncio.Future"


class CompileFrontDoor:
    """Async batched compile server over a :class:`WorkerPool`.

    ``await door.compile(dfg, cgra, ...)`` enqueues one request; a single
    batcher task drains the queue in ``window_ms`` micro-batches (up to
    ``max_batch``), coalesces identical cacheable requests onto one
    worker solve, and dispatches the rest to their affinity shards. The
    queue is bounded (``max_pending``): when the solvers fall behind,
    ``compile`` suspends *before* enqueueing — backpressure reaches the
    client as latency, never as an unbounded memory balloon. Each request
    carries a deadline (``deadline_s`` or the constructor default);
    expiry raises :class:`DeadlineExceeded` for that caller while the
    in-flight shard solve continues and still populates the caches.
    """

    def __init__(self, pool, window_ms: float = 4.0, max_batch: int = 64,
                 max_pending: int = 4096,
                 default_deadline_s: float = 120.0):
        self.pool = pool
        self.window_s = max(0.0, window_ms) / 1e3
        self.max_batch = max(1, max_batch)
        self.max_pending = max_pending
        self.default_deadline_s = default_deadline_s
        self.stats = ServeStats()
        self._queue: Optional[asyncio.Queue] = None
        self._batcher: Optional[asyncio.Task] = None
        self._closed = False

    # --------------------------------------------------------- lifecycle
    async def start(self) -> "CompileFrontDoor":
        self._queue = asyncio.Queue(maxsize=self.max_pending)
        self._closed = False
        self._batcher = asyncio.create_task(self._run())
        return self

    async def stop(self) -> None:
        self._closed = True
        if self._batcher is not None:
            self._batcher.cancel()
            try:
                await self._batcher
            except (asyncio.CancelledError, Exception):
                pass
            self._batcher = None

    async def __aenter__(self) -> "CompileFrontDoor":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # --------------------------------------------------------------- API
    async def compile(self, dfg, cgra, cfg=None, sweep_width: int = 1,
                      use_cache: bool = True,
                      deadline_s: Optional[float] = None):
        """One client request -> :class:`MappingResult` (or raises
        :class:`DeadlineExceeded`)."""
        from ..core.mapper import MapperConfig
        from ..core.service import dfg_signature, topology_signature
        if self._queue is None:
            raise RuntimeError("front door not started: call start() "
                               "before compile()")
        cfg = cfg or MapperConfig()
        deadline = time.monotonic() + (deadline_s
                                       if deadline_s is not None
                                       else self.default_deadline_s)
        key = (dfg_signature(dfg), topology_signature(cgra), astuple(cfg),
               sweep_width)
        fut = asyncio.get_running_loop().create_future()
        item = _Pending(key, dfg, cgra, cfg, sweep_width, use_cache, fut)
        self.stats.submitted += 1
        try:
            await asyncio.wait_for(self._queue.put(item),
                                   timeout=max(0.0,
                                               deadline - time.monotonic()))
            self.stats.queue_peak = max(self.stats.queue_peak,
                                        self._queue.qsize())
            res = await asyncio.wait_for(
                fut, timeout=max(0.0, deadline - time.monotonic()))
        except asyncio.TimeoutError:
            self.stats.deadline_violations += 1
            raise DeadlineExceeded(
                f"compile request missed its deadline "
                f"({deadline_s or self.default_deadline_s:.1f}s)") from None
        self.stats.served += 1
        return res

    # ----------------------------------------------------------- batcher
    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while not self._closed:
            try:
                first = await self._queue.get()
            except asyncio.CancelledError:
                return
            batch = [first]
            t_end = loop.time() + self.window_s
            while len(batch) < self.max_batch:
                rem = t_end - loop.time()
                if rem <= 0 and self._queue.empty():
                    break
                try:
                    batch.append(await asyncio.wait_for(
                        self._queue.get(), timeout=max(rem, 0.0)))
                except (asyncio.TimeoutError, asyncio.CancelledError):
                    break
            self.stats.batches += 1
            self.stats.max_batch_seen = max(self.stats.max_batch_seen,
                                            len(batch))
            self._dispatch(batch)

    def _dispatch(self, batch: List[_Pending]) -> None:
        # coalesce identical cacheable requests: one shard solve feeds
        # every waiter. use_cache=False requests are never coalesced —
        # each explicitly asked for its own solve.
        groups: "Dict[Hashable, List[_Pending]]" = {}
        singles: List[List[_Pending]] = []
        for p in batch:
            if p.use_cache:
                g = groups.setdefault(p.key, [])
                if g:
                    self.stats.coalesced += 1
                g.append(p)
            else:
                singles.append([p])
        # dispatch sorted by affinity shard so one micro-batch's
        # submissions to a shard's queue are contiguous (same-session
        # requests run back-to-back on their warm worker)
        work = list(groups.values()) + singles
        work.sort(key=lambda ps: self.pool.shard_of(
            ps[0].dfg, ps[0].cgra, ps[0].cfg))
        for members in work:
            lead = members[0]
            cf = self.pool.submit(lead.dfg, lead.cgra, lead.cfg,
                                  sweep_width=lead.sweep_width,
                                  use_cache=lead.use_cache)
            afut = asyncio.wrap_future(cf)
            asyncio.ensure_future(self._settle(afut, members))

    async def _settle(self, afut, members: List[_Pending]) -> None:
        try:
            res = await afut
        except Exception as exc:
            self.stats.failed += len(members)
            for p in members:
                if not p.future.done():
                    p.future.set_exception(exc)
            return
        for p in members:
            if not p.future.done():
                p.future.set_result(res)


def offload_report(cfg, cgra_name: str, guide: Optional[str] = None,
                   sweep_width: int = 1) -> None:
    """Map the arch's offloadable inner loops via the shared service —
    one ``compile(MapRequest(...))`` per loop, ``service="default"``
    resolving to the same process-wide pool every driver shares. The
    fabric name takes the full grammar (``4x4``, ``4x4-torus:r8``, ...).
    ``guide`` (a registered guide name or campaign ``.npz`` checkpoint)
    seeds the sweep windows when ``sweep_width > 1`` — learned guidance
    never changes the final II, only where the sweep starts looking."""
    from ..core.api import MapRequest, compile as compile_request
    from ..core.arch import arch
    from ..core.frontend import trace_loop_body
    from ..core.service import get_service
    from .map_cgra import loops_for

    fabric = arch(cgra_name)
    mode = f", guided sweep k={sweep_width}" if guide else ""
    print(f"CGRA offload ({fabric}) via MappingService{mode}:")
    for name, fn, n_carry, loads in loops_for(cfg):
        g, _ = trace_loop_body(fn, n_carry=n_carry, loads=loads, name=name)
        r = compile_request(MapRequest(dfg=g, arch=fabric, timeout_s=60,
                                       service="default", guide=guide,
                                       sweep_width=sweep_width))
        status = f"II={r.ii}" if r.success else "NO MAPPING"
        guid = getattr(r, "guidance", None)
        gtxt = (f" guide_offset={guid['offset']}"
                if guid and guid.get("used") else "")
        print(f"  {name:16s} {status} via={r.service.via} "
              f"pruned={r.service.iis_pruned} "
              f"[{r.service.request_time*1e3:.1f}ms]{gtxt}")
    print(f"  service: {get_service().describe()}")


def main() -> None:
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models.model import LM
    from .mesh import make_host_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen_large")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--offload-cgra", default=None, metavar="RxC",
                    help="also map this arch's scalar inner loops onto a "
                         "CGRA sidecar (e.g. 4x4) through the shared "
                         "MappingService before serving")
    ap.add_argument("--offload-guide", default=None, metavar="NAME_OR_NPZ",
                    help="learned II guidance for the offload mappings (a "
                         "registered guide name or a repro.launch.campaign "
                         ".npz checkpoint); implies a sweep_width=4 guided "
                         "sweep per loop, final IIs unchanged by contract")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.offload_cgra:
        offload_report(cfg, args.offload_cgra, guide=args.offload_guide,
                       sweep_width=4 if args.offload_guide else 1)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    prompt_len = 8
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        # prefill the prompt batch, then decode continuations from the cache
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, prompt_len), 0, cfg.vocab)
        lg, cache = jax.jit(lambda p, t: lm.prefill_with_cache(
            p, t, window=args.window))(params, prompt)
        dec = jax.jit(lm.decode_step, donate_argnums=(1,))
        tok = jnp.argmax(lg[:, :, :cfg.vocab], -1).astype(jnp.int32)
        t0 = time.time()
        outs = []
        for t in range(prompt_len, prompt_len + args.steps):
            lg, cache = dec(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(lg[:, :, :cfg.vocab], -1).astype(jnp.int32)
            outs.append(tok[:, 0])
        dt = time.time() - t0
    seqs = jnp.stack(outs, axis=1)
    print(f"decoded {args.steps} tokens x {args.batch} requests "
          f"in {dt:.2f}s ({args.batch*args.steps/dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  req{b}: {list(map(int, seqs[b][:12]))}...")


if __name__ == "__main__":
    main()

"""Mapping-campaign driver: corpus -> pool -> dataset -> guide -> gates.

    PYTHONPATH=src python -m repro.launch.campaign --quick --check

One invocation runs the whole data flywheel end to end:

  1. build the deduplicated DFG corpus (:mod:`repro.core.campaign`:
     suite kernels + seeded grammar DFGs + mutants, isomorphism-deduped);
  2. fan (corpus x fabric gallery) cells through a
     :class:`~repro.core.workers.WorkerPool` at ``sweep_width=1`` (clean
     per-II labels) and append one record per cell to the sharded
     campaign dataset under ``--out``;
  3. train the :mod:`repro.core.guide` MLP on the dataset, save it to
     ``<out>/guide.npz``, and register it as ``"campaign"``;
  4. evaluate — held-out hit@1 / hit@2 vs the always-start-at-MII
     baseline, and guided-vs-unguided *solver attempts* on held-out
     cells (the predictor must save work, not just score well);
  5. soundness gate — the guided sweep must return the bit-identical
     final II as the unguided sweep on every suite cell;
  6. optionally ``--compact`` the worker-pool mapping store (campaign
     traffic grows the WAL; compaction keeps only live records).

``--check`` turns the summary into CI gates (see :func:`check_gates`);
``--bench-out`` writes the summary JSON (``BENCH_campaign.json`` in CI).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.arch import ArchSpec, arch
from ..core.campaign import (CampaignDataset, CorpusItem, CorpusSpec,
                             build_corpus, cell_key, corpus_digest,
                             run_campaign)
from ..core.mapper import MapperConfig, map_loop
from ..core.store import MappingStore
from ..core.workers import WorkerPool

# suite gate fabrics: every suite kernel on each (33 cells with the
# 11-kernel suite) — the acceptance surface for guided == unguided
SUITE_GATE_SIZES = ("2x2", "3x3", "4x4")

HOLDOUT_BYTE = 64          # cell_key[0] < 64 => held out (~25%)


def build_gallery(spec: str) -> List[ArchSpec]:
    """Parse a comma-separated fabric gallery (full fabric grammar per
    entry: ``4x4``, ``3x3-torus:r8``, ``4x4-onehop``...)."""
    return [arch(s.strip()) for s in spec.split(",") if s.strip()]


def _holdout_cells(items: Sequence[CorpusItem], fabrics: Sequence,
                   cfg: MapperConfig,
                   ) -> List[Tuple[CorpusItem, object]]:
    """The (item, fabric) cells whose dataset records are held out of
    training — same content-keyed rule as guide.train_guide, computed
    from the datagen config so the split matches the dataset exactly."""
    out = []
    for item in items:
        for fabric in fabrics:
            if cell_key(item.key, fabric, cfg, 1)[0] < HOLDOUT_BYTE:
                out.append((item, fabric))
    return out


def eval_guided_attempts(cells: Sequence[Tuple[CorpusItem, object]],
                         guide_name: str, timeout_s: float,
                         sweep_width: int = 4,
                         ) -> Dict[str, float]:
    """Map each held-out cell twice in-process (fresh solver sessions, no
    cache — no warm-state bleed between the two modes) and compare total
    solver attempts. Also asserts the soundness contract on every pair:
    guided and unguided must agree on the final II."""
    att_guided = att_unguided = 0
    mismatches = []
    for item, fabric in cells:
        r0 = map_loop(item.dfg, fabric,
                      MapperConfig(timeout_s=timeout_s),
                      sweep_width=sweep_width)
        r1 = map_loop(item.dfg, fabric,
                      MapperConfig(timeout_s=timeout_s, guide=guide_name),
                      sweep_width=sweep_width)
        att_unguided += len(r0.attempts)
        att_guided += len(r1.attempts)
        if r0.ii != r1.ii:
            mismatches.append((item.name, str(fabric), r0.ii, r1.ii))
    return {"cells": len(cells), "attempts_unguided": att_unguided,
            "attempts_guided": att_guided,
            "attempts_saved": att_unguided - att_guided,
            "ii_mismatches": len(mismatches)}


def suite_gate(guide_name: str, pool: WorkerPool, timeout_s: float,
               sweep_width: int = 4,
               sizes: Sequence[str] = SUITE_GATE_SIZES,
               ) -> Dict[str, object]:
    """Guided final II == unguided final II on every suite cell. Runs
    both modes through the pool (workers resolve the guide from its .npz
    path); core-pruned IIs may differ between runs — warm sessions prune
    refuted IIs — but the final II must be bit-identical."""
    from ..core import suite
    futs = []
    for size in sizes:
        fabric = arch(size)
        for name in suite.names():
            g = suite.get(name)
            f0 = pool.submit(g, fabric, MapperConfig(timeout_s=timeout_s),
                             sweep_width=sweep_width)
            f1 = pool.submit(g, fabric, MapperConfig(timeout_s=timeout_s,
                                                     guide=guide_name),
                             sweep_width=sweep_width)
            futs.append((name, size, f0, f1))
    mismatches = []
    for name, size, f0, f1 in futs:
        ii0 = f0.result().ii
        ii1 = f1.result().ii
        if ii0 != ii1:
            mismatches.append({"kernel": name, "fabric": size,
                               "unguided_ii": ii0, "guided_ii": ii1})
    return {"cells": len(futs), "mismatches": mismatches,
            "ok": not mismatches}


def run(seed: int = 0, out: str = "campaign_out", workers: int = 2,
        n_random: int = 64, n_mutants: int = 40,
        fabrics: str = "2x2,3x3,4x4", timeout_s: float = 25.0,
        sweep_width: int = 4, eval_cells: int = 48,
        compact: bool = False, skip_train: bool = False,
        suite_sizes: Sequence[str] = SUITE_GATE_SIZES) -> Dict:
    """The full campaign pipeline; returns the summary dict (see module
    docstring for the stages)."""
    t_start = time.time()
    os.makedirs(out, exist_ok=True)
    store_path = os.path.join(out, "store")
    guide_path = os.path.join(out, "guide.npz")

    spec = CorpusSpec(seed=seed, n_random=n_random, n_mutants=n_mutants)
    items, corpus_stats = build_corpus(spec)
    gallery = build_gallery(fabrics)
    dedup_rate = corpus_stats["duplicates"] / max(1, corpus_stats["generated"])
    print(f"corpus: {corpus_stats['unique']} unique DFGs "
          f"({corpus_stats['duplicates']} duplicates collapsed, "
          f"dedup rate {dedup_rate:.1%}); digest "
          f"{corpus_digest(items)[:16]}")

    datagen_cfg = MapperConfig(timeout_s=timeout_s)
    dataset = CampaignDataset(os.path.join(out, "cells"))
    summary: Dict = {
        "seed": seed, "corpus": corpus_stats,
        "dedup_rate": dedup_rate,
        "corpus_digest": corpus_digest(items),
        "fabrics": [str(f) for f in gallery],
    }

    with WorkerPool(workers=workers, store_path=store_path) as pool:
        stats, records = run_campaign(items, gallery, pool, dataset,
                                      datagen_cfg, sweep_width=1)
        print(f"campaign: {stats.cells} cells "
              f"({stats.mapped} mapped, {stats.failed} refuted, "
              f"{stats.infeasible} infeasible, {stats.witnesses} UNSAT "
              f"witnesses) at {stats.cells_per_sec:.1f} cells/s")
        summary["campaign"] = stats.snapshot()
        summary["dataset"] = dataset.describe()
        summary["dataset_roundtrip_ok"] = (
            summary["dataset"]["cells"] == stats.cells)

        if not skip_train:
            # train in the driver process — the pool forked long ago, so
            # initialising jax here never races a fork
            from ..core.guide import register_guide, train_guide
            guide, metrics = train_guide(records, seed=seed,
                                         holdout_byte=HOLDOUT_BYTE)
            guide.save(guide_path)
            register_guide("campaign", guide)
            print(f"guide: trained on {metrics['n_train']} cells, "
                  f"held-out hit@1 {metrics['hit1']:.2f} / hit@2 "
                  f"{metrics['hit2']:.2f} (always-MII baseline "
                  f"{metrics['baseline_hit1']:.2f})")
            summary["guide"] = metrics
            summary["guide_path"] = guide_path

            held = _holdout_cells(items, gallery, datagen_cfg)
            held = [c for c in held if c[0].kind != "suite"][:eval_cells]
            ev = eval_guided_attempts(held, "campaign", timeout_s,
                                      sweep_width)
            print(f"eval: {ev['cells']} held-out cells, attempts "
                  f"{ev['attempts_unguided']} unguided -> "
                  f"{ev['attempts_guided']} guided "
                  f"({ev['attempts_saved']} saved), "
                  f"{ev['ii_mismatches']} II mismatches")
            summary["eval"] = ev

            # workers resolve the guide from disk (their registries are
            # empty — they forked before training)
            gate = suite_gate(guide_path, pool, timeout_s, sweep_width,
                              sizes=suite_sizes)
            print(f"suite gate: {gate['cells']} cells, "
                  f"{'OK' if gate['ok'] else 'MISMATCH: ' + str(gate['mismatches'])}")
            summary["suite_gate"] = gate

    if compact:
        store = MappingStore(store_path)
        cstats = store.compact()
        print(f"store compacted: {cstats['bytes_before']} -> "
              f"{cstats['bytes_after']} bytes "
              f"({cstats['records_dropped']} dropped)")
        summary["compaction"] = cstats

    summary["wall_s"] = time.time() - t_start
    return summary


def check_gates(summary: Dict, min_cells: int = 200) -> List[str]:
    """The CI gates (empty list = pass): enough cells through the pool,
    dedup observed, dataset round-trips, the predictor saves solver
    attempts on held-out cells, and the suite soundness gate holds."""
    errs = []
    if summary["campaign"]["cells"] < min_cells:
        errs.append(f"only {summary['campaign']['cells']} cells mapped "
                    f"(need >= {min_cells})")
    if summary["corpus"]["duplicates"] <= 0:
        errs.append("corpus dedup collapsed nothing (expected relabel "
                    "mutants to dedup)")
    if not summary.get("dataset_roundtrip_ok"):
        errs.append(f"dataset round-trip mismatch: "
                    f"{summary['dataset']['cells']} cells read back vs "
                    f"{summary['campaign']['cells']} mapped")
    if summary["campaign"]["errors"]:
        errs.append(f"{summary['campaign']['errors']} worker errors")
    ev = summary.get("eval")
    if ev is not None:
        if ev["ii_mismatches"]:
            errs.append(f"{ev['ii_mismatches']} guided-vs-unguided II "
                        f"mismatches on held-out cells")
        if ev["attempts_guided"] >= ev["attempts_unguided"]:
            errs.append(f"guided sweep saved no attempts "
                        f"({ev['attempts_guided']} vs "
                        f"{ev['attempts_unguided']})")
    gate = summary.get("suite_gate")
    if gate is not None and not gate["ok"]:
        errs.append(f"suite soundness gate failed: {gate['mismatches']}")
    return errs


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="mass mapping campaign + learned II guidance")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: ~200+ cells, 2 workers")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every gate passes "
                         "(cells, dedup, round-trip, attempts saved, "
                         "suite soundness)")
    ap.add_argument("--out", default="campaign_out",
                    help="output directory (dataset shards, store, "
                         "guide.npz)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-random", type=int, default=None,
                    help="grammar-generated DFGs in the corpus")
    ap.add_argument("--n-mutants", type=int, default=None,
                    help="mutation attempts over the corpus parents")
    ap.add_argument("--fabrics", default=None,
                    help="comma-separated fabric gallery "
                         "(full grammar per entry)")
    ap.add_argument("--sweep-width", type=int, default=4,
                    help="window width for the guided-eval and suite-gate "
                         "sweeps (datagen itself runs width 1)")
    ap.add_argument("--timeout-s", type=float, default=25.0)
    ap.add_argument("--eval-cells", type=int, default=None,
                    help="held-out cells for the attempts comparison")
    ap.add_argument("--compact", action="store_true",
                    help="compact the mapping store after the campaign")
    ap.add_argument("--skip-train", action="store_true",
                    help="dataset only: skip guide training and gates")
    ap.add_argument("--bench-out", default=None, metavar="JSON",
                    help="write the summary JSON here")
    args = ap.parse_args(argv)

    if args.quick:
        defaults = dict(workers=2, n_random=64, n_mutants=40,
                        fabrics="2x2,3x3,4x4", eval_cells=40)
    else:
        defaults = dict(workers=None, n_random=256, n_mutants=128,
                        fabrics="2x2,3x3,4x4,3x3-torus,4x4-onehop,"
                                "4x4:mem2,4x4-torus:r8",
                        eval_cells=96)
    summary = run(
        seed=args.seed, out=args.out,
        workers=(args.workers if args.workers is not None
                 else defaults["workers"]),
        n_random=(args.n_random if args.n_random is not None
                  else defaults["n_random"]),
        n_mutants=(args.n_mutants if args.n_mutants is not None
                   else defaults["n_mutants"]),
        fabrics=args.fabrics or defaults["fabrics"],
        timeout_s=args.timeout_s, sweep_width=args.sweep_width,
        eval_cells=(args.eval_cells if args.eval_cells is not None
                    else defaults["eval_cells"]),
        compact=args.compact, skip_train=args.skip_train)

    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(summary, f, indent=2, default=str)
        print(f"wrote {args.bench_out}")
    print(f"campaign done in {summary['wall_s']:.1f}s")
    if args.check:
        errs = check_gates(summary)
        if errs:
            raise SystemExit("campaign --check failed: " +
                             "; ".join(errs))
        print("campaign --check OK")


if __name__ == "__main__":
    main()

"""Disk-backed mapping cache + proven-UNSAT-core registry.

:class:`MappingStore` is the persistence layer of the serving tier: one
append-only write-ahead log (``store.log``) holding three record kinds,
all keyed by the SHA-256 of a canonical encoding of the existing
in-memory cache keys (``(topology_signature, shape_signature /
dfg_signature, config, ...)`` tuples — see :mod:`repro.core.service`):

  * **mapping records** — a served :class:`~repro.core.mapper.MappingResult`
    for one canonical request key. A cold process that opens the store
    starts with yesterday's mapping cache warm (``via="disk"`` hits).
  * **core records** — one proven-UNSAT II per record for a solver-session
    key: the failed-assumption core that refuted ``base + layer_ii``, plus
    (optionally) the refuted projection's clause arena as a self-certifying
    witness — ``verify_core`` re-solves the stored formula and confirms the
    recorded UNSAT, so a registry entry is checkable long after the session
    that produced it is gone. Loaded cores let a fresh session *skip* IIs
    proven infeasible by any earlier process (``via="core"`` attempts).
  * **arena records** — a raw ``(n_vars, lits, offs)`` CSR triple under an
    arbitrary key (the clause arena is the stack-wide interchange format;
    see ``ClauseArena.to_bytes``).

Durability/concurrency model: the log is the store — every mutation is one
appended record (header + CRC-checked payload), serialised across
processes by an exclusive ``flock`` on a sidecar lock file; readers take a
shared lock only while scanning newly appended bytes (``refresh``), so
many worker processes share one store directory safely. Torn tails (a
writer died mid-append) are truncated away on the next open/append;
*corrupted* bytes (bad magic / CRC inside a complete record) quarantine
the whole log — it is renamed aside and the store restarts empty rather
than crash the service or trust a garbled cache. Array payloads are
8-byte aligned so an mmap-holding reader can ``np.frombuffer`` the arena
segments without copying.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple
from zlib import crc32

import numpy as np

from .cnf import ArenaFormatError, CNF, ClauseArena

try:
    import fcntl
except ImportError:          # non-POSIX host: single-process store only
    fcntl = None

_MAGIC = b"SMS1"
# record header: magic | rtype u8 | pad[3] | key sha256 | payload_len u64 |
# payload crc32 u32 — 56 bytes, 8-byte aligned so aligned payloads stay
# aligned in the file
_HEAD = struct.Struct("<4sB3x32sQI4x")
RT_MAPPING, RT_CORE, RT_ARENA = 1, 2, 3

# core-record payload head: ii i64 | n_core i32 | has_arena u8 | pad[3] |
# n_vars u64
_CORE_HEAD = struct.Struct("<qiB3xQ")


class StoreCorruption(Exception):
    """Internal scan verdict: complete-but-invalid bytes in the log."""


# ------------------------------------------------------------- log framing
# The record framing is shared infrastructure: MappingStore's WAL and the
# campaign dataset shards (repro.core.campaign) are both sequences of these
# frames, so torn-tail tolerance and CRC screening behave identically in
# every log this repo writes.


def write_framed(f, rtype: int, key: bytes, payload: bytes) -> int:
    """Append one framed record (header + payload + 8-byte-alignment pad)
    to an open binary file; returns the number of bytes written."""
    head = _HEAD.pack(_MAGIC, rtype, key, len(payload),
                      crc32(payload) & 0xFFFFFFFF)
    pad = b"\x00" * ((-len(payload)) % 8)
    f.write(head + payload + pad)
    return len(head) + len(payload) + len(pad)


def iter_framed(path: str, start: int = 0):
    """Yield ``(rtype, key, payload, record_off, end_off)`` for every
    complete record in ``[start, EOF)``. A torn tail (partial header or
    payload — a writer died mid-append) ends iteration silently; the
    caller detects it by comparing the last ``end_off`` against the file
    size. Complete-but-invalid bytes raise :class:`StoreCorruption`."""
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        f.seek(start)
        pos = start
        while pos + _HEAD.size <= size:
            head = f.read(_HEAD.size)
            if len(head) < _HEAD.size:
                break                              # torn header
            magic, rtype, key, plen, crc = _HEAD.unpack(head)
            if magic != _MAGIC:
                raise StoreCorruption(f"bad record magic at {pos}")
            padded = plen + (-plen) % 8
            if pos + _HEAD.size + padded > size:
                break                              # torn payload
            payload = f.read(padded)[:plen]
            if crc32(payload) & 0xFFFFFFFF != crc:
                raise StoreCorruption(f"payload CRC mismatch at {pos}")
            end = pos + _HEAD.size + padded
            yield rtype, key, payload, pos, end
            pos = end


def canonical_bytes(obj) -> bytes:
    """Deterministic byte encoding of the nested-tuple cache keys.

    Handles exactly the types the service keys contain (ints, floats,
    strings, bools, None, bytes, nested tuples/lists, frozensets — the
    last sorted by element encoding so set iteration order never leaks
    into the key). Raises ``TypeError`` on anything else rather than
    fall back to ``repr``/``pickle``, whose output is not canonical."""
    if obj is None:
        return b"N"
    if obj is True:
        return b"T"
    if obj is False:
        return b"F"
    if isinstance(obj, int):
        return b"i" + str(obj).encode()
    if isinstance(obj, float):
        return b"f" + struct.pack("<d", obj)
    if isinstance(obj, str):
        raw = obj.encode()
        return b"s" + str(len(raw)).encode() + b":" + raw
    if isinstance(obj, bytes):
        return b"b" + str(len(obj)).encode() + b":" + obj
    if isinstance(obj, (tuple, list)):
        return b"(" + b",".join(canonical_bytes(x) for x in obj) + b")"
    if isinstance(obj, frozenset):
        return b"{" + b",".join(sorted(canonical_bytes(x)
                                       for x in obj)) + b"}"
    raise TypeError(f"cannot canonicalise {type(obj).__name__} in store key")


def key_hash(key: Hashable) -> bytes:
    """SHA-256 digest of the canonical encoding — the on-disk key."""
    return hashlib.sha256(canonical_bytes(key)).digest()


@dataclass
class StoreStats:
    mappings_written: int = 0
    mappings_read: int = 0
    cores_written: int = 0
    arenas_written: int = 0
    refreshes: int = 0
    torn_tail_truncated: int = 0
    quarantined: int = 0
    write_errors: int = 0
    compactions: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dict(self.__dict__)


@dataclass
class _CoreRec:
    ii: int
    core: Tuple[int, ...]
    # (offset, length) of the optional arena witness blob + its n_vars
    witness: Optional[Tuple[int, int, int]] = None


class MappingStore:
    """Shared disk store under ``path`` (a directory; created if absent).

    Thread-safe (one internal lock) and multi-process-safe (``flock`` on
    ``store.lock``); every worker opens its own instance on the same
    directory. ``readonly=True`` never appends (useful for inspection).
    """

    def __init__(self, path: str, readonly: bool = False,
                 fsync: bool = False):
        self.path = os.path.abspath(path)
        self.readonly = readonly
        self.fsync = fsync
        os.makedirs(self.path, exist_ok=True)
        self.log_path = os.path.join(self.path, "store.log")
        self._lock_path = os.path.join(self.path, "store.lock")
        self._lock = threading.RLock()
        self.stats = StoreStats()
        # key hash -> (offset, payload_len) of the *latest* record
        self._mappings: Dict[bytes, Tuple[int, int]] = {}
        self._arenas: Dict[bytes, Tuple[int, int]] = {}
        # session key hash -> {ii: core record}
        self._cores: Dict[bytes, Dict[int, _CoreRec]] = {}
        self._scanned = 0          # bytes of the log already indexed
        if not os.path.exists(self.log_path) and not readonly:
            with open(self.log_path, "ab"):
                pass
        self.refresh()

    # ------------------------------------------------------------ locking
    def _flock(self, exclusive: bool):
        """Cross-process advisory lock context (no-op without fcntl)."""
        return _FileLock(self._lock_path, exclusive)

    # ----------------------------------------------------------- scanning
    def _index_record(self, rtype: int, key: bytes, off: int, length: int,
                      payload: bytes) -> None:
        if rtype == RT_MAPPING:
            self._mappings[key] = (off, length)
        elif rtype == RT_ARENA:
            self._arenas[key] = (off, length)
        elif rtype == RT_CORE:
            ii, n_core, has_arena, n_vars = _CORE_HEAD.unpack_from(payload)
            lits_end = _CORE_HEAD.size + 4 * n_core
            core = tuple(np.frombuffer(payload, dtype="<i4", count=n_core,
                                       offset=_CORE_HEAD.size).tolist())
            witness = None
            if has_arena:
                # witness blob sits 8-byte aligned after the core literals
                w_off = lits_end + (-lits_end) % 8
                witness = (off + w_off, length - w_off, int(n_vars))
            self._cores.setdefault(key, {})[ii] = _CoreRec(ii, core, witness)
        # unknown rtypes are skipped (forward compatibility)

    def _scan_from(self, start: int) -> None:
        """Index records in ``[start, EOF)``; tolerate a torn tail, raise
        :class:`StoreCorruption` on complete-but-invalid bytes."""
        size = os.path.getsize(self.log_path)
        if size <= start:
            self._scanned = max(self._scanned, size if start <= size
                                else self._scanned)
            return
        pos = start
        for rtype, key, payload, off, end in iter_framed(self.log_path,
                                                         start):
            self._index_record(rtype, key, off + _HEAD.size, len(payload),
                               payload)
            pos = end
        if pos < size:
            self.stats.torn_tail_truncated += 1
        self._scanned = pos

    def _quarantine(self) -> None:
        """Move the corrupt log aside and restart empty (service keeps
        running; the quarantined file is kept for post-mortem)."""
        dst = f"{self.log_path}.corrupt-{os.getpid()}-{int(time.time())}"
        try:
            os.replace(self.log_path, dst)
        except OSError:
            pass
        self._mappings.clear()
        self._arenas.clear()
        self._cores.clear()
        self._scanned = 0
        self.stats.quarantined += 1
        if not self.readonly:
            with open(self.log_path, "ab"):
                pass

    def refresh(self) -> None:
        """Index any records other writers appended since the last scan."""
        with self._lock:
            self.stats.refreshes += 1
            try:
                with self._flock(exclusive=False):
                    self._scan_from(self._scanned)
            except StoreCorruption:
                with self._flock(exclusive=True):
                    self._quarantine()
            except FileNotFoundError:
                self._scanned = 0

    # ------------------------------------------------------------ writing
    def _append(self, rtype: int, key: bytes, payload: bytes) -> bool:
        if self.readonly:
            return False
        with self._lock:
            try:
                with self._flock(exclusive=True):
                    # index (and validate) everything written since our
                    # last scan, then drop any torn tail before appending
                    try:
                        self._scan_from(self._scanned)
                    except StoreCorruption:
                        self._quarantine()
                    with open(self.log_path, "r+b" if os.path.exists(
                            self.log_path) else "w+b") as f:
                        f.truncate(self._scanned)
                        f.seek(self._scanned)
                        off = self._scanned + _HEAD.size
                        written = write_framed(f, rtype, key, payload)
                        f.flush()
                        if self.fsync:
                            os.fsync(f.fileno())
                    self._index_record(rtype, key, off, len(payload),
                                       payload)
                    self._scanned += written
                return True
            except OSError:
                self.stats.write_errors += 1
                return False

    def _read_payload(self, off: int, length: int) -> Optional[bytes]:
        try:
            with open(self.log_path, "rb") as f:
                f.seek(off)
                data = f.read(length)
            return data if len(data) == length else None
        except OSError:
            return None

    # ----------------------------------------------------------- mappings
    def put_mapping(self, key: Hashable, result) -> bool:
        """Persist one served result under its canonical request key."""
        payload = pickle.dumps(_trim_result(result),
                               protocol=pickle.HIGHEST_PROTOCOL)
        ok = self._append(RT_MAPPING, key_hash(key), payload)
        if ok:
            self.stats.mappings_written += 1
        return ok

    def get_mapping(self, key: Hashable):
        """The stored result for ``key``, or None. A miss re-scans the log
        tail once so hits from concurrent writer processes are visible."""
        kh = key_hash(key)
        with self._lock:
            loc = self._mappings.get(kh)
            if loc is None:
                self.refresh()
                loc = self._mappings.get(kh)
            if loc is None:
                return None
            payload = self._read_payload(*loc)
        if payload is None:
            return None
        try:
            res = pickle.loads(payload)
        except Exception:
            # a record that indexed clean but unpickles dirty: treat as a
            # miss (the CRC already screens bit rot; this guards version
            # skew between writer and reader processes)
            return None
        self.stats.mappings_read += 1
        return res

    @property
    def n_mappings(self) -> int:
        with self._lock:
            return len(self._mappings)

    # -------------------------------------------------------------- cores
    def put_core(self, session_key: Hashable, ii: int,
                 core: Tuple[int, ...],
                 witness: Optional[CNF] = None) -> bool:
        """Record a proven-UNSAT II for a session key. ``witness`` (the
        refuted per-II projection) makes the record self-certifying — see
        :meth:`verify_core`."""
        core_arr = np.asarray(list(core), dtype="<i4")
        blob = b""
        n_vars = 0
        if witness is not None:
            blob = witness.arena.to_bytes()
            n_vars = witness.n_vars
        head = _CORE_HEAD.pack(ii, core_arr.size, 1 if witness is not None
                               else 0, n_vars)
        body = head + core_arr.tobytes()
        body += b"\x00" * ((-len(body)) % 8) + blob
        ok = self._append(RT_CORE, key_hash(session_key), body)
        if ok:
            self.stats.cores_written += 1
        return ok

    def cores_for(self, session_key: Hashable) -> Dict[int, Tuple[int, ...]]:
        """Every proven-UNSAT II recorded for ``session_key`` (by any
        process, ever): ``{ii: failed-assumption core}``."""
        kh = key_hash(session_key)
        with self._lock:
            if kh not in self._cores:
                self.refresh()
            recs = self._cores.get(kh, {})
            return {ii: r.core for ii, r in recs.items()}

    def core_witness(self, session_key: Hashable, ii: int,
                     ) -> Optional[Tuple[int, ClauseArena]]:
        """The stored ``(n_vars, arena)`` of the projection refuted at
        ``ii``, when the writer attached one."""
        with self._lock:
            rec = self._cores.get(key_hash(session_key), {}).get(ii)
            if rec is None or rec.witness is None:
                return None
            off, length, n_vars = rec.witness
            blob = self._read_payload(off, length)
        if blob is None:
            return None
        try:
            return n_vars, ClauseArena.from_bytes(blob)
        except ArenaFormatError:
            return None

    def verify_core(self, session_key: Hashable, ii: int) -> Optional[bool]:
        """Re-solve the stored witness formula and check the recorded
        refutation: True = witness is UNSAT as claimed, False = the store
        holds a wrong verdict, None = no witness recorded."""
        got = self.core_witness(session_key, ii)
        if got is None:
            return None
        n_vars, arena = got
        from .sat.cdcl import solve_arena_worker
        status, _ = solve_arena_worker(n_vars, arena.lits_view(),
                                       arena.offs_view())
        return status == "UNSAT"

    # ------------------------------------------------------------- arenas
    def put_arena(self, key: Hashable, n_vars: int,
                  arena: ClauseArena) -> bool:
        body = struct.pack("<Q", n_vars) + arena.to_bytes()
        ok = self._append(RT_ARENA, key_hash(key), body)
        if ok:
            self.stats.arenas_written += 1
        return ok

    def get_arena(self, key: Hashable) -> Optional[Tuple[int, ClauseArena]]:
        with self._lock:
            loc = self._arenas.get(key_hash(key))
            if loc is None:
                self.refresh()
                loc = self._arenas.get(key_hash(key))
            if loc is None:
                return None
            payload = self._read_payload(*loc)
        if payload is None or len(payload) < 8:
            return None
        n_vars = struct.unpack_from("<Q", payload)[0]
        try:
            return int(n_vars), ClauseArena.from_bytes(payload[8:])
        except ArenaFormatError:
            return None

    # ---------------------------------------------------------- compaction
    def compact(self) -> Dict[str, int]:
        """Rewrite the append-only log keeping only *live* records: the
        latest mapping and arena per key and the latest core per
        (session key, II). Long campaigns overwrite the same cells over
        and over, and an append-only WAL grows without bound — compaction
        reclaims the dead versions while preserving every current
        ``key -> value`` lookup bit-for-bit (witness blobs included; their
        offsets are re-derived by the post-rewrite rescan).

        The rewrite goes to a temp file in the store directory and lands
        via ``os.replace`` under the exclusive cross-process lock, so
        concurrent readers either see the old log or the complete new one,
        never a half-written hybrid. A log that scans corrupt is
        quarantined exactly as ``refresh`` would have done. Returns
        ``{bytes_before, bytes_after, records_kept, records_dropped}``."""
        out = {"bytes_before": 0, "bytes_after": 0, "records_kept": 0,
               "records_dropped": 0}
        if self.readonly:
            return out
        with self._lock:
            try:
                with self._flock(exclusive=True):
                    out["bytes_before"] = os.path.getsize(self.log_path)
                    # one full scan collecting the latest raw payload per
                    # live key (insertion order = first-write order, so the
                    # compacted log keeps a stable, deterministic layout)
                    live: "Dict[Tuple, Tuple[int, bytes, bytes]]" = {}
                    total = 0
                    try:
                        for rtype, key, payload, _off, _end in iter_framed(
                                self.log_path):
                            total += 1
                            if rtype == RT_CORE:
                                ii = _CORE_HEAD.unpack_from(payload)[0]
                                dedup = (rtype, key, ii)
                            else:
                                dedup = (rtype, key)
                            live[dedup] = (rtype, key, payload)
                    except StoreCorruption:
                        self._quarantine()
                        return out
                    tmp = self.log_path + f".compact-{os.getpid()}"
                    with open(tmp, "wb") as f:
                        for rtype, key, payload in live.values():
                            write_framed(f, rtype, key, payload)
                        f.flush()
                        os.fsync(f.fileno())
                    os.replace(tmp, self.log_path)
                    # drop the stale index (every payload offset moved) and
                    # rebuild from the compacted log
                    self._mappings.clear()
                    self._arenas.clear()
                    self._cores.clear()
                    self._scanned = 0
                    self._scan_from(0)
                    self.stats.compactions += 1
                    out["bytes_after"] = os.path.getsize(self.log_path)
                    out["records_kept"] = len(live)
                    out["records_dropped"] = total - len(live)
            except OSError:
                self.stats.write_errors += 1
        return out

    # ---------------------------------------------------------- inspection
    def describe(self) -> Dict[str, int]:
        with self._lock:
            d = self.stats.snapshot()
            d["mappings"] = len(self._mappings)
            d["core_sessions"] = len(self._cores)
            d["cores"] = sum(len(v) for v in self._cores.values())
            d["arenas"] = len(self._arenas)
            d["log_bytes"] = self._scanned
            return d


class _FileLock:
    """``flock`` context on a sidecar lock file (shared or exclusive);
    degrades to a no-op where fcntl is unavailable."""

    def __init__(self, path: str, exclusive: bool):
        self._path = path
        self._exclusive = exclusive
        self._fd: Optional[int] = None

    def __enter__(self):
        if fcntl is not None:
            self._fd = os.open(self._path, os.O_CREAT | os.O_RDWR, 0o644)
            fcntl.flock(self._fd, fcntl.LOCK_EX if self._exclusive
                        else fcntl.LOCK_SH)
        return self

    def __exit__(self, *exc):
        if self._fd is not None:
            try:
                fcntl.flock(self._fd, fcntl.LOCK_UN)
            finally:
                os.close(self._fd)
            self._fd = None
        return False


def _trim_result(result):
    """A pickling-safe shallow copy of a MappingResult: the per-request
    ``service`` report describes the request that *produced* the entry,
    not the one that will read it — every disk hit gets a fresh one."""
    from copy import copy
    out = copy(result)
    out.service = None
    return out

"""SAT encoding of the modulo-scheduling mapping problem (paper §IV-C).

Literals are x_{n,p,c,it}: node ``n`` placed on PE ``p`` at kernel cycle ``c``
with KMS iteration label ``it``. Flat mobility-schedule time is
``t = it*II + c``; C3's Eq. 3 window is exactly the flat-time window

    1 - delta*II  <=  t_d - t_s  <=  (1 - delta)*II

for an edge of loop-carried distance ``delta`` (delta=0 reduces to the
paper's "c_d > c_s if same iteration label, c_d <= c_s if labels differ by
one"). The upper bound is forced by the non-rotating register file: a value
is overwritten by the producer's next kernel instance II cycles later.

Clause families:
  C1  exactly-one position per node                  (paper Eq. 1)
  C2  at-most-one node per (PE, kernel cycle)        (paper Eq. 2)
  C3  per-edge adjacency + timing. The paper ORs Eq. 4/5 conjunction terms;
      given C1, that disjunction is equivalent to the implication form used
      here: for every destination literal w,  (¬w ∨ compatible-src-lits...).
      Delivery mode (internal vs. output register, Eq. 4 vs. 5) is resolved
      post-SAT by register allocation, which models both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .cgra import CGRA
from .cnf import CNF
from .dfg import DFG
from .schedule import KMS, asap_alap, build_kms


@dataclass(frozen=True)
class Lit:
    node: int
    pe: int
    cycle: int
    iteration: int


@dataclass
class Encoding:
    cnf: CNF
    kms: KMS
    cgra: CGRA
    dfg: DFG
    var_of: Dict[Tuple[int, int, int, int], int]   # (n,p,c,it) -> var
    info: Dict[int, Lit]                           # var -> literal info
    stats: Dict[str, int] = field(default_factory=dict)

    def decode(self, model: Sequence[bool]) -> Dict[int, Tuple[int, int, int]]:
        """model[v-1] -> placement {node: (pe, cycle, iteration)}."""
        placement: Dict[int, Tuple[int, int, int]] = {}
        for var, lit in self.info.items():
            if model[var - 1]:
                if lit.node in placement:
                    raise ValueError(f"node {lit.node} assigned twice")
                placement[lit.node] = (lit.pe, lit.cycle, lit.iteration)
        missing = set(self.dfg.nodes) - set(placement)
        if missing:
            raise ValueError(f"unplaced nodes {sorted(missing)}")
        return placement


class EncoderSession:
    """Holds II-independent precomputation (windows, allowed PEs, neighbour
    tables) so the Fig. 3 iterative loop re-encodes only what II changes."""

    def __init__(self, dfg: DFG, cgra: CGRA, amo: str = "pairwise"):
        dfg.validate()
        self.dfg = dfg
        self.cgra = cgra
        self.amo = amo
        self.asap, self.alap, self.length = asap_alap(dfg)
        self.allowed_pes: Dict[int, List[int]] = {
            nid: [p for p in range(cgra.n_pes)
                  if (not node.is_mem) or cgra.can_mem(p)]
            for nid, node in dfg.nodes.items()
        }
        # src PE -> PEs that can consume from it (self + neighbours)
        self.consumers: List[List[int]] = [
            sorted({p} | set(cgra.neighbors(p))) for p in range(cgra.n_pes)
        ]

    # ---------------------------------------------------------------- build
    def encode(self, ii: int) -> Encoding:
        dfg, cgra = self.dfg, self.cgra
        kms = build_kms(dfg, ii)
        cnf = CNF()
        var_of: Dict[Tuple[int, int, int, int], int] = {}
        info: Dict[int, Lit] = {}

        # literal creation: one var per (node, allowed PE, KMS candidate)
        by_node: Dict[int, List[int]] = {}
        by_slot: Dict[Tuple[int, int], List[int]] = {}  # (p, c) -> vars
        for nid in dfg.nodes:
            lits = []
            for c, it in kms.candidates[nid]:
                for p in self.allowed_pes[nid]:
                    v = cnf.new_var()
                    var_of[(nid, p, c, it)] = v
                    info[v] = Lit(nid, p, c, it)
                    lits.append(v)
                    by_slot.setdefault((p, c), []).append(v)
            by_node[nid] = lits

        n_c1 = cnf.n_clauses
        # C1: exactly one literal per node (Eq. 1)
        for nid, lits in by_node.items():
            if not lits:
                # node has no legal position at this II -> trivially UNSAT
                cnf.add_clause([])
                continue
            cnf.exactly_one(lits, self.amo)
        n_c1 = cnf.n_clauses - n_c1

        n_c2 = cnf.n_clauses
        # C2: at most one node per (PE, kernel cycle) (Eq. 2)
        for (p, c), lits in by_slot.items():
            cnf.at_most_one(lits, self.amo)
        n_c2 = cnf.n_clauses - n_c2

        n_c3 = cnf.n_clauses
        # C3: per-edge implication clauses (Eq. 3/4/5 window)
        for src, dst, delta in dfg.edges():
            lo = 1 - delta * ii
            hi = (1 - delta) * ii
            # index src literals by (c, it) for the scan below
            src_cands = kms.candidates[src]
            src_pes = self.allowed_pes[src]
            for cd, itd in kms.candidates[dst]:
                td = kms.flat_time(cd, itd)
                ok_times = [(cs, its) for cs, its in src_cands
                            if lo <= td - kms.flat_time(cs, its) <= hi]
                for pd in self.allowed_pes[dst]:
                    w = var_of[(dst, pd, cd, itd)]
                    support = [var_of[(src, ps, cs, its)]
                               for cs, its in ok_times
                               for ps in src_pes
                               if cgra.reachable(ps, pd)]
                    cnf.add_clause([-w] + support)
        n_c3 = cnf.n_clauses - n_c3

        enc = Encoding(cnf=cnf, kms=kms, cgra=cgra, dfg=dfg,
                       var_of=var_of, info=info)
        enc.stats = {"vars": cnf.n_vars, "clauses": cnf.n_clauses,
                     "c1": n_c1, "c2": n_c2, "c3": n_c3}
        return enc


def encode(dfg: DFG, cgra: CGRA, ii: int, amo: str = "pairwise") -> Encoding:
    return EncoderSession(dfg, cgra, amo).encode(ii)

"""SAT encoding of the modulo-scheduling mapping problem (paper §IV-C).

Literals are x_{n,p,c,it}: node ``n`` placed on PE ``p`` at kernel cycle ``c``
with KMS iteration label ``it``. Flat mobility-schedule time is
``t = it*II + c``; C3's Eq. 3 window generalises the paper's to per-op
latencies (lat(s) = producer's issue->result cycles):

    lat(s) - delta*II  <=  t_d - t_s  <=  (1 - delta)*II + lat(s) - 1

for an edge of loop-carried distance ``delta``: the consumer cannot issue
before the producer's result exists (lower bound), and the value — written
at t_s + lat(s), rewritten by the producer's next kernel instance II
cycles later — is gone from the non-rotating register file after
t_s + II + lat(s) - 1 (upper bound). With lat(s) = 1 everywhere this is
bit-for-bit the paper's window ``1 - delta*II <= t_d - t_s <=
(1 - delta)*II`` — the unit-latency CNF is unchanged down to clause order.

Clause families:
  C1  exactly-one position per node                  (paper Eq. 1)
  C2  at-most-one node per (PE, kernel cycle)        (paper Eq. 2)
      — plus, on multi-cycle fabrics, write-port conflicts: two nodes of
      *different* latencies on one PE whose completions fold to the same
      kernel cycle would write the single output register simultaneously.
      With equal latencies a completion clash implies an issue clash that
      Eq. 2 already forbids, so unit-latency fabrics emit zero extra
      clauses (the bit-parity guarantee holds).
  C3  per-edge adjacency + timing. The paper ORs Eq. 4/5 conjunction terms;
      given C1, that disjunction is equivalent to the implication form used
      here: for every destination literal w,  (¬w ∨ compatible-src-lits...).
      Delivery mode (internal vs. output register, Eq. 4 vs. 5) is resolved
      post-SAT by register allocation, which models both.

Emitter modes: the per-II families above exist twice. The default
``emitters="vector"`` path computes each family as one flat numpy block —
exploiting that a node's variables are laid out contiguously as
``var(n, t, p_idx) = base(n) + (t - asap(n)) * P(n) + p_idx + 1`` — and
extends the clause arena with a handful of array ops per family.
``emitters="legacy"`` keeps the original per-clause Python generators
(`c2_fold_groups` / `c2w_clauses` / `c3_clauses` + ``add_clause`` loops);
it is the pinned baseline for the encode microbenchmark and the oracle the
property tests compare against — the two modes are asserted bit-identical
(same clause order, same literal order) on the whole suite.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .cgra import CGRA
from .cnf import CNF, ClauseArena, IncrementalCNF
from .dfg import DFG
from .schedule import KMS, asap_alap, build_kms, node_latencies


@dataclass(frozen=True)
class Lit:
    node: int
    pe: int
    cycle: int
    iteration: int


# (iu, ju) index pairs of np.triu_indices(k, 1), memoised per k: the pair
# enumeration (0,1),(0,2),...,(1,2),... is exactly the nested i<j loop order
_TRIU_CACHE: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}


def _triu(k: int) -> Tuple[np.ndarray, np.ndarray]:
    got = _TRIU_CACHE.get(k)
    if got is None:
        got = np.triu_indices(k, 1)
        _TRIU_CACHE[k] = got
    return got


def _neg_pairs(u: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Interleave (¬u, ¬w) rows into one flat binary-clause block."""
    flat = np.empty(u.size * 2, dtype=np.int64)
    flat[0::2] = -u
    flat[1::2] = -w
    return flat


def _concat(flats: List[np.ndarray], lens: List[np.ndarray],
            ) -> Tuple[np.ndarray, np.ndarray]:
    if not flats:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
    return np.concatenate(flats), np.concatenate(lens)


class _C3Rows(NamedTuple):
    """Row-major (edge, td, pd) constants for the batched C3 emitter —
    see EncoderSession._c3_rows."""
    td: np.ndarray        # consumer flat time of the row's head literal
    a_s: np.ndarray       # producer ASAP / ALAP window
    b_s: np.ndarray
    lo0: np.ndarray       # window bounds: lo = lo0 - hi1*II,
    hi0: np.ndarray       #                hi = hi0 + (1-hi1)*II
    hi1: np.ndarray       # (hi1 == edge distance delta)
    head: np.ndarray      # head var (positive; emitted negated)
    npsel: np.ndarray     # |reachable src PEs| for the row's dst PE
    selstart: np.ndarray  # row's slice start into sel
    const: np.ndarray     # src var = const + ts*p_s + sel[...]
    p_s: np.ndarray
    sel: np.ndarray       # ragged concat of per-(edge, dst-PE) src-PE indices


class Encoding:
    """Result of one (cold) per-II encode.

    ``var_of`` / ``info`` — the per-II (n,p,c,it) <-> var dictionaries —
    are derived lazily from the session layout: the solver path never
    touches them (decode does, once, after SAT), so the hot encode path
    skips building two O(vars) dicts.
    """

    def __init__(self, cnf: CNF, kms: Optional[KMS], cgra: CGRA, dfg: DFG,
                 var_of: Optional[Dict[Tuple[int, int, int, int], int]] = None,
                 info: Optional[Dict[int, Lit]] = None,
                 stats: Optional[Dict[str, int]] = None,
                 layout: Optional["_Layout"] = None,
                 ii: Optional[int] = None,
                 lat: Optional[Dict[int, int]] = None):
        self.cnf = cnf
        self.cgra = cgra
        self.dfg = dfg
        self.stats: Dict[str, int] = stats or {}
        # audit metadata: family -> [start, end) clause-index range in the
        # arena, filled by EncoderSession.encode(). ``stats`` keeps the
        # historical counters ("c2" = fold + write-port combined); the
        # ranges split C2W out so repro.analysis.cnf_audit can slice each
        # family and cross-check it against its closed-form clause count.
        self.families: Dict[str, Tuple[int, int]] = {}
        self._kms = kms
        self._var_of = var_of
        self._info = info
        self._lay = layout
        self._ii = ii
        self._lat = lat

    @property
    def kms(self) -> KMS:
        """The II's kernel mobility schedule — lazy, like var_of/info: only
        decode/diagnostics read it, never the solve path."""
        if self._kms is None:
            self._kms = build_kms(self.dfg, self._ii, lat=self._lat)
        return self._kms

    @property
    def var_of(self) -> Dict[Tuple[int, int, int, int], int]:
        """(n,p,c,it) -> var."""
        if self._var_of is None:
            ii = self._ii
            self._var_of = {(n, p, t % ii, t // ii): v
                            for (n, p, t), v in self._lay.var_of_t.items()}
        return self._var_of

    @property
    def info(self) -> Dict[int, Lit]:
        """var -> literal info."""
        if self._info is None:
            ii = self._ii
            self._info = {v + 1: Lit(n, p, t % ii, t // ii)
                          for v, (n, p, t) in enumerate(self._lay.info_t)}
        return self._info

    def decode(self, model: Sequence[bool]) -> Dict[int, Tuple[int, int, int]]:
        """model[v-1] -> placement {node: (pe, cycle, iteration)}."""
        placement: Dict[int, Tuple[int, int, int]] = {}
        for var, lit in self.info.items():
            if model[var - 1]:
                if lit.node in placement:
                    raise ValueError(f"node {lit.node} assigned twice")
                placement[lit.node] = (lit.pe, lit.cycle, lit.iteration)
        missing = set(self.dfg.nodes) - set(placement)
        if missing:
            raise ValueError(f"unplaced nodes {sorted(missing)}")
        return placement


@dataclass
class _Layout:
    """II-independent clause structure shared by every II of a session.

    The KMS candidate set of a node is ``{(t % II, t // II) : t in
    [asap, alap]}`` — the underlying *flat times* t do not depend on II, so
    one variable per (node, PE, flat time) covers every candidate II with
    identical numbering. C1 (exactly-one per node) ranges over exactly those
    variables and is therefore II-independent too; it is built once here
    into its own clause arena and copied (one memcpy) into every per-II
    CNF. C2's skeleton — which variables share a (PE, flat-time) slot — is
    also fixed; only the fold ``t % II`` that merges slots changes per II.

    A node's variables are contiguous and t-major: ``var(n, t, p_idx) =
    base0[n] + (t - asap[n]) * npes[n] + p_idx + 1``. The vectorised
    emitters lean on that closed form to compute whole clause families
    without touching the dicts.
    """
    var_of_t: Dict[Tuple[int, int, int], int]      # (node, pe, t) -> var
    info_t: List[Tuple[int, int, int]]             # var-1 -> (node, pe, t)
    by_pt: Dict[Tuple[int, int], List[int]]        # (pe, t) -> vars
    pt_keys: List[Tuple[int, int]]                 # insertion-ordered keys
    c1_arena: ClauseArena                          # C1 clauses, CSR form
    c1_trivial: bool                               # C1 contains an empty clause
    n_vars: int                                    # layout vars + C1 aux
    n_c1: int
    base0: Dict[int, int]                          # node -> #vars before it
    npes: Dict[int, int]                           # node -> |allowed PEs|
    pt_blocks: List[np.ndarray]                    # by_pt values as int32 arrays
    pt_index: Dict[Tuple[int, int], int]           # key -> index into pt_blocks
    v_pe: np.ndarray                               # var-1 -> PE id
    v_t: np.ndarray                                # var-1 -> flat time
    v_lat: np.ndarray                              # var-1 -> node latency
    mixed_lat: bool                                # any two node latencies differ


class EncoderSession:
    """Holds II-independent precomputation (windows, allowed PEs, neighbour
    tables, and the full C1/variable layout) so the Fig. 3 iterative loop —
    and the parallel II-sweep engine in ``sweep.py`` — re-derive only the
    II-dependent C2 fold and C3 timing windows per candidate II.

    Incremental-encoding contract (relied on by ``sweep.py``):
      * variable numbering is identical for every II of one session (one var
        per (node, allowed PE, flat mobility time), created in a fixed
        order), so models/phase hints are comparable across IIs;
      * ``encode(ii)`` never mutates shared state — each call returns a
        fresh ``Encoding`` whose CNF starts from a copy of the shared C1
        arena, so concurrent solvers may consume them freely;
      * with the "sequential" (Sinz) AMO, C1 auxiliary variables live in the
        shared prefix and C2 auxiliaries are allocated per II *after* it, so
        the shared numbering is still stable.

    ``emitters`` selects the per-II clause emitters: ``"vector"`` (default)
    computes each family as flat numpy blocks, ``"legacy"`` runs the
    original per-clause generator loops. Both produce bit-identical clause
    streams (property-tested); legacy is kept as the pinned benchmark
    baseline and test oracle.
    """

    def __init__(self, dfg: DFG, cgra: CGRA, amo: str = "pairwise",
                 emitters: str = "vector"):
        dfg.validate()
        if emitters not in ("vector", "legacy"):
            raise ValueError(f"unknown emitters mode {emitters!r}")
        self.dfg = dfg
        self.cgra = cgra          # a CGRA or a heterogeneous ArchSpec
        self.amo = amo
        self.emitters = emitters
        # per-node issue->result latencies from the fabric's op-class
        # latency table (all 1 on the paper's fabric): they stretch the
        # ASAP/ALAP windows and shift every C3 dependency window below
        self.lat = node_latencies(dfg, cgra)
        self.asap, self.alap, self.length = asap_alap(dfg, self.lat)
        # op-class -> PE compatibility: a node's candidate literals range
        # over exactly the PEs capable of its op class (mem/mul/alu), so
        # capability constraints are enforced by variable layout + C1
        # rather than by extra clauses (generalises the old can_mem check)
        self.allowed_pes: Dict[int, List[int]] = {
            nid: list(cgra.pes_for(node.op))
            for nid, node in dfg.nodes.items()
        }
        # src PE -> PEs that can consume from it (self + neighbours)
        self.consumers: List[List[int]] = [
            sorted({p} | set(cgra.neighbors(p))) for p in range(cgra.n_pes)
        ]
        # dst PE -> frozenset of src PEs that can feed it
        self.reach_from: List[frozenset] = [
            frozenset(ps for ps in range(cgra.n_pes) if cgra.reachable(ps, pd))
            for pd in range(cgra.n_pes)
        ]
        self._layout: Optional[_Layout] = None
        # II-independent per-clause-row constants for the batched C3
        # emitter (built lazily by _c3_rows)
        self._c3_row_cache: Optional[_C3Rows] = None

    # --------------------------------------------------- II-independent part
    def _ensure_layout(self) -> _Layout:
        if self._layout is not None:
            return self._layout
        dfg = self.dfg
        base = CNF()
        var_of_t: Dict[Tuple[int, int, int], int] = {}
        info_t: List[Tuple[int, int, int]] = []
        by_node: Dict[int, List[int]] = {}
        by_pt: Dict[Tuple[int, int], List[int]] = {}
        base0: Dict[int, int] = {}
        npes: Dict[int, int] = {}
        v_pe_parts: List[np.ndarray] = []
        v_t_parts: List[np.ndarray] = []
        v_lat_parts: List[np.ndarray] = []
        # one var per (node, allowed PE, flat mobility time); creation order
        # (node, then time, then PE) matches the historical per-II encoder,
        # because KMS candidates enumerate the same flat times in order.
        for nid in dfg.nodes:
            a, b = self.asap[nid], self.alap[nid]
            pes = self.allowed_pes[nid]
            base0[nid] = base.n_vars
            npes[nid] = len(pes)
            lits = []
            for t in range(a, b + 1):
                for p in pes:
                    v = base.new_var()
                    var_of_t[(nid, p, t)] = v
                    info_t.append((nid, p, t))
                    lits.append(v)
                    by_pt.setdefault((p, t), []).append(v)
            by_node[nid] = lits
            if pes:
                nt = b - a + 1
                v_pe_parts.append(np.tile(np.asarray(pes, np.int64), nt))
                v_t_parts.append(
                    np.repeat(np.arange(a, b + 1, dtype=np.int64), len(pes)))
                v_lat_parts.append(
                    np.full(nt * len(pes), self.lat[nid], dtype=np.int64))
        # C1: exactly one position per node (Eq. 1) — II-independent
        for nid, lits in by_node.items():
            if not lits:
                # node has no legal PE at any II -> trivially UNSAT
                base.add_clause([])
                continue
            base.exactly_one(lits, self.amo)
        empty = np.zeros(0, dtype=np.int64)
        self._layout = _Layout(
            var_of_t=var_of_t, info_t=info_t, by_pt=by_pt,
            pt_keys=list(by_pt), c1_arena=base.arena,
            c1_trivial=base.trivially_unsat,
            n_vars=base.n_vars, n_c1=base.n_clauses,
            base0=base0, npes=npes,
            pt_blocks=[np.asarray(v, dtype=np.int64)
                       for v in by_pt.values()],
            pt_index={k: i for i, k in enumerate(by_pt)},
            v_pe=np.concatenate(v_pe_parts) if v_pe_parts else empty,
            v_t=np.concatenate(v_t_parts) if v_t_parts else empty,
            v_lat=np.concatenate(v_lat_parts) if v_lat_parts else empty,
            mixed_lat=len(set(self.lat.values())) > 1)
        return self._layout

    # ------------------------------------------- per-II clause generators
    # Single source of truth for the II-dependent clause families: both
    # the cold per-II encoder (encode) and the layered incremental one
    # (IncrementalEncoding.ensure_ii) consume these. The legacy per-clause
    # generators below are the pinned oracle; the _*_flat methods are the
    # vectorised emitters asserted bit-identical to them.
    def c2_fold_groups(self, ii: int) -> List[List[Tuple[int, int]]]:
        """Groups of (PE, flat-time) slot keys merged by the ``t % II``
        fold — each group's variables share one kernel-cycle slot."""
        lay = self._ensure_layout()
        by_slot: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for (p, t) in lay.pt_keys:
            by_slot.setdefault((p, t % ii), []).append((p, t))
        return list(by_slot.values())

    def c2w_clauses(self, ii: int):
        """Yield output-register *write-port* conflict clauses for ``ii``:
        at most one result may land on a PE's output register per kernel
        cycle. C2 constrains issue slots, and with uniform latencies a
        completion clash implies an issue clash — so clauses are emitted
        only for pairs of nodes with *different* latencies (none at all
        on a unit-latency fabric, preserving CNF bit-parity)."""
        lay = self._ensure_layout()
        lat = self.lat
        groups: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for v, (n, p, t) in enumerate(lay.info_t):
            groups.setdefault((p, (t + lat[n]) % ii), []).append(
                (v + 1, lat[n]))
        for members in groups.values():
            for a in range(len(members)):
                for b in range(a + 1, len(members)):
                    (u, lu), (w, lw) = members[a], members[b]
                    if lu != lw:
                        yield [-u, -w]

    def c3_clauses(self, ii: int):
        """Yield C3 per-edge implication clauses (Eq. 3/4/5 window) for
        ``ii`` — the only clause family whose structure depends on II.
        The window is shifted by the producer's latency (see module
        docstring); lat == 1 reproduces the paper's window exactly."""
        lay = self._ensure_layout()
        var_of_t = lay.var_of_t
        for src, dst, delta in self.dfg.edges():
            lat_s = self.lat[src]
            lo = lat_s - delta * ii
            hi = (1 - delta) * ii + lat_s - 1
            src_times = range(self.asap[src], self.alap[src] + 1)
            src_pes = self.allowed_pes[src]
            for td in range(self.asap[dst], self.alap[dst] + 1):
                ok_times = [ts for ts in src_times if lo <= td - ts <= hi]
                for pd in self.allowed_pes[dst]:
                    w = var_of_t[(dst, pd, td)]
                    reach = self.reach_from[pd]
                    support = [var_of_t[(src, ps, ts)]
                               for ts in ok_times
                               for ps in src_pes
                               if ps in reach]
                    yield [-w] + support

    # ------------------------------------------------- vectorised emitters
    def _c2_fold_flat(self, ii: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pairwise C2 fold as one flat block: per fold group, the ¬u∨¬w
        pairs in i<j order — the stream ``at_most_one(group_lits)`` emits."""
        lay = self._ensure_layout()
        flats: List[np.ndarray] = []
        lens: List[np.ndarray] = []
        for group in self.c2_fold_groups(ii):
            if len(group) == 1:
                arr = lay.pt_blocks[lay.pt_index[group[0]]]
            else:
                arr = np.concatenate(
                    [lay.pt_blocks[lay.pt_index[k]] for k in group])
            k = arr.size
            if k <= 1:
                continue
            iu, ju = _triu(k)
            flats.append(_neg_pairs(arr[iu], arr[ju]))
            lens.append(np.full(iu.size, 2, dtype=np.int64))
        return _concat(flats, lens)

    def _c2_delta_flat(self, ii: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pairwise C2 fold, *cross-time pairs only* (the incremental
        delta; within-slot pairs live in the base skeleton). Order matches
        the legacy loop: fold groups in order; inside a group, slot-block
        pairs (a,b) in lex order, then the (u,w) cartesian product
        row-major."""
        lay = self._ensure_layout()
        flats: List[np.ndarray] = []
        lens: List[np.ndarray] = []
        for group in self.c2_fold_groups(ii):
            if len(group) <= 1:
                continue
            blocks = [lay.pt_blocks[lay.pt_index[k]] for k in group]
            sizes = np.asarray([b.size for b in blocks], dtype=np.int64)
            ai, bi = _triu(len(blocks))
            cnt = sizes[ai] * sizes[bi]
            total = int(cnt.sum())
            if total == 0:
                continue
            rep = np.repeat(np.arange(ai.size), cnt)
            m = np.arange(total, dtype=np.int64) \
                - np.repeat(np.cumsum(cnt) - cnt, cnt)
            cat = np.concatenate(blocks)
            starts = np.cumsum(sizes) - sizes
            wb = sizes[bi][rep]
            u = cat[starts[ai][rep] + m // wb]
            w = cat[starts[bi][rep] + m % wb]
            flats.append(_neg_pairs(u, w))
            lens.append(np.full(total, 2, dtype=np.int64))
        return _concat(flats, lens)

    def _c2w_flat(self, ii: int) -> Tuple[np.ndarray, np.ndarray]:
        """Write-port conflicts as a flat block — same grouping-by-first-
        occurrence and var-major member order as ``c2w_clauses``. Uniform
        latencies short-circuit to zero clauses (as the generator does)."""
        lay = self._ensure_layout()
        empty = (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        if not lay.mixed_lat or lay.v_t.size == 0:
            return empty
        keys = lay.v_pe * ii + (lay.v_t + lay.v_lat) % ii
        _, first_idx, inv = np.unique(keys, return_index=True,
                                      return_inverse=True)
        # rank sorted-unique groups by first occurrence (dict insertion order)
        grank = np.empty(first_idx.size, dtype=np.int64)
        grank[np.argsort(first_idx, kind="stable")] = \
            np.arange(first_idx.size)
        g = grank[inv]
        order = np.argsort(g, kind="stable")   # group-major, var-order within
        counts = np.bincount(g)
        starts = np.cumsum(counts) - counts
        vs = order + 1                         # member var ids, group-major
        lats = lay.v_lat[order]
        flats: List[np.ndarray] = []
        lens: List[np.ndarray] = []
        for gi in range(counts.size):
            k = int(counts[gi])
            if k < 2:
                continue
            s = int(starts[gi])
            mem_v = vs[s:s + k]
            mem_l = lats[s:s + k]
            iu, ju = _triu(k)
            mask = mem_l[iu] != mem_l[ju]
            if not mask.any():
                continue
            flats.append(_neg_pairs(mem_v[iu[mask]], mem_v[ju[mask]]))
            lens.append(np.full(int(mask.sum()), 2, dtype=np.int64))
        return _concat(flats, lens)

    def _c3_rows(self) -> "_C3Rows":
        """II-independent constants of every C3 clause row, batched.

        C3's clause set has one clause per (edge, td, pd) — the *rows* —
        and only the producer-time window per row moves with II. Everything
        else (the head literal, the per-row PE selection and its slice of
        the concatenated selection table, the window's affine coefficients)
        is fixed, so it is materialised once here as flat row-major arrays
        and each ``_c3_flat(ii)`` call is ~a dozen whole-array ops total,
        independent of edge count. Built on first use, ~O(rows)."""
        if self._c3_row_cache is not None:
            return self._c3_row_cache
        lay = self._ensure_layout()
        parts: Dict[str, List[np.ndarray]] = {
            k: [] for k in ("td", "a_s", "b_s", "lo0", "hi0", "hi1",
                            "head", "npsel", "selstart", "const", "p_s")}
        sel_parts: List[np.ndarray] = []
        sel_top = 0
        for src, dst, delta in self.dfg.edges():
            p_d, p_s = lay.npes[dst], lay.npes[src]
            if p_d == 0:
                continue    # no dst literals -> the generator yields nothing
            src_pes = self.allowed_pes[src]
            sels = [np.asarray([i for i, ps in enumerate(src_pes)
                                if ps in self.reach_from[pd]],
                               dtype=np.int64)
                    for pd in self.allowed_pes[dst]]
            npsel = np.asarray([s.size for s in sels], dtype=np.int64)
            selstart = sel_top + np.cumsum(npsel) - npsel
            sel_parts.extend(sels)
            sel_top += int(npsel.sum())
            lat_s = self.lat[src]
            a_s, b_s = self.asap[src], self.alap[src]
            a_d, b_d = self.asap[dst], self.alap[dst]
            ntd = b_d - a_d + 1
            n_rows = ntd * p_d
            td = np.repeat(np.arange(a_d, b_d + 1, dtype=np.int64), p_d)
            parts["td"].append(td)
            parts["a_s"].append(np.full(n_rows, a_s, dtype=np.int64))
            parts["b_s"].append(np.full(n_rows, b_s, dtype=np.int64))
            # window bounds are affine in II: lo = lo0 + ii*(-delta),
            # hi = hi0 + ii*(1-delta) -> store the coefficients
            parts["lo0"].append(np.full(n_rows, lat_s, dtype=np.int64))
            parts["hi0"].append(np.full(n_rows, lat_s - 1, dtype=np.int64))
            parts["hi1"].append(np.full(n_rows, delta, dtype=np.int64))
            parts["head"].append(
                lay.base0[dst] + 1 + (td - a_d) * p_d
                + np.tile(np.arange(p_d, dtype=np.int64), ntd))
            parts["npsel"].append(np.tile(npsel, ntd))
            parts["selstart"].append(np.tile(selstart, ntd))
            # var(src, ts, psel) = const + ts*p_s + psel
            parts["const"].append(
                np.full(n_rows, lay.base0[src] + 1 - a_s * p_s,
                        dtype=np.int64))
            parts["p_s"].append(np.full(n_rows, p_s, dtype=np.int64))
        empty = np.zeros(0, dtype=np.int64)

        def cat(key: str) -> np.ndarray:
            return np.concatenate(parts[key]) if parts[key] else empty

        self._c3_row_cache = _C3Rows(
            td=cat("td"), a_s=cat("a_s"), b_s=cat("b_s"),
            lo0=cat("lo0"), hi0=cat("hi0"), hi1=cat("hi1"),
            head=cat("head"), npsel=cat("npsel"), selstart=cat("selstart"),
            const=cat("const"), p_s=cat("p_s"),
            sel=np.concatenate(sel_parts) if sel_parts else empty)
        return self._c3_row_cache

    def _c3_flat(self, ii: int) -> Tuple[np.ndarray, np.ndarray]:
        """C3 as one flat block over all edges. The legal producer times
        for a row form the contiguous range ``[max(asap_s, td-hi),
        min(alap_s, td-lo)]``; with the II-independent row constants from
        :meth:`_c3_rows`, each clause — head ``¬w`` plus its ts-major/
        psel-minor support — is a closed-form gather."""
        rows = self._c3_rows()
        n_rows = rows.td.size
        if n_rows == 0:
            return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        # lo = lat_s - delta*ii ; hi = (1 - delta)*ii + lat_s - 1
        lo = rows.lo0 - rows.hi1 * ii
        hi = rows.hi0 + (1 - rows.hi1) * ii
        ts0 = np.maximum(rows.a_s, rows.td - hi)
        ntim = np.minimum(rows.b_s, rows.td - lo) - ts0 + 1
        np.maximum(ntim, 0, out=ntim)
        sup = ntim * rows.npsel
        lens = sup + 1
        offs = np.empty(n_rows + 1, dtype=np.int64)
        offs[0] = 0
        np.cumsum(lens, out=offs[1:])
        flat = np.empty(int(offs[-1]), dtype=np.int64)
        flat[offs[:-1]] = -rows.head
        total_sup = int(sup.sum())
        if total_sup:
            r = np.repeat(np.arange(n_rows), sup)
            m = np.arange(total_sup, dtype=np.int64) \
                - np.repeat(offs[:-1] - np.arange(n_rows), sup)
            nj = rows.npsel[r]
            k = m // nj
            val = rows.const[r] + (ts0[r] + k) * rows.p_s[r] \
                + rows.sel[rows.selstart[r] + m - k * nj]
            flat[np.repeat(offs[:-1] + 1, sup) + m] = val
        return flat, lens

    # ---------------------------------------------------------------- build
    def encode(self, ii: int, emitters: Optional[str] = None) -> Encoding:
        mode = self.emitters if emitters is None else emitters
        dfg, cgra = self.dfg, self.cgra
        lay = self._ensure_layout()

        cnf = CNF()
        cnf.n_vars = lay.n_vars
        cnf.arena = lay.c1_arena.copy()      # shared C1, one memcpy
        cnf.trivially_unsat = lay.c1_trivial
        n_c1 = lay.n_c1

        n_c2 = cnf.n_clauses
        # C2: at most one node per (PE, kernel cycle) (Eq. 2) — fold the
        # precomputed (PE, flat-time) slot skeleton by t % II
        if mode == "vector" and self.amo == "pairwise":
            cnf.extend_flat(*self._c2_fold_flat(ii))
        else:
            for group in self.c2_fold_groups(ii):
                lits = [v for key in group for v in lay.by_pt[key]]
                cnf.at_most_one(lits, self.amo)
        c2w_start = cnf.n_clauses
        # write-port conflicts between mixed-latency nodes (empty on
        # unit-latency fabrics), counted with C2 as resource conflicts
        if mode == "vector":
            cnf.extend_flat(*self._c2w_flat(ii))
        else:
            for cl in self.c2w_clauses(ii):
                cnf.add_clause(cl)
        n_c2 = cnf.n_clauses - n_c2

        n_c3 = cnf.n_clauses
        if mode == "vector":
            cnf.extend_flat(*self._c3_flat(ii))
        else:
            for cl in self.c3_clauses(ii):
                cnf.add_clause(cl)
        n_c3 = cnf.n_clauses - n_c3

        enc = Encoding(cnf=cnf, kms=None, cgra=cgra, dfg=dfg,
                       layout=lay, ii=ii, lat=self.lat)
        enc.stats = {"vars": cnf.n_vars, "clauses": cnf.n_clauses,
                     "c1": n_c1, "c2": n_c2, "c3": n_c3}
        c3_start = cnf.n_clauses - n_c3
        enc.families = {"c1": (0, n_c1), "c2": (n_c1, c2w_start),
                        "c2w": (c2w_start, c3_start),
                        "c3": (c3_start, cnf.n_clauses)}
        return enc


class IncrementalEncoding:
    """One persistent layered formula covering every II of a session.

    The II-independent structure — the (node, PE, flat-time) variable
    layout, C1 exactly-one, and the *within-slot* part of C2 (two nodes on
    the same (PE, flat time) collide at every II) — forms the unguarded
    base layer of an :class:`IncrementalCNF`. Each candidate II adds one
    delta layer guarded by a fresh selector literal:

      * the C2 *fold*: at-most-one across distinct flat times that the
        ``t % II`` fold merges into one kernel slot (for the pairwise AMO
        this is exactly the cross-time pairs; for the Sinz AMO the whole
        folded group is re-encoded in the layer, the base skeleton staying
        as redundant-but-sound helper clauses);
      * C3's per-edge timing windows for that II.

    "Try II=k" is then ``solve(assumptions=assumptions_for(k))`` on the one
    formula — no re-encode, and a solver that stays loaded keeps every
    learned clause across the II bump (assumptions are decisions, not
    axioms, so all learnt clauses remain globally valid).

    Variable numbering of the layout prefix is identical to
    ``EncoderSession.encode(ii)``'s, so models from assumption solves,
    from per-II projections (``project(ii)``), and from the cold path are
    all decoded by the same ``decode(ii, model)``.
    """

    def __init__(self, session: EncoderSession):
        self.session = session
        lay = session._ensure_layout()
        self._lay = lay
        inc = IncrementalCNF()
        inc.n_vars = lay.n_vars
        inc.arena = lay.c1_arena.copy()          # shared C1, one memcpy
        inc.trivially_unsat = lay.c1_trivial
        self.n_c1 = lay.n_c1
        # within-slot C2 skeleton: same (PE, flat-time) collisions hold at
        # every II (t1 == t2  =>  t1 % ii == t2 % ii); always pairwise,
        # emitted as one block (the stream per-key at_most_one would emit)
        flats: List[np.ndarray] = []
        lens: List[np.ndarray] = []
        for blk in lay.pt_blocks:
            k = blk.size
            if k <= 1:
                continue
            iu, ju = _triu(k)
            flats.append(_neg_pairs(blk[iu], blk[ju]))
            lens.append(np.full(iu.size, 2, dtype=np.int64))
        inc.extend_flat(*_concat(flats, lens))
        self.inc = inc
        self.n_base = inc.n_clauses
        # audit metadata: clause-index ranges of the base families and —
        # per encoded layer — of each II-dependent family, mirroring
        # Encoding.families on the cold path. "c2s" is the within-slot C2
        # skeleton (base), "c2" the per-II cross-time fold delta.
        self.base_families: Dict[str, Tuple[int, int]] = {
            "c1": (0, self.n_c1), "c2s": (self.n_c1, self.n_base)}
        self.layer_families: Dict[Hashable, Dict[str, Tuple[int, int]]] = {}
        # per-II projection memo: layers are immutable once encoded, so a
        # projection only changes when n_vars has grown (new layers add
        # selector/aux vars and project() stamps the current n_vars)
        self._proj_cache: Dict[Hashable, Tuple[int, CNF]] = {}

    # ---------------------------------------------------------------- build
    def ensure_ii(self, ii: int) -> int:
        """Encode the delta layer for ``ii`` if absent; returns its selector."""
        inc = self.inc
        if inc.has_layer(ii):
            return inc.selector(ii)
        session, lay = self.session, self._lay
        mode = session.emitters
        sel = inc.begin_layer(ii)
        # C2 fold: slots merged by t % II (shared generator with the cold
        # encoder — see EncoderSession.c2_fold_groups)
        if mode == "vector" and session.amo == "pairwise":
            # cross-time pairs only — within-slot pairs live in the base;
            # extend_flat guards every row with ¬selector
            inc.extend_flat(*session._c2_delta_flat(ii))
        else:
            for group in session.c2_fold_groups(ii):
                if len(group) <= 1:
                    continue
                if session.amo == "pairwise":
                    # cross-time pairs only — within-slot pairs live in the base
                    for a in range(len(group)):
                        for b in range(a + 1, len(group)):
                            for u in lay.by_pt[group[a]]:
                                for w in lay.by_pt[group[b]]:
                                    inc.add(-u, -w)
                else:
                    # Sinz over the whole folded group (aux vars live in the
                    # layer); the base pairwise skeleton stays as redundant
                    # helper clauses
                    lits = [v for key in group for v in lay.by_pt[key]]
                    inc.at_most_one(lits, session.amo)
        c2w_start = inc.n_clauses
        # write-port conflicts between mixed-latency nodes — same family
        # as the cold encoder (empty on unit-latency fabrics); then C3
        # timing windows for this II, clauses guarded by the layer selector
        if mode == "vector":
            inc.extend_flat(*session._c2w_flat(ii))
            c3_start = inc.n_clauses
            inc.extend_flat(*session._c3_flat(ii))
        else:
            for cl in session.c2w_clauses(ii):
                inc.add_clause(cl)
            c3_start = inc.n_clauses
            for cl in session.c3_clauses(ii):
                inc.add_clause(cl)
        inc.end_layer()
        start, end = inc.layer_slice(ii)
        self.layer_families[ii] = {"c2": (start, c2w_start),
                                   "c2w": (c2w_start, c3_start),
                                   "c3": (c3_start, end)}
        return sel

    # -------------------------------------------------------------- queries
    def assumptions(self, ii: int) -> List[int]:
        self.ensure_ii(ii)
        return self.inc.assumptions_for(ii)

    def project(self, ii: int) -> CNF:
        """Plain (unguarded) CNF for base + II's delta — for backends
        without assumption support and for cold-path equivalence checks.
        Memoised per (ii, n_vars): layers never change once encoded, so a
        cached projection stays valid until new layers grow ``n_vars``.
        Callers must treat the returned CNF as immutable."""
        self.ensure_ii(ii)
        nv = self.inc.n_vars
        hit = self._proj_cache.get(ii)
        if hit is not None and hit[0] == nv:
            return hit[1]
        cnf = self.inc.project(ii)
        self._proj_cache[ii] = (nv, cnf)
        return cnf

    def stats_for(self, ii: int) -> Dict[str, int]:
        self.ensure_ii(ii)
        return self.inc.layer_stats(ii)

    def projection_families(self, ii: int) -> Dict[str, Tuple[int, int]]:
        """Audit metadata: family -> [start, end) clause-index ranges in
        ``project(ii)``'s clause stream (base families first, then the
        layer's families shifted to follow them — exactly how
        ``IncrementalCNF.project`` lays the rows out)."""
        self.ensure_ii(ii)
        fams = dict(self.base_families)
        start, _ = self.inc.layer_slice(ii)
        shift = self.n_base - start
        for fam, (a, b) in self.layer_families[ii].items():
            fams[fam] = (a + shift, b + shift)
        return fams

    def decode(self, ii: int, model: Sequence[bool],
               ) -> Dict[int, Tuple[int, int, int]]:
        """Decode any model over (a prefix-compatible superset of) the
        layout variables into {node: (pe, kernel cycle, iteration)}."""
        placement: Dict[int, Tuple[int, int, int]] = {}
        for v, (n, p, t) in enumerate(self._lay.info_t):
            if model[v]:
                if n in placement:
                    raise ValueError(f"node {n} assigned twice")
                placement[n] = (p, t % ii, t // ii)
        missing = set(self.session.dfg.nodes) - set(placement)
        if missing:
            raise ValueError(f"unplaced nodes {sorted(missing)}")
        return placement


def encode(dfg: DFG, cgra: CGRA, ii: int, amo: str = "pairwise",
           emitters: str = "vector") -> Encoding:
    return EncoderSession(dfg, cgra, amo, emitters=emitters).encode(ii)

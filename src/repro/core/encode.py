"""SAT encoding of the modulo-scheduling mapping problem (paper §IV-C).

Literals are x_{n,p,c,it}: node ``n`` placed on PE ``p`` at kernel cycle ``c``
with KMS iteration label ``it``. Flat mobility-schedule time is
``t = it*II + c``; C3's Eq. 3 window generalises the paper's to per-op
latencies (lat(s) = producer's issue->result cycles):

    lat(s) - delta*II  <=  t_d - t_s  <=  (1 - delta)*II + lat(s) - 1

for an edge of loop-carried distance ``delta``: the consumer cannot issue
before the producer's result exists (lower bound), and the value — written
at t_s + lat(s), rewritten by the producer's next kernel instance II
cycles later — is gone from the non-rotating register file after
t_s + II + lat(s) - 1 (upper bound). With lat(s) = 1 everywhere this is
bit-for-bit the paper's window ``1 - delta*II <= t_d - t_s <=
(1 - delta)*II`` — the unit-latency CNF is unchanged down to clause order.

Clause families:
  C1  exactly-one position per node                  (paper Eq. 1)
  C2  at-most-one node per (PE, kernel cycle)        (paper Eq. 2)
      — plus, on multi-cycle fabrics, write-port conflicts: two nodes of
      *different* latencies on one PE whose completions fold to the same
      kernel cycle would write the single output register simultaneously.
      With equal latencies a completion clash implies an issue clash that
      Eq. 2 already forbids, so unit-latency fabrics emit zero extra
      clauses (the bit-parity guarantee holds).
  C3  per-edge adjacency + timing. The paper ORs Eq. 4/5 conjunction terms;
      given C1, that disjunction is equivalent to the implication form used
      here: for every destination literal w,  (¬w ∨ compatible-src-lits...).
      Delivery mode (internal vs. output register, Eq. 4 vs. 5) is resolved
      post-SAT by register allocation, which models both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .cgra import CGRA
from .cnf import CNF, IncrementalCNF
from .dfg import DFG
from .schedule import KMS, asap_alap, build_kms, node_latencies


@dataclass(frozen=True)
class Lit:
    node: int
    pe: int
    cycle: int
    iteration: int


@dataclass
class Encoding:
    cnf: CNF
    kms: KMS
    cgra: CGRA
    dfg: DFG
    var_of: Dict[Tuple[int, int, int, int], int]   # (n,p,c,it) -> var
    info: Dict[int, Lit]                           # var -> literal info
    stats: Dict[str, int] = field(default_factory=dict)

    def decode(self, model: Sequence[bool]) -> Dict[int, Tuple[int, int, int]]:
        """model[v-1] -> placement {node: (pe, cycle, iteration)}."""
        placement: Dict[int, Tuple[int, int, int]] = {}
        for var, lit in self.info.items():
            if model[var - 1]:
                if lit.node in placement:
                    raise ValueError(f"node {lit.node} assigned twice")
                placement[lit.node] = (lit.pe, lit.cycle, lit.iteration)
        missing = set(self.dfg.nodes) - set(placement)
        if missing:
            raise ValueError(f"unplaced nodes {sorted(missing)}")
        return placement


@dataclass
class _Layout:
    """II-independent clause structure shared by every II of a session.

    The KMS candidate set of a node is ``{(t % II, t // II) : t in
    [asap, alap]}`` — the underlying *flat times* t do not depend on II, so
    one variable per (node, PE, flat time) covers every candidate II with
    identical numbering. C1 (exactly-one per node) ranges over exactly those
    variables and is therefore II-independent too; it is built once here and
    its clause tuples are shared (not copied) into every per-II CNF. C2's
    skeleton — which variables share a (PE, flat-time) slot — is also fixed;
    only the fold ``t % II`` that merges slots changes per II.
    """
    var_of_t: Dict[Tuple[int, int, int], int]      # (node, pe, t) -> var
    info_t: List[Tuple[int, int, int]]             # var-1 -> (node, pe, t)
    by_pt: Dict[Tuple[int, int], List[int]]        # (pe, t) -> vars
    pt_keys: List[Tuple[int, int]]                 # insertion-ordered keys
    c1_clauses: List[Tuple[int, ...]]
    n_vars: int                                    # layout vars + C1 aux
    n_c1: int


class EncoderSession:
    """Holds II-independent precomputation (windows, allowed PEs, neighbour
    tables, and the full C1/variable layout) so the Fig. 3 iterative loop —
    and the parallel II-sweep engine in ``sweep.py`` — re-derive only the
    II-dependent C2 fold and C3 timing windows per candidate II.

    Incremental-encoding contract (relied on by ``sweep.py``):
      * variable numbering is identical for every II of one session (one var
        per (node, allowed PE, flat mobility time), created in a fixed
        order), so models/phase hints are comparable across IIs;
      * ``encode(ii)`` never mutates shared state — each call returns a
        fresh ``Encoding`` whose CNF shares the C1 clause *tuples* but owns
        its clause list, so concurrent solvers may consume them freely;
      * with the "sequential" (Sinz) AMO, C1 auxiliary variables live in the
        shared prefix and C2 auxiliaries are allocated per II *after* it, so
        the shared numbering is still stable.
    """

    def __init__(self, dfg: DFG, cgra: CGRA, amo: str = "pairwise"):
        dfg.validate()
        self.dfg = dfg
        self.cgra = cgra          # a CGRA or a heterogeneous ArchSpec
        self.amo = amo
        # per-node issue->result latencies from the fabric's op-class
        # latency table (all 1 on the paper's fabric): they stretch the
        # ASAP/ALAP windows and shift every C3 dependency window below
        self.lat = node_latencies(dfg, cgra)
        self.asap, self.alap, self.length = asap_alap(dfg, self.lat)
        # op-class -> PE compatibility: a node's candidate literals range
        # over exactly the PEs capable of its op class (mem/mul/alu), so
        # capability constraints are enforced by variable layout + C1
        # rather than by extra clauses (generalises the old can_mem check)
        self.allowed_pes: Dict[int, List[int]] = {
            nid: list(cgra.pes_for(node.op))
            for nid, node in dfg.nodes.items()
        }
        # src PE -> PEs that can consume from it (self + neighbours)
        self.consumers: List[List[int]] = [
            sorted({p} | set(cgra.neighbors(p))) for p in range(cgra.n_pes)
        ]
        # dst PE -> frozenset of src PEs that can feed it
        self.reach_from: List[frozenset] = [
            frozenset(ps for ps in range(cgra.n_pes) if cgra.reachable(ps, pd))
            for pd in range(cgra.n_pes)
        ]
        self._layout: Optional[_Layout] = None

    # --------------------------------------------------- II-independent part
    def _ensure_layout(self) -> _Layout:
        if self._layout is not None:
            return self._layout
        dfg = self.dfg
        base = CNF()
        var_of_t: Dict[Tuple[int, int, int], int] = {}
        info_t: List[Tuple[int, int, int]] = []
        by_node: Dict[int, List[int]] = {}
        by_pt: Dict[Tuple[int, int], List[int]] = {}
        # one var per (node, allowed PE, flat mobility time); creation order
        # (node, then time, then PE) matches the historical per-II encoder,
        # because KMS candidates enumerate the same flat times in order.
        for nid in dfg.nodes:
            lits = []
            for t in range(self.asap[nid], self.alap[nid] + 1):
                for p in self.allowed_pes[nid]:
                    v = base.new_var()
                    var_of_t[(nid, p, t)] = v
                    info_t.append((nid, p, t))
                    lits.append(v)
                    by_pt.setdefault((p, t), []).append(v)
            by_node[nid] = lits
        # C1: exactly one position per node (Eq. 1) — II-independent
        for nid, lits in by_node.items():
            if not lits:
                # node has no legal PE at any II -> trivially UNSAT
                base.add_clause([])
                continue
            base.exactly_one(lits, self.amo)
        self._layout = _Layout(
            var_of_t=var_of_t, info_t=info_t, by_pt=by_pt,
            pt_keys=list(by_pt), c1_clauses=base.clauses,
            n_vars=base.n_vars, n_c1=base.n_clauses)
        return self._layout

    # ------------------------------------------- per-II clause generators
    # Single source of truth for the II-dependent clause families: both
    # the cold per-II encoder (encode) and the layered incremental one
    # (IncrementalEncoding.ensure_ii) consume these, so cold/incremental
    # equivalence is structural, not maintained by hand in two loops.
    def c2_fold_groups(self, ii: int) -> List[List[Tuple[int, int]]]:
        """Groups of (PE, flat-time) slot keys merged by the ``t % II``
        fold — each group's variables share one kernel-cycle slot."""
        lay = self._ensure_layout()
        by_slot: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for (p, t) in lay.pt_keys:
            by_slot.setdefault((p, t % ii), []).append((p, t))
        return list(by_slot.values())

    def c2w_clauses(self, ii: int):
        """Yield output-register *write-port* conflict clauses for ``ii``:
        at most one result may land on a PE's output register per kernel
        cycle. C2 constrains issue slots, and with uniform latencies a
        completion clash implies an issue clash — so clauses are emitted
        only for pairs of nodes with *different* latencies (none at all
        on a unit-latency fabric, preserving CNF bit-parity)."""
        lay = self._ensure_layout()
        lat = self.lat
        groups: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for v, (n, p, t) in enumerate(lay.info_t):
            groups.setdefault((p, (t + lat[n]) % ii), []).append(
                (v + 1, lat[n]))
        for members in groups.values():
            for a in range(len(members)):
                for b in range(a + 1, len(members)):
                    (u, lu), (w, lw) = members[a], members[b]
                    if lu != lw:
                        yield [-u, -w]

    def c3_clauses(self, ii: int):
        """Yield C3 per-edge implication clauses (Eq. 3/4/5 window) for
        ``ii`` — the only clause family whose structure depends on II.
        The window is shifted by the producer's latency (see module
        docstring); lat == 1 reproduces the paper's window exactly."""
        lay = self._ensure_layout()
        var_of_t = lay.var_of_t
        for src, dst, delta in self.dfg.edges():
            lat_s = self.lat[src]
            lo = lat_s - delta * ii
            hi = (1 - delta) * ii + lat_s - 1
            src_times = range(self.asap[src], self.alap[src] + 1)
            src_pes = self.allowed_pes[src]
            for td in range(self.asap[dst], self.alap[dst] + 1):
                ok_times = [ts for ts in src_times if lo <= td - ts <= hi]
                for pd in self.allowed_pes[dst]:
                    w = var_of_t[(dst, pd, td)]
                    reach = self.reach_from[pd]
                    support = [var_of_t[(src, ps, ts)]
                               for ts in ok_times
                               for ps in src_pes
                               if ps in reach]
                    yield [-w] + support

    # ---------------------------------------------------------------- build
    def encode(self, ii: int) -> Encoding:
        dfg, cgra = self.dfg, self.cgra
        lay = self._ensure_layout()
        kms = build_kms(dfg, ii, lat=self.lat)

        cnf = CNF()
        cnf.n_vars = lay.n_vars
        cnf.clauses = list(lay.c1_clauses)   # shared tuples, fresh list
        n_c1 = lay.n_c1

        var_of: Dict[Tuple[int, int, int, int], int] = {
            (n, p, t % ii, t // ii): v
            for (n, p, t), v in lay.var_of_t.items()}
        info: Dict[int, Lit] = {
            v + 1: Lit(n, p, t % ii, t // ii)
            for v, (n, p, t) in enumerate(lay.info_t)}

        n_c2 = cnf.n_clauses
        # C2: at most one node per (PE, kernel cycle) (Eq. 2) — fold the
        # precomputed (PE, flat-time) slot skeleton by t % II
        for group in self.c2_fold_groups(ii):
            lits = [v for key in group for v in lay.by_pt[key]]
            cnf.at_most_one(lits, self.amo)
        # write-port conflicts between mixed-latency nodes (empty on
        # unit-latency fabrics), counted with C2 as resource conflicts
        for cl in self.c2w_clauses(ii):
            cnf.add_clause(cl)
        n_c2 = cnf.n_clauses - n_c2

        n_c3 = cnf.n_clauses
        for cl in self.c3_clauses(ii):
            cnf.add_clause(cl)
        n_c3 = cnf.n_clauses - n_c3

        enc = Encoding(cnf=cnf, kms=kms, cgra=cgra, dfg=dfg,
                       var_of=var_of, info=info)
        enc.stats = {"vars": cnf.n_vars, "clauses": cnf.n_clauses,
                     "c1": n_c1, "c2": n_c2, "c3": n_c3}
        return enc


class IncrementalEncoding:
    """One persistent layered formula covering every II of a session.

    The II-independent structure — the (node, PE, flat-time) variable
    layout, C1 exactly-one, and the *within-slot* part of C2 (two nodes on
    the same (PE, flat time) collide at every II) — forms the unguarded
    base layer of an :class:`IncrementalCNF`. Each candidate II adds one
    delta layer guarded by a fresh selector literal:

      * the C2 *fold*: at-most-one across distinct flat times that the
        ``t % II`` fold merges into one kernel slot (for the pairwise AMO
        this is exactly the cross-time pairs; for the Sinz AMO the whole
        folded group is re-encoded in the layer, the base skeleton staying
        as redundant-but-sound helper clauses);
      * C3's per-edge timing windows for that II.

    "Try II=k" is then ``solve(assumptions=assumptions_for(k))`` on the one
    formula — no re-encode, and a solver that stays loaded keeps every
    learned clause across the II bump (assumptions are decisions, not
    axioms, so all learnt clauses remain globally valid).

    Variable numbering of the layout prefix is identical to
    ``EncoderSession.encode(ii)``'s, so models from assumption solves,
    from per-II projections (``project(ii)``), and from the cold path are
    all decoded by the same ``decode(ii, model)``.
    """

    def __init__(self, session: EncoderSession):
        self.session = session
        lay = session._ensure_layout()
        self._lay = lay
        inc = IncrementalCNF()
        inc.n_vars = lay.n_vars
        inc.clauses = list(lay.c1_clauses)       # shared tuples, fresh list
        inc.trivially_unsat = any(not c for c in lay.c1_clauses)
        self.n_c1 = lay.n_c1
        # within-slot C2 skeleton: same (PE, flat-time) collisions hold at
        # every II (t1 == t2  =>  t1 % ii == t2 % ii)
        for key in lay.pt_keys:
            inc.at_most_one(lay.by_pt[key], "pairwise")
        self.inc = inc
        self.n_base = inc.n_clauses

    # ---------------------------------------------------------------- build
    def ensure_ii(self, ii: int) -> int:
        """Encode the delta layer for ``ii`` if absent; returns its selector."""
        inc = self.inc
        if inc.has_layer(ii):
            return inc.selector(ii)
        session, lay = self.session, self._lay
        sel = inc.begin_layer(ii)
        # C2 fold: slots merged by t % II (shared generator with the cold
        # encoder — see EncoderSession.c2_fold_groups)
        for group in session.c2_fold_groups(ii):
            if len(group) <= 1:
                continue
            if session.amo == "pairwise":
                # cross-time pairs only — within-slot pairs live in the base
                for a in range(len(group)):
                    for b in range(a + 1, len(group)):
                        for u in lay.by_pt[group[a]]:
                            for w in lay.by_pt[group[b]]:
                                inc.add(-u, -w)
            else:
                # Sinz over the whole folded group (aux vars live in the
                # layer); the base pairwise skeleton stays as redundant
                # helper clauses
                lits = [v for key in group for v in lay.by_pt[key]]
                inc.at_most_one(lits, session.amo)
        # write-port conflicts between mixed-latency nodes — same
        # generator as the cold encoder (empty on unit-latency fabrics)
        for cl in session.c2w_clauses(ii):
            inc.add_clause(cl)
        # C3 timing windows for this II, clauses guarded by the layer
        # selector — same generator the cold encoder consumes
        for cl in session.c3_clauses(ii):
            inc.add_clause(cl)
        inc.end_layer()
        return sel

    # -------------------------------------------------------------- queries
    def assumptions(self, ii: int) -> List[int]:
        self.ensure_ii(ii)
        return self.inc.assumptions_for(ii)

    def project(self, ii: int) -> CNF:
        """Plain (unguarded) CNF for base + II's delta — for backends
        without assumption support and for cold-path equivalence checks."""
        self.ensure_ii(ii)
        return self.inc.project(ii)

    def stats_for(self, ii: int) -> Dict[str, int]:
        self.ensure_ii(ii)
        return self.inc.layer_stats(ii)

    def decode(self, ii: int, model: Sequence[bool],
               ) -> Dict[int, Tuple[int, int, int]]:
        """Decode any model over (a prefix-compatible superset of) the
        layout variables into {node: (pe, kernel cycle, iteration)}."""
        placement: Dict[int, Tuple[int, int, int]] = {}
        for v, (n, p, t) in enumerate(self._lay.info_t):
            if model[v]:
                if n in placement:
                    raise ValueError(f"node {n} assigned twice")
                placement[n] = (p, t % ii, t // ii)
        missing = set(self.session.dfg.nodes) - set(placement)
        if missing:
            raise ValueError(f"unplaced nodes {sorted(missing)}")
        return placement


def encode(dfg: DFG, cgra: CGRA, ii: int, amo: str = "pairwise") -> Encoding:
    return EncoderSession(dfg, cgra, amo).encode(ii)

"""The unified mapping front door: ``compile(MapRequest(...))``.

The entry points accreted by earlier PRs each exposed one call shape —
``map_loop`` (the sequential Fig. 3 loop, plus routing retries),
``map_sweep`` (the parallel II window engine), ``MappingService.map``
(pool/cache routed), session-injected solves, and ``suite.run_suite``
(batch) — all with overlapping keyword sprawl. :class:`MapRequest` is the
one declarative request object that names every axis of that space, and
:func:`compile` is the one function that serves it:

    from repro.core import MapRequest, compile, arch

    compile(MapRequest(dfg=g, arch="4x4"))                    # Fig. 3 loop
    compile(MapRequest(dfg=g, arch="4x4-torus:r8",
                       sweep_width=4))                        # parallel sweep
    compile(MapRequest(dfg=g, arch=arch("4x4-onehop", mem="col0"),
                       service="default"))                    # pooled + cached
    compile(MapRequest(dfg=g, arch="5x5", routing=True))      # route retries

``arch`` accepts a fabric name (parsed by :func:`repro.core.arch.arch`),
a declarative :class:`~repro.core.arch.ArchSpec`, or a legacy
:class:`~repro.core.cgra.CGRA`. ``service="default"`` routes through the
process-wide :class:`~repro.core.service.MappingService`; a service
instance routes through that instance; ``None`` (default) solves
standalone. The legacy entry points remain as thin compatibility shims —
see the README migration guide.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Union

from .arch import ArchSpec, arch as _parse_arch
from .cgra import CGRA
from .dfg import DFG
from .mapper import MapperConfig, MappingResult, map_loop
from .schedule import Infeasible


@dataclass
class MapRequest:
    """One mapping request: what to map, onto what, and how.

    ``config`` carries the full :class:`~repro.core.mapper.MapperConfig`;
    the convenience fields (``solver``/``timeout_s``/``routing``/
    ``max_ii``) override it when set, so simple requests never construct a
    config at all. ``session`` injects a warm
    :class:`~repro.core.sat.portfolio.SolverSession` whose formula matches
    this (dfg, arch, amo) shape; ``use_cache=False`` forces a solve on a
    service-routed request (the warm-vs-cold benchmark knob). ``lat`` is a
    per-op-class latency table ({"mul": 2, ...}) applied when ``arch`` is
    a fabric *name* — equivalent to the name's ``:mulK``-style suffixes;
    use an explicit :class:`ArchSpec` to combine latencies with other
    structural knobs.
    """
    dfg: DFG
    arch: Union[str, CGRA, ArchSpec] = "4x4"
    config: Optional[MapperConfig] = None
    sweep_width: int = 1
    service: Union[None, str, object] = None   # None | "default" | instance
    session: Optional[object] = None
    use_cache: bool = True
    lat: Optional[Dict[str, int]] = None
    # convenience overrides onto ``config``
    solver: Optional[str] = None
    timeout_s: Optional[float] = None
    routing: Optional[bool] = None
    max_ii: Optional[int] = None
    guide: Optional[str] = None   # learned II guidance (name or .npz path)

    def resolved_arch(self) -> Union[CGRA, ArchSpec]:
        if isinstance(self.arch, str):
            return _parse_arch(self.arch, lat=self.lat)
        if self.lat is not None:
            raise ValueError("MapRequest.lat needs a fabric *name*; give "
                             "an ArchSpec/CGRA its latency table directly")
        return self.arch

    def resolved_config(self) -> MapperConfig:
        cfg = self.config or MapperConfig()
        overrides = {k: getattr(self, k)
                     for k in ("solver", "timeout_s", "routing", "max_ii",
                               "guide")
                     if getattr(self, k) is not None}
        return replace(cfg, **overrides) if overrides else cfg


def compile(request: Union[MapRequest, DFG], /, **kw) -> MappingResult:
    """Serve one :class:`MapRequest` -> :class:`MappingResult`.

    Accepts either a ready request or ``compile(dfg, arch=..., ...)``
    shorthand (keywords become :class:`MapRequest` fields). Dispatch:
    a resolved service (``"default"`` -> the process-wide pool) serves the
    request through cache + warm solver pool; otherwise the engine runs
    standalone — the sequential Fig. 3 loop for ``sweep_width=1`` (or when
    routing retries are on), the parallel II-sweep engine above that —
    optionally on an injected warm session.

    A structurally infeasible request (an op class in the DFG with zero
    capable PEs on the fabric — ``MappingResult.infeasible``) raises
    :class:`repro.core.schedule.Infeasible` with the precise reason
    instead of returning an ordinary "no mapping found" failure: no II
    sweep could ever succeed, and silently reporting one as exhausted
    would hide a spec bug.
    """
    if isinstance(request, MapRequest):
        if kw:
            raise TypeError("pass either a MapRequest or keyword fields, "
                            "not both")
        req = request
    else:
        req = MapRequest(dfg=request, **kw)
    arch_obj = req.resolved_arch()
    # structural-feasibility gate *before* dispatch so the caller gets the
    # original exception with its structured fields (op_class, n_ops)
    # rather than a reconstruction from the engines' flattened string
    from .schedule import res_mii
    res_mii(req.dfg, arch_obj)        # raises Infeasible with the reason
    cfg = req.resolved_config()
    svc = req.service
    if isinstance(svc, str):
        if svc != "default":
            raise ValueError(f"unknown service {svc!r}: expected None, "
                             f"'default', or a MappingService instance")
        from .service import get_service
        svc = get_service()
    if svc is not None:
        res = svc.map(req.dfg, arch_obj, cfg, sweep_width=req.sweep_width,
                      use_cache=req.use_cache)
    else:
        res = map_loop(req.dfg, arch_obj, cfg, sweep_width=req.sweep_width,
                       session=req.session)
    if res.infeasible:
        # belt-and-braces for engine- or cache-produced verdicts the gate
        # above could not see (message-only: the gate is the typed path)
        raise Infeasible(res.infeasible)
    return res

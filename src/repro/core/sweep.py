"""Parallel II-sweep mapping engine.

The paper's Fig. 3 loop tries II = MII, MII+1, ... strictly sequentially,
re-encoding the full CNF and solving from scratch at every step. But the II
attempts are *independent* SAT instances, so this engine:

  1. encodes a window of candidate IIs ``[base, base + sweep_width)`` up
     front through one shared :class:`repro.core.encode.EncoderSession` —
     the II-independent clause structure (C1 exactly-one, the C2
     at-most-one slot skeleton, the per-node literal layout) is built once
     and only the II-dependent C2 fold and C3 timing windows are re-derived
     per candidate;
  2. solves the whole window concurrently via
     :func:`repro.core.sat.portfolio.solve_window` — with the default
     incremental core, one persistent assumption-based complete solver
     walks the candidates lowest-II-first (every UNSAT proof's learned
     clauses carry into the next candidate) while racing a batched WalkSAT
     that vmaps restarts across the II candidates, warm-started from the
     best assignment earlier IIs produced; with ``incremental=False``,
     cold complete solvers run per candidate in a process/thread pool;
  3. early-cancels all higher-II attempts the moment a lower II returns
     SAT *and* passes register allocation, and slides the window upward
     only when every candidate in it fails.

Incremental-encoding contract (what this engine relies on from
``EncoderSession``): variable numbering is identical across the IIs of one
session; ``encode(ii)`` is side-effect-free and cheap after the first call
(C1 clauses are shared by reference); decoded placements use per-II kernel
cycles ``t % ii`` of the same underlying flat mobility times.

Equivalence guarantee: for any ``sweep_width`` the engine returns an II
less than or equal to the sequential reference (``map_loop`` with
``sweep_width=1``), and equal in every case where register allocation
judges the two modes' models alike. Candidates below a winner are never
cancelled, and a WalkSAT model that fails regalloc is treated as
*provisional* (the complete backend's model — the one the sequential
reference would have judged — still decides that II), so the sweep can
never report a *larger* II; it can only improve on the reference when the
racer finds a regalloc-friendly model the complete solver's own model
misses. Placements may differ between modes (different solver races find
different models); both are verified against sequential loop semantics
before being returned.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from .cgra import CGRA
from .dfg import DFG
from .encode import EncoderSession, Encoding
from .mapper import (IIAttempt, MapperConfig, MappingResult, note_pruned_ii)
from .regalloc import RegAllocResult, allocate
from .sat import SAT, UNKNOWN, UNSAT
from .sat.portfolio import solve_window
from .schedule import Infeasible, min_ii
from .simulator import verify_mapping


def map_sweep(dfg: DFG, cgra: CGRA, cfg: Optional[MapperConfig] = None,
              sweep_width: int = 4, service=None,
              session=None) -> MappingResult:
    """Map ``dfg`` onto ``cgra`` by sweeping candidate IIs in parallel
    windows of ``sweep_width``. Drop-in replacement for
    ``mapper.map_loop`` (which delegates here for ``sweep_width > 1``).

    ``cfg.routing`` is not supported by the parallel engine (route-node
    splicing changes the DFG mid-II, which serialises the search); callers
    wanting routing retries use the sequential path. ``cfg.warm_start``
    (CDCL phase hints from a heuristic placement) is likewise
    sequential-only: pool workers solve bare CNFs, so the hint is not
    applied here.

    ``service`` routes the request through a long-lived
    ``repro.core.service.MappingService`` (None = standalone, today's
    behaviour); ``session`` injects a warm ``SolverSession`` whose
    formula matches this (dfg, cgra, amo) shape. Candidate IIs the
    session has already refuted via a failed-assumption core are dropped
    from the window without a solve and recorded as via="core" UNSAT
    attempts — the window then spends its parallelism on undecided IIs
    only.
    """
    cfg = cfg or MapperConfig()
    if service is not None:
        return service.map(dfg, cgra, cfg, sweep_width=sweep_width)
    if cfg.routing:
        raise ValueError("map_sweep does not support routing=True; "
                         "use map_loop(sweep_width=1)")
    if sweep_width < 1:
        raise ValueError(f"sweep_width must be >= 1, got {sweep_width}")
    dfg.validate()
    t_start = time.time()
    deadline = t_start + cfg.timeout_s
    try:
        mii = min_ii(dfg, cgra)
    except Infeasible as e:
        return MappingResult(success=False, cgra=cgra, infeasible=str(e),
                             total_time=time.time() - t_start)
    max_ii = cfg.max_ii if cfg.max_ii is not None else mii + 16
    res = MappingResult(success=False, mii=mii, cgra=cgra)
    sess = session
    enc_session = sess.enc.session if sess is not None \
        else EncoderSession(dfg, cgra, cfg.amo)
    # the incremental core: one persistent layered formula + live complete
    # solver across every window of the sweep (see portfolio.SolverSession);
    # cfg.incremental=False keeps the cold per-II encode+solve reference.
    if sess is None and cfg.incremental:
        from .sat.portfolio import SolverSession
        sess = SolverSession(enc_session, method=cfg.solver, seed=cfg.seed,
                             max_learnt=cfg.max_learnt)

    # learned window-extent guidance (cfg.guide -> repro.core.guide). The
    # suggestion only ever picks how many candidate IIs the next window
    # spans; every II from MII upward still enters some window in
    # ascending order and the winner scan below still demands a proven
    # refutation of every lower candidate — so guidance cannot change the
    # final II, only the wall-clock spent finding it. Any guide failure
    # (unresolvable name, feature extraction, a garbage suggestion) falls
    # back to the unguided fixed width.
    sug = None
    if cfg.guide and sweep_width > 1:
        try:
            from .campaign import cell_features
            from .guide import resolve_guide
            g = resolve_guide(cfg.guide)
            if g is not None:
                sug = g.suggest(cell_features(dfg, cgra))
        except Exception:
            sug = None
        if sug is not None:
            res.guidance = {"guide": cfg.guide, "used": True,
                            "offset": int(sug.offset),
                            "order": [int(o) for o in sug.order],
                            "hopeless": float(sug.hopeless),
                            "spans": []}
        else:
            res.guidance = {"guide": cfg.guide, "used": False}

    base = mii
    while base <= max_ii:
        if time.time() > deadline:
            res.timed_out = True
            break
        if sess is not None and sess.all_unsat:
            # an empty failed-assumption core latched the session: the base
            # formula is UNSAT, no candidate II can ever map
            note_pruned_ii(sess, base, res.attempts)
            break
        width = sweep_width
        if sug is not None:
            try:
                width = int(sug.span_from(base - mii))
            except Exception:
                width = sweep_width
            width = max(1, min(width, max(sweep_width, 16)))
            res.guidance["spans"].append(width)
        window = list(range(base, min(base + width - 1, max_ii) + 1))
        # replay recorded UNSAT cores up front: those IIs never enter the
        # window, so its parallelism is spent on undecided candidates only
        iis: List[int] = []
        for ii in window:
            if sess is not None and sess.is_proven_unsat(ii):
                note_pruned_ii(sess, ii, res.attempts)
            else:
                iis.append(ii)
        if not iis:
            base = window[-1] + 1
            continue
        encs: List[Encoding] = []
        enc_times: List[float] = []
        cnfs = []
        stats_list: List[Dict[str, int]] = []
        for ii in iis:
            t0 = time.time()
            if sess is not None:
                sess.ensure_ii(ii)
                stats_list.append(sess.stats_for(ii))
            else:
                encs.append(enc_session.encode(ii))
                stats_list.append(encs[-1].stats)
            enc_times.append(time.time() - t0)
        if sess is not None:
            # projections materialised only after the whole window is
            # encoded, so their variable space is window-consistent
            cnfs = [sess.project(ii) for ii in iis]
        else:
            cnfs = [e.cnf for e in encs]

        def decode(i: int, model: List[bool]):
            if sess is not None:
                return sess.enc.decode(iis[i], model)
            return encs[i].decode(model)

        # regalloc results captured by the accept callback, keyed by window
        # index; accept returns True (=> cancel all higher IIs) only when
        # register allocation also succeeds, mirroring Fig. 3's criterion.
        placements: Dict[int, Tuple[Dict[int, Tuple[int, int, int]],
                                    RegAllocResult]] = {}

        def accept(i: int, model: List[bool]) -> bool:
            placement = decode(i, model)
            ra = allocate(dfg, cgra, placement, iis[i])
            placements[i] = (placement, ra)
            return ra.ok

        wres = solve_window(
            cnfs, method=cfg.solver, seed=cfg.seed,
            deadline=deadline, accept=accept, session=sess, iis=iis,
            race_flip=cfg.race_flip)

        winner: Optional[int] = None
        blocked = False   # an unresolved candidate below the best SAT
        for i, ii in enumerate(iis):
            r = wres[i]
            att = IIAttempt(
                ii=ii, n_vars=stats_list[i]["vars"],
                n_clauses=stats_list[i]["clauses"], status=r.status,
                solve_time=r.solve_time, encode_time=enc_times[i],
                via=r.via if r.status in (SAT, UNSAT) else "")
            if r.stats is not None:
                att.learned_retained = r.stats.learned_retained
                att.conflicts = r.stats.conflicts
                att.warm_hamming = r.stats.warm_hamming
                att.evicted = r.stats.evicted
                att.phase_hinted = r.stats.phase_hinted
            if i in placements:
                att.regalloc_ok = placements[i][1].ok
            res.attempts.append(att)
            if winner is None and not blocked:
                if r.status == SAT and placements[i][1].ok:
                    winner = i
                elif r.status == UNKNOWN and r.via != "walksat":
                    # undecided below any winner (deadline, killed solver):
                    # equivalence with the sequential loop is lost, so stop
                    # here rather than report a possibly non-minimal II.
                    # (UNKNOWN from the incomplete walksat-only mode is not
                    # blocking — the sequential reference also just moves
                    # to the next II.)
                    blocked = True

        if winner is not None:
            placement, ra = placements[winner]
            chk = verify_mapping(dfg, cgra, placement, iis[winner],
                                 n_iters=cfg.verify_iters)
            if not chk.ok:
                raise AssertionError(
                    f"sweep produced an invalid mapping at II={iis[winner]}: "
                    f"{chk.errors[:3]}")
            res.success = True
            res.ii = iis[winner]
            res.placement = placement
            res.regalloc = ra
            res.dfg = dfg
            break
        if blocked:
            res.timed_out = time.time() > deadline
            break
        base = window[-1] + 1

    res.total_time = time.time() - t_start
    return res

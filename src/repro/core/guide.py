"""Learned II guidance for the sweep: predict where the feasible II lives.

A small MLP trained on campaign cell records (:mod:`repro.core.campaign`)
maps :func:`~repro.core.campaign.cell_features` — DFG statistics, the KMS
mobility histogram, fabric geometry/capability summary — to (a) a
distribution over the *II offset* (final II − MII, bucketed to
``N_OFFSETS``) and (b) a *hopelessness* probability (the sweep will refute
every candidate II). The sweep consumes predictions through
:meth:`IIGuide.suggest`.

**Soundness contract.** Guidance is advisory only: it chooses the sweep's
*window extents* (how many candidate IIs to encode and race per round),
never which IIs exist. The sweep still walks every II from MII upward in
ascending order and only reports a winner once every lower candidate holds
a proven refutation — so the guided final II is bit-identical to the
unguided one on every input, by construction (property-tested over the
whole suite in ``tests/test_guide.py``). A guide that predicts garbage can
only waste or save wall-clock.

**Fork-safety.** The prediction path (:class:`IIGuide`) is pure numpy —
it runs inside :class:`~repro.core.workers.WorkerPool` shards, which fork
before anything XLA-ish may initialise. jax + optax are imported lazily
inside :func:`train_guide` only.

Guides are referenced by *name* (``MapperConfig.guide`` is a string so
configs stay hashable/serialisable for the service cache and the store):
:func:`resolve_guide` looks the name up in a process registry first
(:func:`register_guide` — how campaigns and tests inject guides, including
adversarial stubs) and falls back to loading an ``.npz`` checkpoint path.
"""
from __future__ import annotations

import math
import os
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .campaign import N_FEATURES

# offset buckets: final II - MII clipped to [0, N_OFFSETS-1]; the last
# bucket absorbs "far above MII" (offsets that large are rare and the
# sweep's max-span cap truncates any suggestion anyway)
N_OFFSETS = 8

# the widest window a suggestion may open (in IIs); also the width used
# for cells predicted hopeless — burn through the II range in few rounds
MAX_GUIDED_SPAN = 8


@dataclass
class GuideSuggestion:
    """One prediction, ready for the sweep: ``order`` is every offset
    bucket sorted most-probable first, ``offset`` its head, ``hopeless``
    the probability that no candidate II maps at all."""
    offset: int
    order: Tuple[int, ...]
    probs: Tuple[float, ...]
    hopeless: float

    def span_from(self, base_offset: int) -> int:
        """Window width (in IIs) to open at ``base_offset`` = base - MII:
        wide enough to cover the most probable not-yet-refuted offset, at
        least 1, at most :data:`MAX_GUIDED_SPAN`. Cells predicted hopeless
        get the full span — every candidate needs refuting anyway."""
        if self.hopeless > 0.5:
            return MAX_GUIDED_SPAN
        for off in self.order:
            if off >= base_offset:
                return max(1, min(off - base_offset + 1, MAX_GUIDED_SPAN))
        return 1


class IIGuide:
    """Numpy forward pass of the trained MLP (one tanh hidden layer, a
    softmax offset head and a sigmoid hopelessness head, with input
    standardisation folded into the parameters)."""

    PARAM_KEYS = ("mean", "std", "w1", "b1", "wo", "bo", "wh", "bh")

    def __init__(self, params: Dict[str, np.ndarray]):
        missing = [k for k in self.PARAM_KEYS if k not in params]
        if missing:
            raise ValueError(f"guide params missing {missing}")
        self.params = {k: np.asarray(params[k], dtype=np.float32)
                       for k in self.PARAM_KEYS}
        if self.params["w1"].shape[0] != N_FEATURES:
            raise ValueError(
                f"guide expects {self.params['w1'].shape[0]} features, "
                f"campaign emits {N_FEATURES}")

    # ------------------------------------------------------------ forward
    def _forward(self, x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        p = self.params
        z = (x - p["mean"]) / p["std"]
        h = np.tanh(z @ p["w1"] + p["b1"])
        logits = h @ p["wo"] + p["bo"]
        logits = logits - logits.max(axis=-1, keepdims=True)
        e = np.exp(logits)
        probs = e / e.sum(axis=-1, keepdims=True)
        hop = 1.0 / (1.0 + np.exp(-(h @ p["wh"] + p["bh"])))
        return probs, hop

    def predict(self, features: np.ndarray
                ) -> Tuple[np.ndarray, float]:
        """(offset probabilities over ``N_OFFSETS`` buckets, hopelessness
        probability) for one feature vector."""
        x = np.asarray(features, dtype=np.float32).reshape(1, -1)
        probs, hop = self._forward(x)
        return probs[0], float(hop.reshape(-1)[0])

    def suggest(self, features: np.ndarray) -> GuideSuggestion:
        """Sanitised, sweep-ready suggestion: NaN/inf-free probabilities
        (a degenerate forward pass degrades to the uniform 'no opinion'
        prediction — never an exception on the mapping path)."""
        probs, hop = self.predict(features)
        probs = np.nan_to_num(probs, nan=0.0, posinf=0.0, neginf=0.0)
        if probs.sum() <= 0:
            probs = np.full(N_OFFSETS, 1.0 / N_OFFSETS, dtype=np.float32)
        if not math.isfinite(hop):
            hop = 0.0
        # stable sort: ties resolve lowest-offset-first
        order = tuple(int(o) for o in
                      np.argsort(-probs, kind="stable"))
        return GuideSuggestion(
            offset=order[0], order=order,
            probs=tuple(float(v) for v in probs),
            hopeless=min(1.0, max(0.0, float(hop))))

    # -------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        np.savez(path, **self.params)

    @classmethod
    def load(cls, path: str) -> "IIGuide":
        with np.load(path) as z:
            return cls({k: z[k] for k in cls.PARAM_KEYS})


def init_guide(seed: int = 0, hidden: int = 32) -> IIGuide:
    """A randomly initialised (untrained) guide — the training starting
    point, and a handy stand-in for tests."""
    rng = np.random.default_rng(seed)
    s1 = 1.0 / math.sqrt(N_FEATURES)
    s2 = 1.0 / math.sqrt(hidden)
    return IIGuide({
        "mean": np.zeros(N_FEATURES, dtype=np.float32),
        "std": np.ones(N_FEATURES, dtype=np.float32),
        "w1": rng.normal(0, s1, (N_FEATURES, hidden)).astype(np.float32),
        "b1": np.zeros(hidden, dtype=np.float32),
        "wo": rng.normal(0, s2, (hidden, N_OFFSETS)).astype(np.float32),
        "bo": np.zeros(N_OFFSETS, dtype=np.float32),
        "wh": rng.normal(0, s2, (hidden, 1)).astype(np.float32),
        "bh": np.zeros(1, dtype=np.float32),
    })


# ---------------------------------------------------------------- registry

_REGISTRY: Dict[str, object] = {}
_REG_LOCK = threading.Lock()


def register_guide(name: str, guide) -> None:
    """Install ``guide`` (an :class:`IIGuide`, or any object with a
    compatible ``suggest(features)``) under ``name`` for this process.
    ``None`` removes the entry."""
    with _REG_LOCK:
        if guide is None:
            _REGISTRY.pop(name, None)
        else:
            _REGISTRY[name] = guide


def clear_guides() -> None:
    with _REG_LOCK:
        _REGISTRY.clear()


def resolve_guide(spec: Optional[str]):
    """Resolve a ``MapperConfig.guide`` string: a registered name wins,
    otherwise an existing ``.npz`` checkpoint path is loaded (and cached
    in the registry so worker processes pay the load once). Returns None
    for unresolvable specs — the sweep then runs unguided."""
    if not spec:
        return None
    with _REG_LOCK:
        g = _REGISTRY.get(spec)
    if g is not None:
        return g
    if os.path.exists(spec):
        try:
            g = IIGuide.load(spec)
        except Exception:
            return None
        register_guide(spec, g)
        return g
    return None


# ---------------------------------------------------------------- training


def _dataset_arrays(records: Sequence, holdout_byte: int = 64,
                    ) -> Tuple[np.ndarray, ...]:
    """Stack campaign records into train/held-out arrays. The split is
    *deterministic* and content-keyed: a record is held out iff the first
    byte of its cell key is below ``holdout_byte`` (≈ holdout_byte/256 of
    the data) — stable across runs, shards, and processes. Structurally
    infeasible cells are dropped (the fabric can never run them, there is
    nothing to predict); refuted-everywhere cells keep offset bucket
    ``N_OFFSETS - 1`` and label the hopelessness head."""
    Xs: List[np.ndarray] = []
    yo: List[int] = []
    yh: List[float] = []
    held: List[bool] = []
    for rec in records:
        if rec.infeasible:
            continue
        off = rec.offset
        if off is None:
            off = N_OFFSETS - 1
        Xs.append(np.asarray(rec.features, dtype=np.float32))
        yo.append(min(max(int(off), 0), N_OFFSETS - 1))
        yh.append(0.0 if rec.success else 1.0)
        held.append(rec.key[0] < holdout_byte)
    if not Xs:
        raise ValueError("no trainable cells in the dataset")
    X = np.stack(Xs)
    yo_a = np.asarray(yo, dtype=np.int32)
    yh_a = np.asarray(yh, dtype=np.float32)
    held_a = np.asarray(held, dtype=bool)
    return X, yo_a, yh_a, held_a


def evaluate_guide(guide: IIGuide, X: np.ndarray, yo: np.ndarray,
                   ) -> Dict[str, float]:
    """hit@1 / hit@2 of the offset head vs the always-offset-0 baseline
    (the unguided sweep's implicit prediction: start at MII)."""
    probs, _hop = guide._forward(X.astype(np.float32))
    top2 = np.argsort(-probs, axis=-1, kind="stable")[:, :2]
    hit1 = float(np.mean(top2[:, 0] == yo))
    hit2 = float(np.mean((top2[:, 0] == yo) | (top2[:, 1] == yo)))
    return {"hit1": hit1, "hit2": hit2,
            "baseline_hit1": float(np.mean(yo == 0)),
            "n": int(len(yo))}


def train_guide(records: Sequence, seed: int = 0, hidden: int = 32,
                epochs: int = 300, lr: float = 3e-3,
                batch: int = 256, holdout_byte: int = 64,
                ) -> Tuple[IIGuide, Dict[str, float]]:
    """Train an :class:`IIGuide` on campaign cell records with jax +
    optax (adam, cross-entropy on the offset head + binary cross-entropy
    on the hopelessness head). Returns (guide, metrics): held-out hit@1 /
    hit@2 vs the always-start-at-MII baseline, plus split sizes.

    jax is imported here, not at module top — callers on the worker-pool
    fork path only ever touch the numpy :class:`IIGuide`."""
    import jax
    import jax.numpy as jnp
    import optax

    X, yo, yh, held = _dataset_arrays(records, holdout_byte)
    Xtr, ytr_o, ytr_h = X[~held], yo[~held], yh[~held]
    Xte, yte_o = X[held], yo[held]
    if len(Xtr) == 0:          # tiny corpora: train on everything
        Xtr, ytr_o, ytr_h = X, yo, yh
    mean = Xtr.mean(axis=0)
    std = Xtr.std(axis=0)
    std[std < 1e-6] = 1.0

    g0 = init_guide(seed=seed, hidden=hidden)
    params = {k: jnp.asarray(g0.params[k]) for k in ("w1", "b1", "wo",
                                                     "bo", "wh", "bh")}
    Z = jnp.asarray((Xtr - mean) / std)
    Yo = jnp.asarray(ytr_o)
    Yh = jnp.asarray(ytr_h)

    def loss_fn(p, z, y_off, y_hop):
        h = jnp.tanh(z @ p["w1"] + p["b1"])
        logits = h @ p["wo"] + p["bo"]
        ce = optax.softmax_cross_entropy_with_integer_labels(
            logits, y_off).mean()
        hop_logit = (h @ p["wh"] + p["bh"]).reshape(-1)
        bce = optax.sigmoid_binary_cross_entropy(hop_logit, y_hop).mean()
        return ce + 0.25 * bce

    opt = optax.adam(lr)
    state = opt.init(params)

    @jax.jit
    def step(p, s, z, y_off, y_hop):
        loss, grads = jax.value_and_grad(loss_fn)(p, z, y_off, y_hop)
        updates, s = opt.update(grads, s, p)
        return optax.apply_updates(p, updates), s, loss

    n = len(Xtr)
    key = jax.random.PRNGKey(seed)
    loss = jnp.float32(0)
    for ep in range(epochs):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n)
        for i in range(0, n, batch):
            idx = perm[i:i + batch]
            params, state, loss = step(params, state, Z[idx], Yo[idx],
                                       Yh[idx])

    final = {k: np.asarray(v, dtype=np.float32)
             for k, v in params.items()}
    final["mean"] = mean.astype(np.float32)
    final["std"] = std.astype(np.float32)
    guide = IIGuide(final)
    metrics: Dict[str, float] = {
        "n_train": int(len(Xtr)), "n_heldout": int(len(Xte)),
        "final_loss": float(loss),
    }
    if len(Xte):
        metrics.update(evaluate_guide(guide, Xte, yte_o))
    else:
        metrics.update({"hit1": 0.0, "hit2": 0.0, "baseline_hit1": 0.0,
                        "n": 0})
    return guide, metrics


__all__ = [
    "N_OFFSETS", "MAX_GUIDED_SPAN", "GuideSuggestion", "IIGuide",
    "init_guide", "register_guide", "clear_guides", "resolve_guide",
    "evaluate_guide", "train_guide",
]

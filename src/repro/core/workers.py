"""Multi-process solve workers: the parallelism layer of the serving tier.

:class:`WorkerPool` fans ``map()`` requests out over N solver *shards*.
Each shard is a single-worker forked process running its own
:class:`~repro.core.service.MappingService` over the **shared**
:class:`~repro.core.store.MappingStore` directory — so every shard sees
every other shard's persisted mappings and proven-UNSAT cores, while its
in-memory warm state (pooled solver sessions, learnt clauses, near-shape
lattice) stays process-local and lock-free.

Requests are routed by **affinity**: the shard index is a stable hash of
(topology signature, near-shape lattice bucket), so every request in one
kernel *family* lands on the same shard and keeps hitting that shard's
warm sessions — the near-shape admission of
:func:`repro.core.service.near_shape_key` only pays off if family members
actually meet. Different families ride different shards and solve in true
parallel (separate processes, no GIL).

Fork-safety: this module's import chain is deliberately jax-free (see the
note in ``core/sat/portfolio.py``) — shards fork *clean* and only a
shard's own walksat racer ever initialises XLA, inside the child. Where
fork is unavailable (or ``inline=True``), the pool degrades to
single-worker *thread* shards over one shared thread-safe service: same
API, same affinity serialisation, no process isolation.
"""
from __future__ import annotations

import multiprocessing
import os
import struct
from concurrent.futures import Future, ProcessPoolExecutor, ThreadPoolExecutor
from typing import Dict, List, Optional

from .cgra import CGRA
from .dfg import DFG
from .mapper import MapperConfig, MappingResult
from .service import (MappingService, near_shape_key, shape_signature,
                      topology_signature)
from .store import MappingStore, key_hash

# ------------------------------------------------- worker-process globals

_WORKER_SVC: Optional[MappingService] = None


def _worker_init(store_path: Optional[str], near_delta: int,
                 max_sessions: int, cache_size: int) -> None:
    global _WORKER_SVC
    store = MappingStore(store_path) if store_path else None
    _WORKER_SVC = MappingService(max_sessions=max_sessions,
                                 cache_size=cache_size, store=store,
                                 near_delta=near_delta)


def _svc() -> MappingService:
    # real raise, not assert: shard entrypoints must guard under -O too
    if _WORKER_SVC is None:
        raise RuntimeError("worker not initialised: _worker_init() did not "
                           "run in this process")
    return _WORKER_SVC


def _worker_map(dfg: DFG, cgra: CGRA, cfg: MapperConfig, sweep_width: int,
                use_cache: bool) -> MappingResult:
    return _svc().map(dfg, cgra, cfg, sweep_width=sweep_width,
                      use_cache=use_cache)


def _worker_stats() -> Dict:
    return _svc().describe()


# ------------------------------------------------------------------ pool


class WorkerPool:
    """N affinity-routed solver shards over one shared store directory.

    ``submit()`` returns a ``concurrent.futures.Future`` resolving to the
    shard's :class:`MappingResult`; ``map()`` is the blocking convenience.
    ``workers=0`` (or fork unavailable) runs inline thread shards over one
    shared service — identical semantics minus process isolation.
    """

    def __init__(self, workers: Optional[int] = None,
                 store_path: Optional[str] = None, near_delta: int = 1,
                 max_sessions: int = 64, cache_size: int = 512,
                 inline: bool = False):
        if workers is None:
            workers = max(1, min(4, (os.cpu_count() or 2) - 1))
        self.n_workers = max(1, workers)
        self.store_path = store_path
        self.near_delta = near_delta
        self.inline = inline or workers == 0
        self._shards: List = []
        self._inline_svc: Optional[MappingService] = None
        if not self.inline:
            try:
                ctx = multiprocessing.get_context("fork")
                for _ in range(self.n_workers):
                    ex = ProcessPoolExecutor(
                        max_workers=1, mp_context=ctx,
                        initializer=_worker_init,
                        initargs=(store_path, near_delta, max_sessions,
                                  cache_size))
                    self._shards.append(ex)
                # fork every worker now, before the caller does anything
                # XLA-ish in this process
                for f in [ex.submit(os.getpid) for ex in self._shards]:
                    f.result(timeout=60)
            except Exception:
                for ex in self._shards:
                    ex.shutdown(wait=False, cancel_futures=True)
                self._shards = []
                self.inline = True
        if self.inline:
            store = MappingStore(store_path) if store_path else None
            self._inline_svc = MappingService(
                max_sessions=max_sessions, cache_size=cache_size,
                store=store, near_delta=near_delta)
            self._shards = [ThreadPoolExecutor(max_workers=1)
                            for _ in range(self.n_workers)]

    # ---------------------------------------------------------- routing
    def shard_of(self, dfg: DFG, cgra: CGRA,
                 cfg: Optional[MapperConfig] = None) -> int:
        """Affinity shard for a request: one kernel family (same topology
        + near-shape bucket + solver knobs), one shard, forever."""
        cfg = cfg or MapperConfig()
        shape = shape_signature(dfg, cgra)
        fam = (topology_signature(cgra),
               near_shape_key(shape, max(1, self.near_delta)),
               cfg.amo, cfg.solver, cfg.seed)
        h = key_hash(fam)
        return struct.unpack("<Q", h[:8])[0] % self.n_workers

    # -------------------------------------------------------------- API
    def submit(self, dfg: DFG, cgra: CGRA,
               cfg: Optional[MapperConfig] = None, sweep_width: int = 1,
               use_cache: bool = True) -> Future:
        cfg = cfg or MapperConfig()
        shard = self._shards[self.shard_of(dfg, cgra, cfg)]
        if self.inline:
            svc = self._inline_svc
            return shard.submit(svc.map, dfg, cgra, cfg,
                                sweep_width=sweep_width,
                                use_cache=use_cache)
        return shard.submit(_worker_map, dfg, cgra, cfg, sweep_width,
                            use_cache)

    def map(self, dfg: DFG, cgra: CGRA, cfg: Optional[MapperConfig] = None,
            sweep_width: int = 1, use_cache: bool = True,
            timeout: Optional[float] = None) -> MappingResult:
        return self.submit(dfg, cgra, cfg, sweep_width,
                           use_cache).result(timeout=timeout)

    # -------------------------------------------------------- inspection
    def stats(self) -> Dict:
        """Aggregated per-shard service counters (sum across shards, plus
        the per-shard breakdown under ``"shards"``)."""
        if self.inline:
            per = [self._inline_svc.describe()]
        else:
            per = []
            for ex in self._shards:
                try:
                    per.append(ex.submit(_worker_stats).result(timeout=30))
                except Exception:
                    per.append({})
        total: Dict = {}
        for d in per:
            for k, v in d.items():
                if isinstance(v, (int, float)):
                    total[k] = total.get(k, 0) + v
        total["shards"] = per
        total["n_workers"] = self.n_workers
        total["inline"] = self.inline
        return total

    def shutdown(self, wait: bool = True) -> None:
        for ex in self._shards:
            ex.shutdown(wait=wait, cancel_futures=not wait)
        self._shards = []

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

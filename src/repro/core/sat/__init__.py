"""SAT solver backends.

``solve(cnf, method=...)`` dispatches to:
  * "cdcl"    — our own CDCL (watched literals, VSIDS, Luby restarts,
                phase saving). Always available; host CPU.
  * "z3"      — Z3 (the paper's solver), when importable.
  * "walksat" — batched probSAT in JAX (TPU-native portfolio path);
                incomplete: returns UNKNOWN instead of UNSAT.
  * "auto"    — z3 if available else cdcl.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from ..cnf import CNF

SAT, UNSAT, UNKNOWN = "SAT", "UNSAT", "UNKNOWN"


def resolve_method(method: str) -> str:
    """Resolve "auto" to the concrete complete backend used on this host."""
    if method == "auto":
        return "z3" if _has_z3() else "cdcl"
    return method


def solve(cnf: CNF, method: str = "auto", *, max_conflicts: Optional[int] = None,
          phase_hint: Optional[List[bool]] = None, seed: int = 0,
          walksat_steps: int = 20000, walksat_batch: int = 64,
          stop: Optional[Callable[[], bool]] = None,
          ) -> Tuple[str, Optional[List[bool]]]:
    if getattr(cnf, "trivially_unsat", False):
        # an empty clause was recorded (CNF.add_clause marker): fail fast
        # and identically across every backend
        return UNSAT, None
    method = resolve_method(method)
    if method == "z3":
        from .z3_backend import solve_z3
        return solve_z3(cnf, stop=stop)
    if method == "cdcl":
        from .cdcl import CDCLSolver
        return CDCLSolver(cnf).solve(max_conflicts=max_conflicts,
                                     phase_hint=phase_hint, stop=stop)
    if method == "walksat":
        from .walksat_jax import solve_walksat
        return solve_walksat(cnf, seed=seed, steps=walksat_steps,
                             batch=walksat_batch, stop=stop)
    if method == "portfolio":
        from .portfolio import solve_portfolio
        return solve_portfolio(cnf, seed=seed, stop=stop)
    raise ValueError(f"unknown SAT method {method!r}")


def _has_z3() -> bool:
    try:
        import z3  # noqa: F401
        return True
    except ImportError:
        return False

"""Mapper-search portfolio.

On real hardware the probSAT batch is sharded across the mesh with
shard_map — each device runs an independent slice of chains (different
seeds/noise), an all_reduce(max) on the solved flag elects a winner, and the
host falls back to a complete solver only for the UNSAT certificate. On this
CPU container the same code path runs with a single device; the structure is
identical.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..cnf import CNF


def solve_portfolio(cnf: CNF, *, seed: int = 0, steps: int = 8192,
                    chains_per_device: int = 32,
                    ) -> Tuple[str, Optional[List[bool]]]:
    """Incomplete sharded search first, complete solver as fallback."""
    from . import SAT, UNKNOWN
    from .walksat_jax import solve_walksat
    from . import solve as solve_any

    n_dev = jax.device_count()
    status, model = solve_walksat(
        cnf, seed=seed, steps=steps, batch=chains_per_device * n_dev)
    if status == SAT:
        return status, model
    # complete fallback (z3 if available, else our CDCL)
    return solve_any(cnf, method="auto")


def sharded_chain_batch(n_vars: int, chains_per_device: int, seed: int,
                        mesh: Mesh, axis: str = "data") -> jnp.ndarray:
    """Device-sharded initial assignments for the portfolio: [D*B, V+1] bool
    sharded over ``axis``. Used by launch-time portfolio runs on a pod."""
    n_dev = mesh.shape[axis]
    total = n_dev * chains_per_device
    key = jax.random.PRNGKey(seed)
    init = jax.random.bernoulli(key, 0.5, (total, n_vars + 1))
    return jax.device_put(init, NamedSharding(mesh, P(axis, None)))

"""Mapper-search portfolio: single-instance racing, window solving, and the
persistent incremental ``SolverSession``.

``solve_portfolio`` is the per-instance portfolio (incomplete sharded
probSAT first, complete solver for the UNSAT certificate) — deterministic
for a fixed seed because the two legs run sequentially.

``SolverSession`` is the assumption-based incremental core: it owns one
layered formula (``repro.core.encode.IncrementalEncoding``) and one
persistent complete solver for the whole II sweep, so "try II=k" is an
assumption solve that retains every clause learned at earlier IIs, and the
WalkSAT leg warm-starts from the previous II's best near-miss assignment
(the shared variable numbering makes assignments comparable across IIs).

``solve_window`` is the engine room of the parallel II-sweep
(``repro.core.sweep``): it takes the CNFs of a window of candidate IIs and
solves them concurrently —

  * the complete backend runs on every candidate, lowest II first — our
    CDCL in a persistent fork-started process pool (real parallelism for
    the UNSAT proofs; CPython threads would serialise on the GIL), z3 (which
    releases the GIL inside check()) on a thread pool when importable;
  * one staged racer thread runs the *batched* WalkSAT
    (``solve_walksat_window``), which vmaps restarts across all candidates
    on the clause tensors, so the JAX leg certifies hard SAT instances
    while the complete leg grinds on the proofs;
  * per-candidate stop events implement early cancellation: the caller's
    ``accept`` callback may kill all higher-II work the moment a lower II
    returns SAT + regalloc-OK.

On real hardware the probSAT batch is additionally sharded across the mesh
with shard_map — each device runs an independent slice of chains (different
seeds/noise), an all_reduce(max) on the solved flag elects a winner, and the
host falls back to a complete solver only for the UNSAT certificate. On this
CPU container the same code path runs with a single device; the structure is
identical.
"""
from __future__ import annotations

import multiprocessing
import os
import threading
import time
import warnings
from collections import OrderedDict
from concurrent.futures import (FIRST_COMPLETED, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait as futures_wait)
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

# jax is imported lazily inside the functions that touch it: the serving
# tier forks worker processes off modules that import this file, and a
# child forked after the parent initialised XLA inherits its runtime locks
# (the classic fork-after-jax deadlock). Keeping the module import jax-free
# lets `core.workers` fork clean solver processes; only the walksat/
# portfolio legs — which the cdcl/z3 worker paths never enter — pay the
# deferred import.

from ..cnf import CNF

CANCELLED = "CANCELLED"

# ------------------------------------------------------------- process pool
# CPython's GIL serialises the pure-Python CDCL, so concurrent UNSAT proofs
# inside one process gain nothing from threads. The window solver therefore
# runs the CDCL leg in a small persistent process pool; z3 releases the GIL
# and stays on threads. Fork context: spawn would re-execute unguarded
# parent scripts' module level in every worker, and the workers only ever
# run the dependency-free CDCL (never JAX/XLA), which is fork-safe. The
# pool is created lazily and reused across windows. Non-Linux hosts without
# fork fall back to threads transparently.
_PROC_POOL: Optional[ProcessPoolExecutor] = None
_PROC_POOL_BROKEN = False
_PROC_POOL_COOLDOWN_UNTIL = 0.0


def _proc_pool() -> Optional[ProcessPoolExecutor]:
    global _PROC_POOL, _PROC_POOL_BROKEN
    if _PROC_POOL_BROKEN:
        return None
    if _PROC_POOL is None and time.time() < _PROC_POOL_COOLDOWN_UNTIL:
        # a pool was just torn down (deadline kill); an unjoined racer
        # thread may still be draining its last XLA chunk, and forking
        # while it runs is the hazard the pre-fork below exists to avoid.
        # Callers fall back to threads for this brief window.
        return None
    if _PROC_POOL is None:
        # jax warns that fork + its internal threads can deadlock the child;
        # our workers run only the dependency-free pure-Python CDCL and
        # never call back into XLA, so that hazard doesn't apply — silence
        # the specific warning rather than scare every sweep user
        warnings.filterwarnings(
            "ignore", message=r"os\.fork\(\) was called",
            category=RuntimeWarning)
        try:
            n = max(2, os.cpu_count() or 2)
            pool = ProcessPoolExecutor(
                max_workers=n,
                mp_context=multiprocessing.get_context("fork"))
            # Pre-fork every worker NOW, while no racer thread is mid-XLA:
            # lazy forking in a later window could otherwise snapshot a
            # walksat thread holding runtime locks. sleep() keeps all n
            # tasks occupied long enough that n distinct workers spawn.
            futures_wait([pool.submit(time.sleep, 0.05) for _ in range(n)])
            _PROC_POOL = pool
        except Exception:
            _PROC_POOL_BROKEN = True
            return None
    return _PROC_POOL


def _reset_pool() -> None:
    """Tear down the pool, killing any still-running proofs, so a window
    that blew its deadline cannot starve the next map's windows. The next
    sweep lazily builds a fresh pool (after a short cooldown that lets any
    leaked racer thread drain before we fork again)."""
    global _PROC_POOL, _PROC_POOL_COOLDOWN_UNTIL
    pool, _PROC_POOL = _PROC_POOL, None
    _PROC_POOL_COOLDOWN_UNTIL = time.time() + 2.0
    if pool is None:
        return
    try:
        for p in list(getattr(pool, "_processes", {}).values()):
            p.terminate()
        pool.shutdown(wait=False, cancel_futures=True)
    except Exception:
        pass


def solve_portfolio(cnf: CNF, *, seed: int = 0, steps: int = 8192,
                    chains_per_device: int = 32,
                    stop: Optional[Callable[[], bool]] = None,
                    ) -> Tuple[str, Optional[List[bool]]]:
    """Incomplete sharded search first, complete solver as fallback.

    Deterministic for a fixed seed: the WalkSAT leg either certifies SAT
    (same model every run — jax PRNG is seed-deterministic) or the complete
    leg decides; there is no wall-clock race in this single-instance path.
    """
    import jax
    from . import SAT, UNKNOWN
    from .walksat_jax import solve_walksat
    from . import solve as solve_any

    n_dev = jax.device_count()
    status, model = solve_walksat(
        cnf, seed=seed, steps=steps, batch=chains_per_device * n_dev,
        stop=stop)
    if status == SAT:
        return status, model
    # complete fallback (z3 if available, else our CDCL)
    return solve_any(cnf, method="auto", stop=stop)


@dataclass
class SolveStats:
    """Reuse statistics of one incremental solve (see IIAttempt)."""
    learned_retained: Optional[int] = None   # clauses carried into this call
    conflicts: Optional[int] = None          # conflicts of this call
    warm_hamming: Optional[int] = None       # warm-start init vs final model
    via: str = ""
    # failed-assumption core of an UNSAT verdict (subset of the selector
    # assumptions; [] = formula UNSAT regardless of II); None when the
    # call was SAT/UNKNOWN or the backend produced no core
    core: Optional[List[int]] = None
    evicted: Optional[int] = None            # learnt clauses evicted so far
    # the complete solve was seeded with the session's best (near-miss)
    # assignment as CDCL saved phases — the walksat racer's asynchronous
    # feedback channel into the complete leg
    phase_hinted: bool = False
    # the walksat leg reused a cached dense pack of this II's projection
    # instead of re-packing (None = no walksat leg ran)
    pack_reused: Optional[bool] = None


class SolverSession:
    """Persistent incremental solver owned by the Fig. 3 loop.

    One layered formula + one live complete backend cover every candidate
    II of a sweep: ``solve_complete(ii)`` is ``solve(assumptions=[sel_ii])``
    on the persistent solver (z3's lemmas / our CDCL's learned clauses,
    activities, and phases all survive the II bump because delta layers are
    guarded, never retracted), and ``solve_ii(ii)`` additionally honours
    the incomplete/portfolio method semantics with WalkSAT warm-started
    from the best assignment any earlier II produced.

    The cold path (fresh encode+solve per II) remains available via
    ``MapperConfig(incremental=False)`` as the equivalence reference.

    Service extensions: ``max_learnt`` bounds the persistent CDCL's
    learnt-clause database (a long-lived session survives thousands of
    sweeps with bounded memory); every UNSAT verdict's failed-assumption
    core is recorded in ``proven_unsat`` so later sweeps through the same
    session skip provably-UNSAT IIs without re-solving them
    (``is_proven_unsat`` / ``proven_lower_bound``), and an *empty* core
    latches ``all_unsat`` — the formula is UNSAT at every II.
    """

    def __init__(self, enc_session, method: str = "auto", seed: int = 0,
                 walksat_steps: Optional[int] = None,
                 walksat_batch: Optional[int] = None,
                 max_learnt: Optional[int] = None):
        from . import resolve_method
        from ..encode import IncrementalEncoding
        self.enc = IncrementalEncoding(enc_session)
        self.raw_method = method
        self.complete_method = resolve_method(
            "auto" if method in ("walksat", "portfolio") else method)
        self.seed = seed
        # defaults track the cold legs' shapes (solve() for walksat,
        # solve_portfolio() for portfolio) so incremental and cold runs of
        # the same kernel share the probSAT XLA compile cache
        if method == "portfolio":
            import jax
            self.walksat_steps = walksat_steps or 8192
            self.walksat_batch = walksat_batch or 32 * jax.device_count()
        else:
            self.walksat_steps = walksat_steps or 20000
            self.walksat_batch = walksat_batch or 64
        self.max_learnt = max_learnt
        self._cdcl = None
        self._z3 = None
        self._synced = 0                      # clauses pushed to the backend
        self.best_assign: Optional[List[bool]] = None   # layout-var space
        self.best_quality: Optional[int] = None         # unsat count (0=model)
        self._best_lock = threading.Lock()    # racer threads update warm state
        self.n_solves = 0
        # II -> failed-assumption core that refuted it (proof, not budget)
        self.proven_unsat: Dict[int, Tuple[int, ...]] = {}
        self.all_unsat = False                # an empty core arrived
        self.pruned_total = 0                 # IIs skipped via a recorded core
        # asynchronous racer->complete feedback accounting: near-miss
        # assignments accepted into the warm state, and phase hints handed
        # out to complete solves (see phase_hint())
        self.near_miss_updates = 0
        self.phase_hints_served = 0
        # dense-pack caches for the walksat legs: per-II host packs and the
        # last stacked window pack, both keyed on the projection's identity
        # (arena literal count, n_vars) — the formula is append-only, so an
        # unchanged (length, vars) pair means an unchanged clause stream.
        # The per-II cache is LRU-bounded (``max_cached_packs``): a serving
        # process sweeps many IIs through one session, and each pack holds
        # dense O(clauses x max_len) tensors
        self._pack_np: "OrderedDict[int, Tuple[Tuple[int, int], object]]" \
            = OrderedDict()
        self._pack_window: Optional[Tuple[tuple, object]] = None
        self.max_cached_packs = 16
        self.pack_reuses = 0                  # cache hits across all legs
        self.pack_evictions = 0               # LRU drops from the pack cache

    # ------------------------------------------------------------- formula
    def ensure_ii(self, ii: int) -> None:
        self.enc.ensure_ii(ii)

    def project(self, ii: int) -> CNF:
        return self.enc.project(ii)

    def stats_for(self, ii: int):
        return self.enc.stats_for(ii)

    # ------------------------------------------------------------ pack cache
    def host_pack(self, ii: int) -> Tuple[object, bool]:
        """Dense host pack of ``project(ii)``, cached. Returns (pack,
        reused). The session formula only ever grows (layers are guarded,
        never retracted), so (arena literal count, n_vars) identifies the
        projection's exact clause stream — a matching key means the cached
        pack is bit-identical to what ``pack_cnf_np`` would rebuild."""
        from .walksat_jax import pack_cnf_np
        cnf = self.project(ii)
        key = (cnf.arena.n_lits, cnf.n_vars)
        hit = self._pack_np.get(ii)
        if hit is not None and hit[0] == key:
            self._pack_np.move_to_end(ii)
            self.pack_reuses += 1
            return hit[1], True
        pack = pack_cnf_np(cnf)
        self._pack_np[ii] = (key, pack)
        self._pack_np.move_to_end(ii)
        while len(self._pack_np) > self.max_cached_packs:
            self._pack_np.popitem(last=False)
            self.pack_evictions += 1
        return pack, False

    def packed_window(self, iis: List[int], cnfs: List[CNF],
                      ) -> Tuple[object, List[object], bool]:
        """Stacked device pack for a window of per-II projections, cached.
        Returns (packed, per-CNF host packs, reused). A warm sweep leg
        re-solving an unchanged window reuses the device tensors outright
        (zero packing); a grown window restacks from the per-II host-pack
        cache, repacking only the IIs whose projections changed."""
        from .walksat_jax import pack_cnf_window
        key = tuple((ii, c.arena.n_lits, c.n_vars)
                    for ii, c in zip(iis, cnfs))
        cached = self._pack_window
        host = [self.host_pack(ii)[0] for ii in iis]
        if cached is not None and cached[0] == key:
            self.pack_reuses += 1
            return cached[1], host, True
        packed = pack_cnf_window(cnfs, host)
        self._pack_window = (key, packed)
        return packed, host, False

    def _backend(self):
        if self.complete_method == "z3":
            if self._z3 is None:
                from .z3_backend import Z3IncrementalSolver
                self._z3 = Z3IncrementalSolver()
            return self._z3
        if self._cdcl is None:
            from .cdcl import CDCLSolver
            self._cdcl = CDCLSolver(max_learnt=self.max_learnt)
        return self._cdcl

    # --------------------------------------------------- UNSAT-core pruning
    def is_proven_unsat(self, ii: int) -> bool:
        """True when a failed-assumption core already refutes ``ii`` on
        this session's formula — solving it again is pure waste."""
        return self.all_unsat or ii in self.proven_unsat

    def note_core(self, ii: int, core: Optional[List[int]]) -> None:
        """Record an UNSAT verdict's failed-assumption core for ``ii``.
        Callers must only pass cores from *proven* UNSAT answers (the
        backends leave ``last_core=None`` on budget/stop UNKNOWNs, so a
        budget exhaustion can never be mislabeled as a refuted II)."""
        if core is None:
            return
        self.proven_unsat[ii] = tuple(core)
        if not core:
            # empty core: the refutation used no assumption at all — the
            # base formula is UNSAT, so every candidate II is
            self.all_unsat = True

    def proven_lower_bound(self, start_ii: int) -> int:
        """Smallest II >= ``start_ii`` not already refuted by a recorded
        core — the II lower bound this session can prove without solving."""
        ii = start_ii
        while self.is_proven_unsat(ii) and not self.all_unsat:
            ii += 1
        return ii

    @property
    def clauses_evicted(self) -> int:
        return self._cdcl.evicted_total if self._cdcl is not None else 0

    @property
    def learnt_db_size(self) -> int:
        return self._cdcl.learnt_db_size if self._cdcl is not None else 0

    def _sync(self):
        """Push clauses encoded since the last solve into the live solver
        (append-only: layers are guarded, nothing is ever retracted)."""
        backend = self._backend()
        inc = self.enc.inc
        if self._synced < len(inc.clauses):
            backend.add_clauses(inc.clauses[self._synced:], n_vars=inc.n_vars)
            self._synced = len(inc.clauses)
        return backend

    # -------------------------------------------------------------- solving
    def solve_complete(self, ii: int, stop: Optional[Callable[[], bool]] = None,
                       phase_hint: Optional[List[bool]] = None,
                       ) -> Tuple[str, Optional[List[bool]], SolveStats]:
        """Assumption-based solve of base + II's delta on the persistent
        complete backend."""
        self.ensure_ii(ii)
        assumptions = self.enc.assumptions(ii)
        backend = self._sync()
        stats = SolveStats(via=self.complete_method)
        if self.complete_method == "cdcl":
            stats.learned_retained = backend.n_learnt
            status, model = backend.solve(assumptions=assumptions, stop=stop,
                                          phase_hint=phase_hint)
            stats.conflicts = backend.last_conflicts
            stats.evicted = backend.evicted_total or None
        else:
            status, model = backend.solve(assumptions=assumptions, stop=stop)
            zst = backend.stats()
            stats.conflicts = int(zst.get("conflicts", 0)) or None
        self.n_solves += 1
        from . import SAT, UNSAT
        if status == UNSAT:
            # the failed-assumption core proves this II infeasible on this
            # formula forever; backends leave it None on budget/stop
            # UNKNOWNs, so only real refutations are recorded
            stats.core = getattr(backend, "last_core", None)
            self.note_core(ii, stats.core)
        if status == SAT and model:
            self.update_best(model, 0)
        return status, model, stats

    def solve_ii(self, ii: int, stop: Optional[Callable[[], bool]] = None,
                 phase_hint: Optional[List[bool]] = None,
                 ) -> Tuple[str, Optional[List[bool]], SolveStats]:
        """Per-II solve honouring the session's method semantics:
        ``walksat`` = warm-started incomplete only; ``portfolio`` =
        warm-started WalkSAT first, persistent complete solver as the
        fallback/certificate; anything else = ``solve_complete``."""
        from . import SAT
        if self.raw_method not in ("walksat", "portfolio"):
            return self.solve_complete(ii, stop=stop, phase_hint=phase_hint)
        from .walksat_jax import solve_walksat
        init = self.warm_init()
        near: dict = {}
        cnf = self.project(ii)
        pack, reused = self.host_pack(ii)
        status, model = solve_walksat(
            cnf, seed=self.seed, steps=self.walksat_steps,
            batch=self.walksat_batch, stop=stop, init=init, near_miss=near,
            pack=pack)
        if status == SAT:
            stats = SolveStats(via="walksat", pack_reused=reused)
            if init is not None:
                stats.warm_hamming = _hamming(init, model)
            self.update_best(model, 0)
            self.n_solves += 1
            return status, model, stats
        if 0 in near:
            self.update_best(near[0][1], near[0][0])
        if self.raw_method == "walksat":
            self.n_solves += 1
            return status, None, SolveStats(via="walksat",
                                            pack_reused=reused)
        return self.solve_complete(ii, stop=stop, phase_hint=phase_hint)

    # ------------------------------------------------------------ warm state
    def warm_init(self) -> Optional[List[bool]]:
        return self.best_assign

    def update_best(self, assign: List[bool], n_unsat: int) -> None:
        """Keep the highest-quality recent assignment as the next warm
        start: a full model (n_unsat=0) always wins; a near-miss replaces
        only a worse (or absent) near-miss. Locked: the window racer
        thread and the complete leg both report here."""
        nv = self.enc.inc.n_base_vars or self.enc.inc.n_vars
        with self._best_lock:
            if n_unsat == 0 or self.best_quality is None \
                    or self.best_quality > n_unsat:
                self.best_assign = list(assign[:nv])
                self.best_quality = n_unsat
                if n_unsat > 0:
                    self.near_miss_updates += 1

    def warm_snapshot(self) -> Optional[List[bool]]:
        """Locked copy of the current best assignment (service-side read
        for near-shape admission)."""
        with self._best_lock:
            return None if self.best_assign is None \
                else list(self.best_assign)

    def adopt_warm(self, assign: List[bool]) -> None:
        """Seed the warm-start state from a *different* session's best
        assignment (near-shape admission): purely heuristic — WalkSAT
        restarts and CDCL phases start there, but no clauses, cores, or
        learnt facts transfer, so soundness is untouched. The donor's
        assignment is truncated/padded to this session's base variables
        and stored as a worst-quality near-miss, so any genuine model or
        near-miss this session produces immediately replaces it."""
        nv = self.enc.inc.n_base_vars or self.enc.inc.n_vars
        a = [bool(x) for x in assign[:nv]]
        a += [False] * (nv - len(a))
        with self._best_lock:
            if self.best_assign is None:
                self.best_assign = a
                self.best_quality = 1 << 30

    def phase_hint(self) -> Optional[List[bool]]:
        """The session's best assignment (model or near-miss) as a CDCL
        saved-phase seed — the channel through which the walksat racer's
        near-misses flow back into the complete leg asynchronously. A
        near-miss that almost satisfies the formula is a strong prior on
        the structured part of the assignment, so starting CDCL's phases
        there tends to reach either a model or the conflicting core
        faster. Locked copy (the racer updates concurrently)."""
        with self._best_lock:
            if self.best_assign is None:
                return None
            self.phase_hints_served += 1
            return list(self.best_assign)


def _hamming(a: List[bool], b: List[bool]) -> int:
    return sum(1 for x, y in zip(a, b) if bool(x) != bool(y))


@dataclass
class WindowResult:
    """Outcome of one candidate in a window solve."""
    status: str                      # SAT | UNSAT | UNKNOWN | CANCELLED
    model: Optional[List[bool]]
    via: str                         # "cdcl" | "z3" | "walksat" | "cancel" ...
    # elapsed time from window start to this candidate's delivery — i.e.
    # queueing + solving, NOT the solver's own runtime (candidates share
    # a worker pool; a 0.1s solve that waited 5s reports 5.1s)
    solve_time: float
    stats: Optional[SolveStats] = None


def solve_window(cnfs: List[CNF], *, method: str = "auto", seed: int = 0,
                 use_walksat: Optional[bool] = None, walksat_steps: int = 8192,
                 walksat_batch: int = 24, walksat_delay: float = 0.75,
                 max_workers: Optional[int] = None,
                 deadline: Optional[float] = None,
                 accept: Optional[Callable[[int, List[bool]], bool]] = None,
                 session: Optional[SolverSession] = None,
                 iis: Optional[List[int]] = None,
                 race_flip: bool = True, flip_delay: float = 0.25,
                 ) -> List[WindowResult]:
    """Solve a window of K CNFs (candidate IIs, ascending) concurrently.

    ``accept(i, model)`` is invoked under the window lock whenever candidate
    ``i`` is certified SAT; returning True declares it a winner and cancels
    every candidate above it (their results become CANCELLED). Candidates
    *below* a winner always run to completion, so the caller can still
    identify the minimal feasible II. ``deadline`` (absolute time.time())
    aborts outstanding work with UNKNOWN.

    The batched-WalkSAT racer is *staged*: it sleeps for ``walksat_delay``
    seconds and starts walking only if the complete leg hasn't already
    resolved the window — easy windows (the common case on small kernels)
    never pay for it, hard SAT instances still get cracked while CDCL/z3
    grinds on the proofs.

    With ``session`` (the incremental core), the complete leg is the
    session's one persistent assumption-based solver, lowest II first —
    learned clauses from candidate i carry straight into candidate i+1, so
    consecutive UNSAT proofs start warm instead of re-deriving the same
    conflicts in parallel cold solvers. ``cnfs`` must then be the session's
    per-II projections (``session.project(ii)``, ascending II order): the
    racer walks those, warm-started from the session's best assignment.

    ``race_flip`` (CDCL sessions only) additionally races a *second*
    complete solver per candidate: a cold CDCL on the projection, started
    from the opposite saved phases (all-True vs the persistent solver's
    all-False default), staged behind ``flip_delay`` like the WalkSAT
    racer. Whichever leg delivers first decides the candidate — the
    winner is reported in the result's ``via`` ("cdcl" = session leg,
    "cdcl-flip" = the flipped racer). A flip-leg UNSAT is a proof on
    base + that II's layer, so it is recorded in the session's
    proven-UNSAT registry exactly like a failed-assumption core.
    """
    from . import SAT, UNKNOWN, resolve_method, solve as solve_any

    K = len(cnfs)
    t0 = time.time()
    results: List[Optional[WindowResult]] = [None] * K
    stops = [threading.Event() for _ in range(K)]
    closed = threading.Event()
    lock = threading.Lock()
    if method == "portfolio":   # portfolio semantics == complete + racer
        method, use_walksat = "auto", True
    method = resolve_method(method)
    complete = method in ("z3", "cdcl")
    if use_walksat is None:
        use_walksat = True

    def past_deadline() -> bool:
        return deadline is not None and time.time() > deadline

    def deliver(i: int, status: str, model, via: str,
                stats: Optional[SolveStats] = None) -> None:
        with lock:
            if closed.is_set() or results[i] is not None:
                return
            accepted = False
            if status == SAT and accept is not None:
                accepted = accept(i, model)
                if not accepted and complete and (
                        via in ("walksat", "cdcl-flip")
                        or (stats is not None and stats.phase_hinted)):
                    # provisional: a racer-leg model — or a session-leg
                    # model whose search was steered by a racer phase
                    # hint — that fails the caller's acceptance (e.g.
                    # regalloc) must not decide this candidate: an
                    # unhinted solve may yet produce a model that passes,
                    # which is exactly what the sequential reference
                    # would have judged. Leave the candidate open (the
                    # session leg retries hinted SAT rejections unhinted).
                    return
            results[i] = WindowResult(status, model, via, time.time() - t0,
                                      stats)
            if session is not None and status == SAT and model:
                # recorded while the window is provably open (we hold the
                # lock and ``closed`` is unset), so a late racer thread
                # can never clobber a *later* window's warm-start state
                session.update_best(model, 0)
            stops[i].set()
            if accepted:
                for j in range(i + 1, K):
                    stops[j].set()

    def run_complete(i: int) -> None:
        if stops[i].is_set() or past_deadline():
            return
        status, model = solve_any(
            cnfs[i], method=method, seed=seed,
            stop=lambda: stops[i].is_set() or past_deadline())
        if status == UNKNOWN and (stops[i].is_set() or past_deadline()):
            return   # cancelled / timed out; filled in at the end
        deliver(i, status, model, method)

    def run_walksat() -> None:
        # staged start: no work at all if the complete leg wins the window
        # (or the deadline passes) inside the grace period
        if closed.wait(min(walksat_delay,
                           max(0.0, (deadline or 1e18) - time.time()))):
            return
        if past_deadline():
            return
        from .walksat_jax import solve_walksat_window
        inits = None
        near: dict = {}
        packed = hpacks = None
        if session is not None:
            warm = session.warm_init()
            if warm is not None:
                inits = [warm] * K
            if iis is not None:
                # session windows are per-II projections: reuse the cached
                # device/host packs, skipping packing when nothing changed
                packed, hpacks, _ = session.packed_window(iis, cnfs)

        def on_sat_cb(i: int, model) -> None:
            st = None
            if inits is not None:
                st = SolveStats(via="walksat",
                                warm_hamming=_hamming(inits[i], model))
            deliver(i, SAT, model, "walksat", st)   # also records warm state

        def on_near_miss_cb(i: int, n_unsat: int, assign) -> None:
            # stream near-misses into the session *while the walk runs* —
            # the session leg picks them up as CDCL phase hints for the
            # candidates it hasn't started yet. Guarded by the window
            # lock/closed pair like the final push below, so a late racer
            # can never pollute a later window's warm-start state.
            with lock:
                if not closed.is_set():
                    session.update_best(assign, n_unsat)

        try:
            solve_walksat_window(
                cnfs, seed=seed, steps=walksat_steps, batch=walksat_batch,
                stop=lambda: past_deadline() or all(
                    s.is_set() for s in stops),
                should_skip=lambda i: stops[i].is_set(),
                on_sat=on_sat_cb, inits=inits,
                near_miss=near if session is not None else None,
                on_near_miss=on_near_miss_cb if session is not None
                else None,
                packed=packed, packs=hpacks)
        except Exception:   # incomplete leg must never take down the window
            pass
        if session is not None:
            # this racer thread is deliberately unjoined and may drain
            # after solve_window has returned — near-misses from a closed
            # window must not clobber a later window's warm-start state
            with lock:
                if not closed.is_set():
                    for nu, a in near.values():
                        session.update_best(a, nu)

    def _start_racer() -> None:
        # Racer thread, deliberately not joined later: JAX compiled
        # computations release the GIL, so the racer (when its staged delay
        # elapses) genuinely overlaps the complete leg; when the window
        # resolves first, ``closed`` turns any late walksat delivery into a
        # no-op and the thread drains at its next stop poll instead of
        # stalling our return by up to one XLA compile. Non-daemon so
        # interpreter shutdown waits for the drain rather than tearing down
        # XLA under a live computation. Started only after the process-pool
        # submissions so worker forks never overlap fresh XLA work.
        if use_walksat and complete:
            threading.Thread(target=run_walksat, daemon=False).start()

    def run_complete_procs(futs: dict) -> None:
        """CDCL leg on the process pool: real parallelism for the UNSAT
        proofs. ``futs`` were submitted before the racer thread started so
        the workers fork before any new XLA work begins in this process."""
        global _PROC_POOL, _PROC_POOL_BROKEN
        abandoned = set()
        while True:
            with lock:
                pending = [i for i in range(K)
                           if results[i] is None and i not in abandoned]
            if not pending or past_deadline():
                break
            done, _ = futures_wait([futs[i] for i in pending], timeout=0.1,
                                   return_when=FIRST_COMPLETED)
            idx_of = {id(futs[i]): i for i in pending}
            for f in done:
                i = idx_of.get(id(f))
                if i is None:
                    continue
                try:
                    status, model = f.result()
                except Exception:
                    # worker died (e.g. spawn unsupported under this
                    # parent): never report UNKNOWN for a decidable
                    # instance — solve it in-process instead, and stop
                    # using the pool
                    _PROC_POOL_BROKEN, _PROC_POOL = True, None
                    run_complete(i)
                    continue
                deliver(i, status, model, method)
            # reap candidates cancelled by an accept() (or solved by the
            # racer): dequeue what we can, abandon what is already running
            # (its eventual result is discarded by the closed/result check)
            for i in range(K):
                if i in abandoned or i not in futs:
                    continue
                with lock:
                    dead = stops[i].is_set() and results[i] is None
                    solved_elsewhere = results[i] is not None
                if dead or solved_elsewhere:
                    if not futs[i].done():
                        futs[i].cancel()
                    if dead:
                        abandoned.add(i)
        # deadline break: dequeue whatever hasn't started yet; if proofs
        # are still *running* past the deadline, kill the whole pool —
        # workers have no cooperative stop, and a doomed unbounded UNSAT
        # proof would otherwise starve every later map's windows
        leftovers = False
        for f in futs.values():
            if not f.done() and not f.cancel():
                leftovers = True
        if leftovers and past_deadline():
            _reset_pool()

    def submit_procs() -> Optional[dict]:
        """Submit the window to the process pool (forking workers now,
        before the racer thread may touch XLA). None => pool unusable."""
        global _PROC_POOL, _PROC_POOL_BROKEN
        pool = _proc_pool()
        if pool is None:
            return None
        from .cdcl import solve_arena_worker, solve_clauses_worker
        try:
            futs = {}
            for i in range(K):
                arena = getattr(cnfs[i], "arena", None)
                if arena is not None:
                    # ship the CSR arrays — two contiguous numpy buffers
                    # pickle far cheaper than a list of int tuples
                    futs[i] = pool.submit(solve_arena_worker,
                                          cnfs[i].n_vars,
                                          arena.lits_view(),
                                          arena.offs_view())
                else:
                    futs[i] = pool.submit(solve_clauses_worker,
                                          cnfs[i].n_vars, cnfs[i].clauses)
            return futs
        except Exception:
            _PROC_POOL_BROKEN, _PROC_POOL = True, None
            return None

    def run_session_leg() -> None:
        """The incremental complete leg: one persistent assumption-based
        solver, lowest II first. Sequential by design — candidate i's
        learned clauses are exactly what makes candidate i+1 cheap, which
        replaces the cold path's process-parallel independent proofs.

        Each candidate's solve is seeded with the session's best
        assignment as CDCL saved phases — near-misses the walksat racer
        banked while earlier candidates were being proven flow straight
        into later candidates' complete searches. A hinted SAT model the
        caller rejects (regalloc) is provisional (see ``deliver``); the
        leg then re-solves that candidate unhinted so its final verdict
        is the one the sequential reference would have produced."""
        for i in range(K):
            if past_deadline():
                break
            if stops[i].is_set():
                continue
            hint = session.phase_hint() if method == "cdcl" else None
            status, model, st = session.solve_complete(
                iis[i],
                stop=lambda i=i: stops[i].is_set() or past_deadline(),
                phase_hint=hint)
            if status == UNKNOWN and (stops[i].is_set() or past_deadline()):
                continue   # cancelled / timed out; filled in at the end
            st.phase_hinted = hint is not None
            deliver(i, status, model, method, st)
            if st.phase_hinted and status == SAT:
                with lock:
                    still_open = results[i] is None and not closed.is_set()
                if still_open and not stops[i].is_set():
                    status, model, st = session.solve_complete(
                        iis[i],
                        stop=lambda i=i: (stops[i].is_set()
                                          or past_deadline()))
                    if status == UNKNOWN and (stops[i].is_set()
                                              or past_deadline()):
                        continue
                    deliver(i, status, model, method, st)

    def run_flip_leg() -> None:
        """The second racing complete leg (ROADMAP PR 2 follow-up): a cold
        CDCL per candidate on the session's projection, started from the
        *opposite* saved phases — all-True where the persistent solver
        defaults to all-False — so the two legs walk complementary search
        trajectories over the same instances. Staged behind ``flip_delay``
        (easy windows the session leg resolves first never pay), lowest II
        first, skipping candidates already decided. An UNSAT here refutes
        base + that II's layer outright, so it feeds the session's
        proven-UNSAT registry like a failed-assumption core (core =
        [layer selector], never the empty all-UNSAT latch)."""
        if closed.wait(min(flip_delay,
                           max(0.0, (deadline or 1e18) - time.time()))):
            return
        from . import SAT as _SAT, UNSAT as _UNSAT
        from .cdcl import CDCLSolver
        for i in range(K):
            if stops[i].is_set() or past_deadline():
                continue
            solver = CDCLSolver(cnfs[i])
            status, model = solver.solve(
                phase_hint=[True] * cnfs[i].n_vars,
                stop=lambda i=i: stops[i].is_set() or past_deadline())
            if status not in (_SAT, _UNSAT):
                continue
            st = SolveStats(via="cdcl-flip",
                            conflicts=solver.last_conflicts)
            if status == _UNSAT:
                inc = session.enc.inc
                if inc.has_layer(iis[i]):
                    st.core = [inc.selector(iis[i])]
                    session.note_core(iis[i], st.core)
            deliver(i, status, model, "cdcl-flip", st)

    flip_thread: Optional[threading.Thread] = None
    if complete and session is not None:
        if iis is None or len(iis) != K:
            raise ValueError("session window solving needs one candidate "
                             f"II per CNF: got {iis!r} for {K} window(s)")
        _start_racer()
        if race_flip and method == "cdcl" and K:
            flip_thread = threading.Thread(target=run_flip_leg,
                                           daemon=False)
            flip_thread.start()
        run_session_leg()
    elif complete:
        futs = submit_procs() if method == "cdcl" else None
        _start_racer()
        if futs is not None:
            run_complete_procs(futs)
        else:
            # z3 (releases the GIL inside check()) — or the fallback when
            # the process pool is unavailable: a small thread pool, lowest
            # II first
            workers = max_workers or max(1, min(K, (os.cpu_count() or 2)))
            with ThreadPoolExecutor(max_workers=workers) as tpool:
                list(tpool.map(run_complete, range(K)))
    else:
        # incomplete-only window (method == "walksat")
        from .walksat_jax import solve_walksat_window
        warm = session.warm_init() if session is not None else None
        near: dict = {}
        packed = hpacks = None
        if session is not None and iis is not None:
            packed, hpacks, _ = session.packed_window(iis, cnfs)
        ws = solve_walksat_window(
            cnfs, seed=seed, steps=walksat_steps, batch=walksat_batch,
            stop=past_deadline, should_skip=lambda i: stops[i].is_set(),
            on_sat=lambda i, model: deliver(i, SAT, model, "walksat"),
            inits=[warm] * K if warm is not None else None,
            near_miss=near if session is not None else None,
            packed=packed, packs=hpacks)
        if session is not None:
            for nu, a in near.values():
                session.update_best(a, nu)
        for i, (status, model) in enumerate(ws):
            if status != SAT:      # SAT already delivered via on_sat
                deliver(i, status, model, "walksat")

    with lock:
        closed.set()
        for i in range(K):
            stops[i].set()   # ensure the racer's stop poll fires promptly
            if results[i] is None:
                via = "cancel" if stops[i].is_set() and not past_deadline() \
                    else "deadline"
                results[i] = WindowResult(
                    CANCELLED if via == "cancel" else UNKNOWN,
                    None, via, time.time() - t0)
    if flip_thread is not None:
        # the flip racer polls its stop event every few hundred CDCL
        # ticks, so this join is short; joining keeps flip threads from
        # piling up across consecutive windows of one sweep
        flip_thread.join(timeout=10.0)
    return results   # type: ignore[return-value]


def sharded_chain_batch(n_vars: int, chains_per_device: int, seed: int,
                        mesh: "Mesh", axis: str = "data") -> "jnp.ndarray":
    """Device-sharded initial assignments for the portfolio: [D*B, V+1] bool
    sharded over ``axis``. Used by launch-time portfolio runs on a pod."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    n_dev = mesh.shape[axis]
    total = n_dev * chains_per_device
    key = jax.random.PRNGKey(seed)
    init = jax.random.bernoulli(key, 0.5, (total, n_vars + 1))
    return jax.device_put(init, NamedSharding(mesh, P(axis, None)))

"""Mapper-search portfolio: single-instance racing and window solving.

``solve_portfolio`` is the per-instance portfolio (incomplete sharded
probSAT first, complete solver for the UNSAT certificate) — deterministic
for a fixed seed because the two legs run sequentially.

``solve_window`` is the engine room of the parallel II-sweep
(``repro.core.sweep``): it takes the CNFs of a window of candidate IIs and
solves them concurrently —

  * the complete backend runs on every candidate, lowest II first — our
    CDCL in a persistent fork-started process pool (real parallelism for
    the UNSAT proofs; CPython threads would serialise on the GIL), z3 (which
    releases the GIL inside check()) on a thread pool when importable;
  * one staged racer thread runs the *batched* WalkSAT
    (``solve_walksat_window``), which vmaps restarts across all candidates
    on the clause tensors, so the JAX leg certifies hard SAT instances
    while the complete leg grinds on the proofs;
  * per-candidate stop events implement early cancellation: the caller's
    ``accept`` callback may kill all higher-II work the moment a lower II
    returns SAT + regalloc-OK.

On real hardware the probSAT batch is additionally sharded across the mesh
with shard_map — each device runs an independent slice of chains (different
seeds/noise), an all_reduce(max) on the solved flag elects a winner, and the
host falls back to a complete solver only for the UNSAT certificate. On this
CPU container the same code path runs with a single device; the structure is
identical.
"""
from __future__ import annotations

import multiprocessing
import os
import threading
import time
import warnings
from concurrent.futures import (FIRST_COMPLETED, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait as futures_wait)
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..cnf import CNF

CANCELLED = "CANCELLED"

# ------------------------------------------------------------- process pool
# CPython's GIL serialises the pure-Python CDCL, so concurrent UNSAT proofs
# inside one process gain nothing from threads. The window solver therefore
# runs the CDCL leg in a small persistent process pool; z3 releases the GIL
# and stays on threads. Fork context: spawn would re-execute unguarded
# parent scripts' module level in every worker, and the workers only ever
# run the dependency-free CDCL (never JAX/XLA), which is fork-safe. The
# pool is created lazily and reused across windows. Non-Linux hosts without
# fork fall back to threads transparently.
_PROC_POOL: Optional[ProcessPoolExecutor] = None
_PROC_POOL_BROKEN = False
_PROC_POOL_COOLDOWN_UNTIL = 0.0


def _proc_pool() -> Optional[ProcessPoolExecutor]:
    global _PROC_POOL, _PROC_POOL_BROKEN
    if _PROC_POOL_BROKEN:
        return None
    if _PROC_POOL is None and time.time() < _PROC_POOL_COOLDOWN_UNTIL:
        # a pool was just torn down (deadline kill); an unjoined racer
        # thread may still be draining its last XLA chunk, and forking
        # while it runs is the hazard the pre-fork below exists to avoid.
        # Callers fall back to threads for this brief window.
        return None
    if _PROC_POOL is None:
        # jax warns that fork + its internal threads can deadlock the child;
        # our workers run only the dependency-free pure-Python CDCL and
        # never call back into XLA, so that hazard doesn't apply — silence
        # the specific warning rather than scare every sweep user
        warnings.filterwarnings(
            "ignore", message=r"os\.fork\(\) was called",
            category=RuntimeWarning)
        try:
            n = max(2, os.cpu_count() or 2)
            pool = ProcessPoolExecutor(
                max_workers=n,
                mp_context=multiprocessing.get_context("fork"))
            # Pre-fork every worker NOW, while no racer thread is mid-XLA:
            # lazy forking in a later window could otherwise snapshot a
            # walksat thread holding runtime locks. sleep() keeps all n
            # tasks occupied long enough that n distinct workers spawn.
            futures_wait([pool.submit(time.sleep, 0.05) for _ in range(n)])
            _PROC_POOL = pool
        except Exception:
            _PROC_POOL_BROKEN = True
            return None
    return _PROC_POOL


def _reset_pool() -> None:
    """Tear down the pool, killing any still-running proofs, so a window
    that blew its deadline cannot starve the next map's windows. The next
    sweep lazily builds a fresh pool (after a short cooldown that lets any
    leaked racer thread drain before we fork again)."""
    global _PROC_POOL, _PROC_POOL_COOLDOWN_UNTIL
    pool, _PROC_POOL = _PROC_POOL, None
    _PROC_POOL_COOLDOWN_UNTIL = time.time() + 2.0
    if pool is None:
        return
    try:
        for p in list(getattr(pool, "_processes", {}).values()):
            p.terminate()
        pool.shutdown(wait=False, cancel_futures=True)
    except Exception:
        pass


def solve_portfolio(cnf: CNF, *, seed: int = 0, steps: int = 8192,
                    chains_per_device: int = 32,
                    stop: Optional[Callable[[], bool]] = None,
                    ) -> Tuple[str, Optional[List[bool]]]:
    """Incomplete sharded search first, complete solver as fallback.

    Deterministic for a fixed seed: the WalkSAT leg either certifies SAT
    (same model every run — jax PRNG is seed-deterministic) or the complete
    leg decides; there is no wall-clock race in this single-instance path.
    """
    from . import SAT, UNKNOWN
    from .walksat_jax import solve_walksat
    from . import solve as solve_any

    n_dev = jax.device_count()
    status, model = solve_walksat(
        cnf, seed=seed, steps=steps, batch=chains_per_device * n_dev,
        stop=stop)
    if status == SAT:
        return status, model
    # complete fallback (z3 if available, else our CDCL)
    return solve_any(cnf, method="auto", stop=stop)


@dataclass
class WindowResult:
    """Outcome of one candidate in a window solve."""
    status: str                      # SAT | UNSAT | UNKNOWN | CANCELLED
    model: Optional[List[bool]]
    via: str                         # "cdcl" | "z3" | "walksat" | "cancel" ...
    # elapsed time from window start to this candidate's delivery — i.e.
    # queueing + solving, NOT the solver's own runtime (candidates share
    # a worker pool; a 0.1s solve that waited 5s reports 5.1s)
    solve_time: float


def solve_window(cnfs: List[CNF], *, method: str = "auto", seed: int = 0,
                 use_walksat: Optional[bool] = None, walksat_steps: int = 8192,
                 walksat_batch: int = 24, walksat_delay: float = 0.75,
                 max_workers: Optional[int] = None,
                 deadline: Optional[float] = None,
                 accept: Optional[Callable[[int, List[bool]], bool]] = None,
                 ) -> List[WindowResult]:
    """Solve a window of K CNFs (candidate IIs, ascending) concurrently.

    ``accept(i, model)`` is invoked under the window lock whenever candidate
    ``i`` is certified SAT; returning True declares it a winner and cancels
    every candidate above it (their results become CANCELLED). Candidates
    *below* a winner always run to completion, so the caller can still
    identify the minimal feasible II. ``deadline`` (absolute time.time())
    aborts outstanding work with UNKNOWN.

    The batched-WalkSAT racer is *staged*: it sleeps for ``walksat_delay``
    seconds and starts walking only if the complete leg hasn't already
    resolved the window — easy windows (the common case on small kernels)
    never pay for it, hard SAT instances still get cracked while CDCL/z3
    grinds on the proofs.
    """
    from . import SAT, UNKNOWN, resolve_method, solve as solve_any

    K = len(cnfs)
    t0 = time.time()
    results: List[Optional[WindowResult]] = [None] * K
    stops = [threading.Event() for _ in range(K)]
    closed = threading.Event()
    lock = threading.Lock()
    if method == "portfolio":   # portfolio semantics == complete + racer
        method, use_walksat = "auto", True
    method = resolve_method(method)
    complete = method in ("z3", "cdcl")
    if use_walksat is None:
        use_walksat = True

    def past_deadline() -> bool:
        return deadline is not None and time.time() > deadline

    def deliver(i: int, status: str, model, via: str) -> None:
        with lock:
            if closed.is_set() or results[i] is not None:
                return
            accepted = False
            if status == SAT and accept is not None:
                accepted = accept(i, model)
                if not accepted and via == "walksat" and complete:
                    # provisional: an incomplete-leg model that fails the
                    # caller's acceptance (e.g. regalloc) must not decide
                    # this candidate — the complete backend may yet produce
                    # a model that passes, which is exactly what the
                    # sequential reference would have judged. Leave the
                    # candidate open for the complete leg.
                    return
            results[i] = WindowResult(status, model, via, time.time() - t0)
            stops[i].set()
            if accepted:
                for j in range(i + 1, K):
                    stops[j].set()

    def run_complete(i: int) -> None:
        if stops[i].is_set() or past_deadline():
            return
        status, model = solve_any(
            cnfs[i], method=method, seed=seed,
            stop=lambda: stops[i].is_set() or past_deadline())
        if status == UNKNOWN and (stops[i].is_set() or past_deadline()):
            return   # cancelled / timed out; filled in at the end
        deliver(i, status, model, method)

    def run_walksat() -> None:
        # staged start: no work at all if the complete leg wins the window
        # (or the deadline passes) inside the grace period
        if closed.wait(min(walksat_delay,
                           max(0.0, (deadline or 1e18) - time.time()))):
            return
        if past_deadline():
            return
        from .walksat_jax import solve_walksat_window
        try:
            solve_walksat_window(
                cnfs, seed=seed, steps=walksat_steps, batch=walksat_batch,
                stop=lambda: past_deadline() or all(
                    s.is_set() for s in stops),
                should_skip=lambda i: stops[i].is_set(),
                on_sat=lambda i, model: deliver(i, SAT, model, "walksat"))
        except Exception:   # incomplete leg must never take down the window
            pass

    def _start_racer() -> None:
        # Racer thread, deliberately not joined later: JAX compiled
        # computations release the GIL, so the racer (when its staged delay
        # elapses) genuinely overlaps the complete leg; when the window
        # resolves first, ``closed`` turns any late walksat delivery into a
        # no-op and the thread drains at its next stop poll instead of
        # stalling our return by up to one XLA compile. Non-daemon so
        # interpreter shutdown waits for the drain rather than tearing down
        # XLA under a live computation. Started only after the process-pool
        # submissions so worker forks never overlap fresh XLA work.
        if use_walksat and complete:
            threading.Thread(target=run_walksat, daemon=False).start()

    def run_complete_procs(futs: dict) -> None:
        """CDCL leg on the process pool: real parallelism for the UNSAT
        proofs. ``futs`` were submitted before the racer thread started so
        the workers fork before any new XLA work begins in this process."""
        global _PROC_POOL, _PROC_POOL_BROKEN
        abandoned = set()
        while True:
            with lock:
                pending = [i for i in range(K)
                           if results[i] is None and i not in abandoned]
            if not pending or past_deadline():
                break
            done, _ = futures_wait([futs[i] for i in pending], timeout=0.1,
                                   return_when=FIRST_COMPLETED)
            idx_of = {id(futs[i]): i for i in pending}
            for f in done:
                i = idx_of.get(id(f))
                if i is None:
                    continue
                try:
                    status, model = f.result()
                except Exception:
                    # worker died (e.g. spawn unsupported under this
                    # parent): never report UNKNOWN for a decidable
                    # instance — solve it in-process instead, and stop
                    # using the pool
                    _PROC_POOL_BROKEN, _PROC_POOL = True, None
                    run_complete(i)
                    continue
                deliver(i, status, model, method)
            # reap candidates cancelled by an accept() (or solved by the
            # racer): dequeue what we can, abandon what is already running
            # (its eventual result is discarded by the closed/result check)
            for i in range(K):
                if i in abandoned or i not in futs:
                    continue
                with lock:
                    dead = stops[i].is_set() and results[i] is None
                    solved_elsewhere = results[i] is not None
                if dead or solved_elsewhere:
                    if not futs[i].done():
                        futs[i].cancel()
                    if dead:
                        abandoned.add(i)
        # deadline break: dequeue whatever hasn't started yet; if proofs
        # are still *running* past the deadline, kill the whole pool —
        # workers have no cooperative stop, and a doomed unbounded UNSAT
        # proof would otherwise starve every later map's windows
        leftovers = False
        for f in futs.values():
            if not f.done() and not f.cancel():
                leftovers = True
        if leftovers and past_deadline():
            _reset_pool()

    def submit_procs() -> Optional[dict]:
        """Submit the window to the process pool (forking workers now,
        before the racer thread may touch XLA). None => pool unusable."""
        global _PROC_POOL, _PROC_POOL_BROKEN
        pool = _proc_pool()
        if pool is None:
            return None
        from .cdcl import solve_clauses_worker
        try:
            return {i: pool.submit(solve_clauses_worker,
                                   cnfs[i].n_vars, cnfs[i].clauses)
                    for i in range(K)}
        except Exception:
            _PROC_POOL_BROKEN, _PROC_POOL = True, None
            return None

    if complete:
        futs = submit_procs() if method == "cdcl" else None
        _start_racer()
        if futs is not None:
            run_complete_procs(futs)
        else:
            # z3 (releases the GIL inside check()) — or the fallback when
            # the process pool is unavailable: a small thread pool, lowest
            # II first
            workers = max_workers or max(1, min(K, (os.cpu_count() or 2)))
            with ThreadPoolExecutor(max_workers=workers) as tpool:
                list(tpool.map(run_complete, range(K)))
    else:
        # incomplete-only window (method == "walksat")
        from .walksat_jax import solve_walksat_window
        ws = solve_walksat_window(
            cnfs, seed=seed, steps=walksat_steps, batch=walksat_batch,
            stop=past_deadline, should_skip=lambda i: stops[i].is_set(),
            on_sat=lambda i, model: deliver(i, SAT, model, "walksat"))
        for i, (status, model) in enumerate(ws):
            if status != SAT:      # SAT already delivered via on_sat
                deliver(i, status, model, "walksat")

    with lock:
        closed.set()
        for i in range(K):
            stops[i].set()   # ensure the racer's stop poll fires promptly
            if results[i] is None:
                via = "cancel" if stops[i].is_set() and not past_deadline() \
                    else "deadline"
                results[i] = WindowResult(
                    CANCELLED if via == "cancel" else UNKNOWN,
                    None, via, time.time() - t0)
    return results   # type: ignore[return-value]


def sharded_chain_batch(n_vars: int, chains_per_device: int, seed: int,
                        mesh: Mesh, axis: str = "data") -> jnp.ndarray:
    """Device-sharded initial assignments for the portfolio: [D*B, V+1] bool
    sharded over ``axis``. Used by launch-time portfolio runs on a pod."""
    n_dev = mesh.shape[axis]
    total = n_dev * chains_per_device
    key = jax.random.PRNGKey(seed)
    init = jax.random.bernoulli(key, 0.5, (total, n_vars + 1))
    return jax.device_put(init, NamedSharding(mesh, P(axis, None)))

"""Conflict-driven clause learning SAT solver.

A dependency-free CDCL so the framework never requires Z3: two-watched
literals, EVSIDS branching, phase saving, 1UIP learning, Luby restarts.
Literals are signed ints (DIMACS). Designed for the KMS instances this
framework produces (1e4–1e5 vars, 1e5–1e6 clauses) — pure Python, so Z3 is
preferred when present; this backend is the always-available fallback and
the reference for the JAX portfolio's UNSAT certification.

Clause storage is flat (mirroring ``repro.core.cnf.ClauseArena``): one
literal list ``db`` plus per-clause ``cl_off``/``cl_len`` indexed by clause
id, and watch lists held in a dense list indexed by literal code
(``2v`` for ``+v``, ``2v+1`` for ``¬v``) instead of a dict keyed by signed
literal. The propagation loop then touches only small-int list indexing —
no dict hashing, no tuple allocation — while keeping the *identical*
decision/learning behaviour (watch order, clause order, restart schedule),
so ``last_core``, learnt-DB eviction, and all stats are unchanged.

Incremental interface (the assumption-based sweep core):

  * ``solve(assumptions=[...])`` — MiniSat-style: assumptions are enqueued
    as pseudo-decisions below all real decisions; a conflict that reaches
    decision level 0 is global UNSAT (the solver stays UNSAT forever), a
    falsified assumption is UNSAT *under these assumptions only*.
  * ``add_clauses(...)`` — grow the formula between solve calls.
  * learned clauses, variable activities, and saved phases all persist
    across calls — solving II=k+1 after II=k starts from everything the
    previous call derived, which is the whole point of the layered
    selector-literal encoding in ``repro.core.cnf.IncrementalCNF``.

Service extensions (the long-lived ``repro.core.service`` process):

  * **failed-assumption cores** — after an UNSAT-under-assumptions
    verdict, ``last_core`` holds the subset of the assumption literals
    that the final conflict actually depends on (MiniSat's
    ``analyzeFinal``). An empty core means the formula itself is UNSAT
    regardless of assumptions. ``last_core`` is ``None`` after SAT and —
    critically — after every UNKNOWN: a ``max_conflicts`` budget
    exhaustion or a cooperative ``stop()`` is *not* a refutation, and
    callers that treat cores as proofs (the mapping service's II
    pruning) must never see one for an undecided call. ``last_limit``
    says which limit ended an UNKNOWN call ("conflicts" | "stop").
  * **bounded learnt-clause database** — with ``max_learnt=N`` the
    solver scores retained learnt clauses by (LBD, activity) and evicts
    the worst down to ``N // 2`` whenever the database grows past ``N``.
    Only clauses currently locked as propagation reasons are exempt
    (soundness of the trail); glue/binary clauses merely *rank first*
    under the LBD sort, so retention genuinely stays bounded. Eviction
    only drops redundant lemmas, never input clauses, so correctness is
    unaffected; ``evicted_total`` counts evictions for the service's
    reuse stats.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from ..cnf import CNF, ClauseArena


def solve_clauses_worker(n_vars: int, clauses: List[Tuple[int, ...]],
                         ) -> Tuple[str, Optional[List[bool]]]:
    """Process-pool entry point for the sweep portfolio: rebuilds the CNF
    from picklable primitives and solves it. Lives here (not portfolio.py)
    so spawn-started workers import only this light, jax-free module."""
    cnf = CNF()
    cnf.n_vars = n_vars
    cnf.clauses = [tuple(c) for c in clauses]
    return CDCLSolver(cnf).solve()


def solve_arena_worker(n_vars: int, lits, offs,
                       ) -> Tuple[str, Optional[List[bool]]]:
    """Like :func:`solve_clauses_worker` but takes the clause arena's raw
    (lits, offs) CSR arrays — two contiguous numpy buffers pickle across
    the pool far cheaper than a list of int tuples."""
    cnf = CNF()
    cnf.n_vars = n_vars
    cnf.arena = ClauseArena.from_arrays(lits, offs)
    return CDCLSolver(cnf).solve()


def _luby(x: int) -> int:
    """Luby sequence, 0-based index (MiniSat's iterative formulation)."""
    size, seq = 1, 0
    while size < x + 1:
        seq += 1
        size = 2 * size + 1
    while size - 1 != x:
        size = (size - 1) // 2
        seq -= 1
        x %= size
    return 1 << seq


def _lit_code(lit: int) -> int:
    """Dense watch-list index: +v -> 2v, ¬v -> 2v+1."""
    return (lit << 1) if lit > 0 else ((-lit << 1) | 1)


class CDCLSolver:
    def __init__(self, cnf: Optional[CNF] = None,
                 max_learnt: Optional[int] = None):
        self.nv = 0
        # flat clause database: clause ci is db[cl_off[ci] : cl_off[ci]+cl_len[ci]]
        self.db: List[int] = []
        self.cl_off: List[int] = []
        self.cl_len: List[int] = []
        # watch lists indexed by literal code (2v / 2v+1)
        self.watches: List[List[int]] = [[], []]
        # assignment: 0 unassigned, 1 true, -1 false (index = var)
        self.assign = [0]
        self.level = [0]
        self.reason: List[Optional[int]] = [None]
        self.trail: List[int] = []          # assigned literals in order
        self.trail_lim: List[int] = []      # decision-level boundaries
        self.qhead = 0
        self.activity = [0.0]
        self.var_inc = 1.0
        self.saved_phase = [False]
        self.ok = True
        self._units: List[int] = []
        self.n_input = 0          # input (non-learnt) clauses incl. units
        self.n_learnt = 0         # learnt clauses currently retained
        self.conflicts_total = 0  # across all solve() calls
        self.last_conflicts = 0   # conflicts of the latest solve() call
        # learnt-clause database bound: None keeps every learnt clause
        # forever (the PR 2 behaviour); an int N evicts down to N // 2 by
        # (LBD asc, activity desc) whenever retention exceeds N.
        self.max_learnt = max_learnt
        self._learnt_meta: Dict[int, List[float]] = {}  # ci -> [act, lbd]
        self.cla_inc = 1.0
        self.evicted_total = 0
        # failed-assumption core of the latest solve: a subset of the
        # assumption literals whose conjunction is refuted ([] = the
        # formula itself is UNSAT); None after SAT and after UNKNOWN
        self.last_core: Optional[List[int]] = None
        # which limit ended the latest UNKNOWN call: "conflicts" | "stop"
        self.last_limit: Optional[str] = None
        if cnf is not None:
            self.add_clauses(cnf.clauses, n_vars=cnf.n_vars)

    # ------------------------------------------------------- incremental API
    def grow_vars(self, n_vars: int) -> None:
        if n_vars <= self.nv:
            return
        extra = n_vars - self.nv
        self.assign.extend([0] * extra)
        self.level.extend([0] * extra)
        self.reason.extend([None] * extra)
        self.activity.extend([0.0] * extra)
        self.saved_phase.extend([False] * extra)
        self.watches.extend([] for _ in range(2 * extra))
        self.nv = n_vars

    def add_clauses(self, clauses, n_vars: Optional[int] = None) -> bool:
        """Add input clauses between solve calls (backtracks to level 0;
        learned clauses and heuristic state are kept). Returns False — and
        latches the solver UNSAT — on an empty clause."""
        self._backtrack(0)
        rows = clauses.iter_lists() if hasattr(clauses, "iter_lists") \
            else clauses
        if n_vars is not None:
            self.grow_vars(n_vars)
        elif hasattr(clauses, "max_var"):
            self.grow_vars(clauses.max_var())
        else:
            rows = [list(cl) for cl in rows]
            self.grow_vars(max((abs(l) for cl in rows for l in cl),
                               default=0))
        for cl in rows:
            self.n_input += 1
            if not self._add_clause(list(cl)):
                self.ok = False
        return self.ok

    @property
    def n_clauses(self) -> int:
        return len(self.cl_len)

    @property
    def clauses(self) -> List[List[int]]:
        """Materialised clause list (debugging/introspection only — the
        solver itself reads the flat ``db``)."""
        return [self.db[o:o + n] for o, n in zip(self.cl_off, self.cl_len)]

    @property
    def learnt_db_size(self) -> int:
        """Learnt clauses currently stored in the clause database (learnt
        *units* are level-0 trail facts, not database entries, so
        ``n_learnt`` may exceed this)."""
        return len(self._learnt_meta)

    # ------------------------------------------------------------ plumbing
    def _value(self, lit: int) -> int:
        v = self.assign[abs(lit)]
        return v if lit > 0 else -v

    def _watch(self, lit: int, ci: int) -> None:
        self.watches[(lit << 1) if lit > 0 else ((-lit << 1) | 1)].append(ci)

    def _append_db(self, lits: List[int]) -> int:
        """Append a clause to the flat database; returns its clause id."""
        ci = len(self.cl_len)
        self.cl_off.append(len(self.db))
        self.cl_len.append(len(lits))
        self.db.extend(lits)
        return ci

    def _clause(self, ci: int) -> List[int]:
        off = self.cl_off[ci]
        return self.db[off:off + self.cl_len[ci]]

    def _add_clause(self, lits: List[int]) -> bool:
        lits = sorted(set(lits), key=abs)
        # tautology / dedup
        for i in range(len(lits) - 1):
            if lits[i] == -lits[i + 1]:
                return True
        if not lits:
            return False
        if len(lits) == 1:
            self._units.append(lits[0])
            return True
        ci = self._append_db(lits)
        self._watch(lits[0], ci)
        self._watch(lits[1], ci)
        return True

    def _enqueue(self, lit: int, reason: Optional[int]) -> bool:
        val = self._value(lit)
        if val == 1:
            return True
        if val == -1:
            return False
        v = abs(lit)
        self.assign[v] = 1 if lit > 0 else -1
        self.level[v] = len(self.trail_lim)
        self.reason[v] = reason
        self.saved_phase[v] = lit > 0
        self.trail.append(lit)
        return True

    def _propagate(self) -> Optional[int]:
        """Returns conflicting clause index or None."""
        db = self.db
        cl_off = self.cl_off
        cl_len = self.cl_len
        watches = self.watches
        assign = self.assign
        trail = self.trail
        while self.qhead < len(trail):
            lit = trail[self.qhead]
            self.qhead += 1
            falsified = -lit
            fcode = (falsified << 1) if falsified > 0 \
                else ((-falsified << 1) | 1)
            wl = watches[fcode]
            if not wl:
                continue
            keep: List[int] = []
            i = 0
            while i < len(wl):
                ci = wl[i]
                i += 1
                off = cl_off[ci]
                # ensure falsified is clause position 1
                if db[off] == falsified:
                    db[off] = db[off + 1]
                    db[off + 1] = falsified
                first = db[off]
                fval = assign[first] if first > 0 else -assign[-first]
                if fval == 1:
                    keep.append(ci)
                    continue
                # search replacement watch
                moved = False
                for k in range(off + 2, off + cl_len[ci]):
                    q = db[k]
                    if (assign[q] if q > 0 else -assign[-q]) != -1:
                        db[off + 1] = q
                        db[k] = falsified
                        watches[(q << 1) if q > 0
                                else ((-q << 1) | 1)].append(ci)
                        moved = True
                        break
                if moved:
                    continue
                keep.append(ci)
                if fval == -1:
                    keep.extend(wl[i:])
                    watches[fcode] = keep
                    return ci
                self._enqueue(first, ci)
            watches[fcode] = keep
        return None

    # -------------------------------------------------------------- branch
    def _bump(self, v: int) -> None:
        self.activity[v] += self.var_inc
        if self.activity[v] > 1e100:
            for u in range(1, self.nv + 1):
                self.activity[u] *= 1e-100
            self.var_inc *= 1e-100

    def _decide(self) -> int:
        best, bestv = -1.0, 0
        for v in range(1, self.nv + 1):
            if self.assign[v] == 0 and self.activity[v] > best:
                best, bestv = self.activity[v], v
        return bestv

    def _analyze(self, confl: int) -> Tuple[List[int], int]:
        learnt = [0]  # slot for the asserting literal
        seen = [False] * (self.nv + 1)
        counter = 0
        lit = 0
        idx = len(self.trail) - 1
        cur_level = len(self.trail_lim)
        ci: Optional[int] = confl
        first = True
        while True:
            cl = self._clause(ci)
            meta = self._learnt_meta.get(ci)
            if meta is not None:    # learnt clause used in analysis: bump
                meta[0] += self.cla_inc
            # for reason clauses, cl[0] is the propagated literal
            for q in (cl if first else cl[1:] if cl[0] == lit else
                      [x for x in cl if x != lit]):
                v = abs(q)
                if not seen[v] and self.level[v] > 0:
                    seen[v] = True
                    self._bump(v)
                    if self.level[v] >= cur_level:
                        counter += 1
                    else:
                        learnt.append(q)
            first = False
            # walk back the trail to the next marked literal
            while not seen[abs(self.trail[idx])]:
                idx -= 1
            lit = self.trail[idx]
            v = abs(lit)
            seen[v] = False
            counter -= 1
            idx -= 1
            if counter == 0:
                break
            ci = self.reason[v]
        learnt[0] = -lit
        if len(learnt) == 1:
            bt = 0
        else:
            bt = max(self.level[abs(q)] for q in learnt[1:])
        return learnt, bt

    def _backtrack(self, lvl: int) -> None:
        if len(self.trail_lim) <= lvl:
            return
        lim = self.trail_lim[lvl]
        for lit in reversed(self.trail[lim:]):
            self.assign[abs(lit)] = 0
        del self.trail[lim:]
        del self.trail_lim[lvl:]
        self.qhead = min(self.qhead, len(self.trail))

    def _analyze_final(self, lit: int) -> List[int]:
        """Failed-assumption core (MiniSat ``analyzeFinal``): the subset of
        the current assumption literals whose conjunction is already
        refuted, given that assumption ``lit`` was found falsified by
        propagation from the clauses and the earlier assumptions. Walks the
        implication graph backwards from ``¬lit``; every pseudo-decision it
        reaches is an assumption that the refutation depends on."""
        core = [lit]
        if not self.trail_lim:
            return core     # falsified at level 0: lit alone is refuted
        seen = {abs(lit)}
        for i in range(len(self.trail) - 1, self.trail_lim[0] - 1, -1):
            q = self.trail[i]
            v = abs(q)
            if v not in seen:
                continue
            r = self.reason[v]
            if r is None:
                core.append(q)  # assumption pseudo-decision (as enqueued)
            else:
                for x in self._clause(r):
                    if abs(x) != v and self.level[abs(x)] > 0:
                        seen.add(abs(x))
            seen.discard(v)
        return core

    # ------------------------------------------------- learnt-DB reduction
    def _reduce_db(self) -> None:
        """Evict the worst-scored learnt clauses down to ``max_learnt // 2``.

        Scoring is MiniSat/Glucose-flavoured: LBD first (lower = closer to
        a proof skeleton, so glue and binary clauses rank at the top),
        activity second (recently useful in conflict analysis). Clauses
        locked as the propagation reason of a currently-assigned variable
        are always kept (required for soundness of the trail); everything
        else competes for the ``max_learnt // 2`` slots, so retention
        stays bounded. The flat database is compacted and watches / reason
        indices remapped, so this is safe at any decision level."""
        locked = {self.reason[abs(lit)] for lit in self.trail
                  if self.reason[abs(lit)] is not None}
        target = max(0, (self.max_learnt or 0) // 2)
        ranked = sorted(self._learnt_meta.items(),
                        key=lambda kv: (kv[1][1], -kv[1][0],
                                        self.cl_len[kv[0]]))
        keep = set()
        for ci, (act, lbd) in ranked:
            if ci in locked or len(keep) < target:
                keep.add(ci)
        dropped = len(self._learnt_meta) - len(keep)
        if dropped == 0:
            return
        remap: Dict[int, int] = {}
        new_db: List[int] = []
        new_off: List[int] = []
        new_len: List[int] = []
        for ci in range(len(self.cl_len)):
            if ci in self._learnt_meta and ci not in keep:
                continue
            remap[ci] = len(new_len)
            off, n = self.cl_off[ci], self.cl_len[ci]
            new_off.append(len(new_db))
            new_len.append(n)
            new_db.extend(self.db[off:off + n])
        self.db, self.cl_off, self.cl_len = new_db, new_off, new_len
        self._learnt_meta = {remap[ci]: meta
                             for ci, meta in self._learnt_meta.items()
                             if ci in keep}
        for v in range(1, self.nv + 1):
            r = self.reason[v]
            if self.assign[v] != 0 and r is not None:
                self.reason[v] = remap[r]   # locked => kept => remappable
            else:
                self.reason[v] = None       # stale entry of an unassigned var
        # positions 0/1 are exactly the watched literals (the propagate
        # loop maintains that invariant), so rebuilding from them is exact
        self.watches = [[] for _ in range(2 * (self.nv + 1))]
        for ci in range(len(self.cl_len)):
            off = self.cl_off[ci]
            self._watch(self.db[off], ci)
            self._watch(self.db[off + 1], ci)
        self.n_learnt -= dropped
        self.evicted_total += dropped

    # ---------------------------------------------------------------- main
    def solve(self, max_conflicts: Optional[int] = None,
              phase_hint: Optional[List[bool]] = None,
              stop: Optional[Callable[[], bool]] = None,
              assumptions: Optional[List[int]] = None,
              ) -> Tuple[str, Optional[List[bool]]]:
        """``stop`` is a cooperative cancellation hook (polled every few
        hundred loop iterations); when it returns True the search aborts
        with UNKNOWN. Used by the sweep portfolio to kill higher-II
        attempts once a lower II wins.

        ``assumptions`` are literals temporarily forced for this call only
        (MiniSat semantics): they occupy the lowest decision levels, so
        UNSAT here means "UNSAT under these assumptions" unless the
        conflict reaches level 0, in which case the formula itself is
        UNSAT and the solver latches ``ok=False``. The solver object is
        reusable after any outcome; learned clauses, activities, and
        phases carry over to the next call.

        Verdict bookkeeping for incremental callers: UNSAT sets
        ``last_core`` (failed-assumption subset; ``[]`` when the formula
        is UNSAT regardless of assumptions), while an exhausted
        ``max_conflicts`` budget or a fired ``stop`` returns UNKNOWN with
        ``last_core=None`` and ``last_limit`` saying which limit hit —
        a budget exhaustion under assumptions is *undecided*, never a
        proven-UNSAT II.
        """
        from . import SAT, UNSAT, UNKNOWN
        self.last_core = None
        self.last_limit = None
        if not self.ok:
            self.last_core = []
            return UNSAT, None
        assumptions = assumptions or []
        self._backtrack(0)
        self.qhead = 0
        if self.max_learnt is not None \
                and len(self._learnt_meta) > self.max_learnt:
            self._reduce_db()
        if phase_hint:
            for v in range(1, min(self.nv, len(phase_hint)) + 1):
                self.saved_phase[v] = bool(phase_hint[v - 1])
        for u in self._units:
            if not self._enqueue(u, None):
                self.ok = False
                self.last_core = []
                return UNSAT, None
        if self._propagate() is not None:
            self.ok = False
            self.last_core = []
            return UNSAT, None
        conflicts = 0
        self.last_conflicts = 0
        restart_idx = 1
        budget = 100 * _luby(restart_idx)
        ticks = 0
        try:
            while True:
                ticks += 1
                if stop is not None and ticks % 256 == 0 and stop():
                    self.last_limit = "stop"
                    return UNKNOWN, None
                confl = self._propagate()
                if confl is not None:
                    conflicts += 1
                    self.conflicts_total += 1
                    self.last_conflicts = conflicts
                    if len(self.trail_lim) == 0:
                        self.ok = False
                        self.last_core = []
                        return UNSAT, None
                    learnt, bt = self._analyze(confl)
                    self._backtrack(bt)
                    self.n_learnt += 1
                    if len(learnt) == 1:
                        if not self._enqueue(learnt[0], None):
                            self.ok = False
                            self.last_core = []
                            return UNSAT, None
                    else:
                        ci = self._append_db(learnt)
                        self._watch(learnt[0], ci)
                        self._watch(learnt[1], ci)
                        self._enqueue(learnt[0], ci)
                        lbd = len({self.level[abs(q)] for q in learnt})
                        self._learnt_meta[ci] = [self.cla_inc, lbd]
                    self.var_inc *= 1.0 / 0.95
                    self.cla_inc *= 1.0 / 0.999
                    if self.cla_inc > 1e20:
                        for meta in self._learnt_meta.values():
                            meta[0] *= 1e-20
                        self.cla_inc *= 1e-20
                    if self.max_learnt is not None \
                            and len(self._learnt_meta) > self.max_learnt:
                        self._reduce_db()
                    if max_conflicts is not None and conflicts >= max_conflicts:
                        self.last_limit = "conflicts"
                        return UNKNOWN, None
                    if conflicts >= budget:
                        restart_idx += 1
                        budget = conflicts + 100 * _luby(restart_idx)
                        self._backtrack(0)
                elif len(self.trail_lim) < len(assumptions):
                    # assumption pseudo-decisions occupy the lowest levels;
                    # a restart undoes them and this branch re-enqueues
                    lit = assumptions[len(self.trail_lim)]
                    val = self._value(lit)
                    if val == -1:
                        # falsified by propagation from clauses + earlier
                        # assumptions: UNSAT under these assumptions only
                        self.last_core = self._analyze_final(lit)
                        return UNSAT, None
                    self.trail_lim.append(len(self.trail))
                    if val == 0:
                        self._enqueue(lit, None)
                else:
                    v = self._decide()
                    if v == 0:
                        model = [self.assign[u] == 1
                                 for u in range(1, self.nv + 1)]
                        return SAT, model
                    self.trail_lim.append(len(self.trail))
                    lit = v if self.saved_phase[v] else -v
                    self._enqueue(lit, None)
        finally:
            self.last_conflicts = conflicts

"""Z3 backend — the solver used in the paper's own experiments.

``solve_z3`` is the one-shot (cold) path. ``Z3IncrementalSolver`` keeps a
single ``z3.Solver`` alive across the II sweep: clauses are only ever
added (delta layers arrive guarded by selector literals, see
``repro.core.cnf.IncrementalCNF``) and each candidate II is decided by
``check(assumptions)`` — no push/pop, so z3 retains its learned lemmas
across consecutive IIs instead of re-deriving them per call.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..cnf import CNF


class Z3IncrementalSolver:
    """One persistent ``z3.Solver`` with assumption-based solving."""

    def __init__(self):
        import z3
        self._z3 = z3
        self.solver = z3.Solver()
        self.xs: List = [None]      # xs[v] = Bool for var v (1-based)
        self.n_clauses = 0
        self.unsat_latched = False  # an unguarded empty clause arrived
        # failed-assumption core of the latest solve (subset of the
        # assumption literals, as ints); None after SAT / UNKNOWN —
        # mirrors CDCLSolver.last_core so SolverSession treats both
        # complete backends identically
        self.last_core: Optional[List[int]] = None

    def grow_vars(self, n_vars: int) -> None:
        z3 = self._z3
        while len(self.xs) <= n_vars:
            self.xs.append(z3.Bool(f"x{len(self.xs)}"))

    def add_clauses(self, clauses: Sequence[Tuple[int, ...]],
                    n_vars: Optional[int] = None) -> None:
        z3, xs = self._z3, self.xs
        if n_vars is not None:
            self.grow_vars(n_vars)
        else:
            self.grow_vars(max((abs(l) for cl in clauses for l in cl),
                               default=0))
            xs = self.xs
        for cl in clauses:
            if not cl:
                self.unsat_latched = True
                continue
            self.solver.add(
                z3.Or(*[xs[l] if l > 0 else z3.Not(xs[-l]) for l in cl]))
            self.n_clauses += 1

    def solve(self, assumptions: Optional[List[int]] = None,
              stop: Optional[Callable[[], bool]] = None,
              ) -> Tuple[str, Optional[List[bool]]]:
        z3 = self._z3
        from . import SAT, UNSAT, UNKNOWN
        self.last_core = None
        if self.unsat_latched:
            self.last_core = []
            return UNSAT, None
        if stop is not None and stop():
            return UNKNOWN, None
        xs = self.xs
        assumptions = assumptions or []
        assumed = [xs[l] if l > 0 else z3.Not(xs[-l]) for l in assumptions]
        # cooperative cancellation: bounded solve slices, polling ``stop``
        # between slices (z3 releases the GIL inside check())
        self.solver.set("timeout", 500 if stop is not None else 0)
        while True:
            res = self.solver.check(*assumed)
            if res == z3.sat:
                m = self.solver.model()
                return SAT, [z3.is_true(m[xs[v]])
                             for v in range(1, len(xs))]
            if res == z3.unsat:
                # failed-assumption core: z3 returns the subset of the
                # check() assumptions in the final conflict; map the
                # exprs back to our ints positionally
                try:
                    core_exprs = self.solver.unsat_core()
                    self.last_core = [lit for lit, e in
                                      zip(assumptions, assumed)
                                      if any(e.eq(c) for c in core_exprs)]
                except Exception:
                    self.last_core = list(assumptions)  # sound over-approx
                return UNSAT, None
            if stop is None or stop():
                return UNKNOWN, None

    def stats(self) -> Dict[str, float]:
        """Best-effort solver statistics (key set depends on z3 build)."""
        try:
            return {k: v for k, v in self.solver.statistics()}
        except Exception:
            return {}


def solve_z3(cnf: CNF, timeout_ms: Optional[int] = None,
             stop: Optional[Callable[[], bool]] = None,
             ) -> Tuple[str, Optional[List[bool]]]:
    import z3
    from . import SAT, UNSAT, UNKNOWN

    if getattr(cnf, "trivially_unsat", False):
        return UNSAT, None
    if stop is not None and stop():
        return UNKNOWN, None
    s = z3.Solver()
    if timeout_ms:
        s.set("timeout", timeout_ms)
    elif stop is not None:
        # cooperative cancellation: bounded solve slices, polling ``stop``
        # between slices (z3 releases the GIL inside check(), so the sweep's
        # watchdog thread can flip the event while we are solving)
        s.set("timeout", 500)
    xs = [z3.Bool(f"x{v}") for v in range(cnf.n_vars + 1)]  # xs[0] unused
    for cl in cnf.clauses:
        if not cl:
            return UNSAT, None
        s.add(z3.Or(*[xs[l] if l > 0 else z3.Not(xs[-l]) for l in cl]))

    def model_of() -> List[bool]:
        m = s.model()
        return [z3.is_true(m[xs[v]]) for v in range(1, cnf.n_vars + 1)]

    while True:
        res = s.check()
        if res == z3.sat:
            return SAT, model_of()
        if res == z3.unsat:
            return UNSAT, None
        if stop is None or timeout_ms or stop():
            return UNKNOWN, None
        # else: slice expired without a verdict — keep solving

"""Z3 backend — the solver used in the paper's own experiments."""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from ..cnf import CNF


def solve_z3(cnf: CNF, timeout_ms: Optional[int] = None,
             stop: Optional[Callable[[], bool]] = None,
             ) -> Tuple[str, Optional[List[bool]]]:
    import z3
    from . import SAT, UNSAT, UNKNOWN

    if stop is not None and stop():
        return UNKNOWN, None
    s = z3.Solver()
    if timeout_ms:
        s.set("timeout", timeout_ms)
    elif stop is not None:
        # cooperative cancellation: bounded solve slices, polling ``stop``
        # between slices (z3 releases the GIL inside check(), so the sweep's
        # watchdog thread can flip the event while we are solving)
        s.set("timeout", 500)
    xs = [z3.Bool(f"x{v}") for v in range(cnf.n_vars + 1)]  # xs[0] unused
    for cl in cnf.clauses:
        if not cl:
            return UNSAT, None
        s.add(z3.Or(*[xs[l] if l > 0 else z3.Not(xs[-l]) for l in cl]))

    def model_of() -> List[bool]:
        m = s.model()
        return [z3.is_true(m[xs[v]]) for v in range(1, cnf.n_vars + 1)]

    while True:
        res = s.check()
        if res == z3.sat:
            return SAT, model_of()
        if res == z3.unsat:
            return UNSAT, None
        if stop is None or timeout_ms or stop():
            return UNKNOWN, None
        # else: slice expired without a verdict — keep solving

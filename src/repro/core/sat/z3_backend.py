"""Z3 backend — the solver used in the paper's own experiments."""
from __future__ import annotations

from typing import List, Optional, Tuple

from ..cnf import CNF


def solve_z3(cnf: CNF, timeout_ms: Optional[int] = None,
             ) -> Tuple[str, Optional[List[bool]]]:
    import z3
    from . import SAT, UNSAT, UNKNOWN

    s = z3.Solver()
    if timeout_ms:
        s.set("timeout", timeout_ms)
    xs = [z3.Bool(f"x{v}") for v in range(cnf.n_vars + 1)]  # xs[0] unused
    for cl in cnf.clauses:
        if not cl:
            return UNSAT, None
        s.add(z3.Or(*[xs[l] if l > 0 else z3.Not(xs[-l]) for l in cl]))
    res = s.check()
    if res == z3.sat:
        m = s.model()
        model = [z3.is_true(m[xs[v]]) for v in range(1, cnf.n_vars + 1)]
        return SAT, model
    if res == z3.unsat:
        return UNSAT, None
    return UNKNOWN, None

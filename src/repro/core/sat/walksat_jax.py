"""Batched probSAT/WalkSAT in JAX — the accelerator-native mapper search path.

The KMS CNF is lowered to dense padded tensors; a *batch* of candidate
assignments walks in parallel (one probSAT chain per batch row), so clause
evaluation becomes regular tensor work that the VPU/MXU executes well. On a
pod the batch is sharded over the mesh (see ``_maybe_shard_window``); the
first chain to satisfy the formula wins.

Two engines drive the chunked walk:

  * ``engine="device"`` (default) — the whole chunk schedule runs inside a
    single jitted :func:`jax.lax.while_loop`. Per-candidate solved flags,
    first-solution snapshots, and best-over-all-chunks near-miss state are
    device arrays; the host blocks only every ``_POLL_CHUNKS`` chunks on a
    tiny status tuple (``jax.block_until_ready``) to poll ``stop()`` /
    ``should_skip`` and extract freshly certified models. Chunk sizes are
    *traced* values, so one XLA executable covers every chunk of the
    progressive schedule instead of one compile per chunk length.
  * ``engine="host"`` — the PR 1/2 reference loop: one jitted fixed-length
    chunk per host iteration, flags polled after every chunk. Kept as the
    bit-compatibility oracle (same seeds => same models as the device
    engine) and selectable via ``REPRO_WALKSAT_ENGINE=host``.

Both engines share one inner step (``_pick_flip_one`` + the flip/true-count
update), so they consume the PRNG stream identically and return identical
results for a fixed seed. On TPU/GPU the true-count evaluation routes
through the ``kernels/clause_eval`` Pallas kernel and the flip+incremental
true-count update through the fused ``kernels/flip_update`` kernel
(``REPRO_SAT_KERNELS`` overrides: ``0`` forces the pure-jnp path, ``interpret``
forces the kernels in interpret mode — the CPU-testable route).

This solver is incomplete: it can certify SAT but returns UNKNOWN instead of
UNSAT — the Fig. 3 loop then falls back to CDCL/Z3 for the UNSAT proof.

``pack_cnf``/``true_counts_ref`` are also the reference oracle for the
``kernels/clause_eval`` Pallas kernel.
"""
from __future__ import annotations

import functools
import os
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..cnf import CNF

_INT32_MAX = np.iinfo(np.int32).max

# chunks walked on-device between host polls of the status array (device
# engine): larger values amortise dispatch, smaller values make stop()/
# should_skip() more responsive. The per-chunk step count is already
# bounded by formula size (see _chunk_plan), so 4 keeps cancellation
# latency well under a second on real instances.
_POLL_CHUNKS = 4


class NonModelError(RuntimeError):
    """A walksat leg returned an assignment that does not satisfy its CNF.

    This is a *miscompiled-kernel / packer-bug* guard, not a user error: a
    chain is only reported SAT after its padded true-count vector shows
    every clause satisfied, so a failing ``CNF.check`` means the device
    computation and the host formula disagree. Raised as a structured
    error (never a bare ``assert``) so the guard survives ``python -O``.
    """


def _validate_model(cnf: CNF, model: List[bool], ctx: str) -> None:
    if not cnf.check(model):
        raise NonModelError(
            f"walksat returned a non-model ({ctx}): device true-counts "
            f"claim SAT but CNF.check fails on {cnf.n_vars} vars / "
            f"{cnf.n_clauses} clauses")


class PackedCNF(NamedTuple):
    cvars: jnp.ndarray   # [C, Lmax] int32 var ids (1-based), 0 = padding
    csign: jnp.ndarray   # [C, Lmax] bool, True = positive literal
    ovars: jnp.ndarray   # [V+1, Omax] int32 clause ids (0-based), -1 = padding
    osign: jnp.ndarray   # [V+1, Omax] bool sign of the var in that clause
    n_vars: int
    n_clauses: int


class HostPack(NamedTuple):
    """Host-side (numpy) twin of :class:`PackedCNF` — what the session-level
    pack cache stores, so reuse never round-trips through device arrays."""
    cvars: np.ndarray
    csign: np.ndarray
    ovars: np.ndarray
    osign: np.ndarray
    n_vars: int
    n_clauses: int


def pack_cnf_np(cnf: CNF) -> HostPack:
    """Vectorised dense pack of one CNF, straight off the clause arena.

    The arena *is* the CSR form of the formula — ``lits[offs[i]:offs[i+1]]``
    is clause i — so the padded clause matrix is one scatter of the literal
    buffer at ``(repeat(clause_id, lens), ranges(lens))`` and the occurrence
    lists are the same scatter after a stable sort of the literals by
    variable (stability keeps each variable's occurrences in (clause,
    position) order, exactly the order the old per-clause append built).
    No per-clause Python iteration anywhere.
    """
    arena = getattr(cnf, "arena", None)
    if arena is not None:
        lits = arena.lits_view()
        offs = arena.offs_view()
        lens = np.diff(offs)
    else:   # degenerate / mock CNFs without an arena
        rows = [list(c) for c in cnf.clauses]
        lens = np.asarray([len(r) for r in rows], dtype=np.int64)
        lits = np.asarray([l for r in rows for l in r], dtype=np.int32)
        offs = np.concatenate([[0], np.cumsum(lens)])
    C = cnf.n_clauses
    V = cnf.n_vars
    n = lits.size
    lmax = int(lens.max()) if C else 1
    cvars = np.zeros((C, lmax), np.int32)
    csign = np.zeros((C, lmax), bool)
    rows = np.repeat(np.arange(C), lens)
    cols = np.arange(n) - np.repeat(offs[:-1], lens)
    av = np.abs(lits)
    sg = lits > 0
    cvars[rows, cols] = av
    csign[rows, cols] = sg
    counts = np.bincount(av, minlength=V + 1)
    omax = int(counts.max()) if counts.size else 0
    ovars = np.full((V + 1, omax), -1, np.int32)
    osign = np.zeros((V + 1, omax), bool)
    if n:
        order = np.argsort(av, kind="stable")
        va = av[order]
        j = np.arange(n) - (np.cumsum(counts) - counts)[va]
        ovars[va, j] = rows[order]
        osign[va, j] = sg[order]
    return HostPack(cvars, csign, ovars, osign, V, C)


def pack_cnf(cnf: CNF) -> PackedCNF:
    p = pack_cnf_np(cnf)
    return PackedCNF(jnp.asarray(p.cvars), jnp.asarray(p.csign),
                     jnp.asarray(p.ovars), jnp.asarray(p.osign),
                     p.n_vars, p.n_clauses)


def true_counts_ref(packed: PackedCNF, assign: jnp.ndarray) -> jnp.ndarray:
    """Per-clause count of satisfied literals. assign: [V+1] bool -> [C] int32.

    Pure-jnp oracle; the Pallas ``clause_eval`` kernel computes the same
    quantity blockwise (see repro.kernels.clause_eval).
    """
    mask = packed.cvars > 0
    vals = assign[packed.cvars] == packed.csign
    return jnp.sum(jnp.where(mask, vals, False), axis=-1).astype(jnp.int32)


def true_counts_batch(packed: PackedCNF, assign: jnp.ndarray,
                      use_kernel: bool | None = None) -> jnp.ndarray:
    """Batched per-clause true counts [B, C]; routes to the Pallas
    clause_eval kernel on TPU/GPU (compiled), jnp oracle elsewhere."""
    if use_kernel is None:
        use_kernel = jax.default_backend() in ("tpu", "gpu")
    if use_kernel:
        from ...kernels.clause_eval import true_counts as tc_kernel
        return tc_kernel(packed.cvars, packed.csign.astype(bool), assign)
    return jax.vmap(lambda a: true_counts_ref(packed, a))(assign)


# ----------------------------------------------------------- kernel routing

def _sat_kernels_mode() -> Optional[str]:
    """How the walksat engines evaluate/update true counts.

    ``None``   — pure-jnp path (the default on CPU).
    ``"auto"`` — Pallas kernels, compiled (TPU Mosaic / GPU Triton).
    ``"interpret"`` — Pallas kernels in interpret mode (CPU-testable).

    ``REPRO_SAT_KERNELS`` overrides: ``0``/``off`` => jnp everywhere,
    ``interpret`` => interpret-mode kernels, ``1``/``compiled`` => compiled.
    """
    env = os.environ.get("REPRO_SAT_KERNELS", "").strip().lower()
    if env in ("0", "false", "off", "jnp"):
        return None
    if env == "interpret":
        return "interpret"
    if env in ("1", "true", "on", "compiled"):
        return "auto"
    return "auto" if jax.default_backend() in ("tpu", "gpu") else None


def _window_tc(cvars: jnp.ndarray, csign: jnp.ndarray, assign: jnp.ndarray,
               kernels: Optional[str]) -> jnp.ndarray:
    """Window true counts [K, B, C] — the inner evaluation of the sweep,
    routed through the Pallas ``clause_eval`` kernel when enabled."""
    if kernels is not None:
        from ...kernels.clause_eval import true_counts_window
        return true_counts_window(
            cvars, csign, assign,
            interpret=True if kernels == "interpret" else None)

    def per_k(cv, cs, a):                     # a: [B, V+1]
        mask = cv > 0
        vals = a[:, cv] == cs[None]           # [B, C, L]
        return jnp.sum(jnp.where(mask[None], vals, False),
                       axis=-1).astype(jnp.int32)
    return jax.vmap(per_k)(cvars, csign, assign)


# ------------------------------------------------------------ probSAT step

def _pick_flip_one(cvars, ovars, osign, assign, tc, key, cb):
    """One probSAT variable pick for a batch of chains of one CNF.

    assign: [B, V+1] bool, tc: [B, C] int32. Returns (v_flip [B] — var 0
    (the dummy) for already-solved chains, new_val [B], key')."""
    unsat = tc == 0                           # [B, C]
    any_unsat = jnp.any(unsat, axis=-1)       # [B]
    key, k1, k2 = jax.random.split(key, 3)
    # pick a random unsat clause per chain
    logits = jnp.where(unsat, 0.0, -1e30)
    cidx = jax.random.categorical(k1, logits, axis=-1)      # [B]
    vs = cvars[cidx]                          # [B, Lmax]
    vmask = vs > 0
    # break count per candidate var: clauses where v is the sole support
    occ_c = ovars[vs]                         # [B, Lmax, Omax]
    occ_s = osign[vs]
    occ_valid = occ_c >= 0
    occ_cc = jnp.where(occ_valid, occ_c, 0)
    flat = occ_cc.reshape(occ_cc.shape[0], -1)              # [B, L*O]
    tc_at = jnp.take_along_axis(tc, flat, axis=-1).reshape(occ_c.shape)
    a_at = jnp.take_along_axis(assign, vs, axis=-1)         # [B, Lmax]
    supports = occ_s == a_at[..., None]       # var currently satisfies c'
    brk = jnp.sum(occ_valid & supports & (tc_at == 1), axis=-1)  # [B, Lmax]
    # probSAT polynomial heuristic: p ∝ (1 + brk)^-cb
    w = jnp.where(vmask, -cb * jnp.log1p(brk.astype(jnp.float32)), -1e30)
    pick = jax.random.categorical(k2, w, axis=-1)           # [B]
    v_flip = jnp.take_along_axis(vs, pick[:, None], axis=-1)[:, 0]
    v_flip = jnp.where(any_unsat, v_flip, 0)  # flip dummy var 0 if solved
    new_val = ~jnp.take_along_axis(assign, v_flip[:, None], axis=-1)[:, 0]
    return v_flip, new_val, key


def _apply_flip_one(ovars, osign, assign, tc, v_flip, new_val):
    """Apply the flip + incremental true-count update via occurrence lists
    (pure-jnp reference for the fused ``kernels/flip_update`` kernel)."""
    assign = assign.at[jnp.arange(assign.shape[0]), v_flip].set(new_val)
    occ_cf = ovars[v_flip]                    # [B, Omax]
    occ_sf = osign[v_flip]
    validf = occ_cf >= 0
    delta = jnp.where(occ_sf == new_val[:, None], 1, -1)
    delta = jnp.where(validf, delta, 0)
    tc = tc + jnp.zeros_like(tc).at[
        jnp.arange(tc.shape[0])[:, None], jnp.where(validf, occ_cf, 0)
    ].add(delta)
    return assign, tc


def _window_chunk(cvars, csign, ovars, osign, assign, tc, keys, n_steps, cb,
                  kernels: Optional[str]):
    """Walk all K CNFs for ``n_steps`` probSAT steps (n_steps may be a
    traced scalar — both engines share this one implementation, so they
    consume the PRNG stream identically and stay bit-compatible).

    assign: [K, B, V+1] bool; tc: [K, B, C] int32; keys: [K, 2].
    """
    del csign  # only the pick/update tensors are read here

    def body(_, carry):
        assign, tc, keys = carry
        v_flip, new_val, keys = jax.vmap(
            lambda cv, ov, os_, a, t, k:
            _pick_flip_one(cv, ov, os_, a, t, k, cb)
        )(cvars, ovars, osign, assign, tc, keys)
        if kernels is not None:
            from ...kernels.flip_update import flip_update
            kk = jnp.arange(assign.shape[0])[:, None]
            occ_c = ovars[kk, v_flip]          # [K, B, O]
            occ_s = osign[kk, v_flip]
            assign, tc = flip_update(
                assign, tc, v_flip, occ_c, occ_s, new_val,
                interpret=True if kernels == "interpret" else None)
        else:
            assign, tc = jax.vmap(_apply_flip_one)(
                ovars, osign, assign, tc, v_flip, new_val)
        return assign, tc, keys

    return jax.lax.fori_loop(0, n_steps, body, (assign, tc, keys))


@functools.partial(jax.jit, static_argnums=(4, 5, 6, 9))
def _run_chains_window(cvars: jnp.ndarray, csign: jnp.ndarray,
                       ovars: jnp.ndarray, osign: jnp.ndarray,
                       n_vars: int, steps: int, cb: float,
                       assign0: jnp.ndarray, keys: jnp.ndarray,
                       kernels: Optional[str] = None,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fixed-length chunk of probSAT over a *window* of K CNFs (the
    host engine's unit of work; one jit entry per chunk length).

    cvars/csign: [K, C, Lmax]; ovars/osign: [K, V+1, Omax];
    assign0: [K, B, V+1]; keys: [K, 2]. Returns (solved [K, B], assign,
    per-clause true counts [K, B, C] — the near-miss signal).
    """
    del n_vars
    tc0 = _window_tc(cvars, csign, assign0, kernels)
    assign, tc, _ = _window_chunk(cvars, csign, ovars, osign,
                                  assign0, tc0, keys, steps, cb, kernels)
    solved = ~jnp.any(tc == 0, axis=-1)
    return solved, assign, tc


# -------------------------------------------------------- chunk scheduling

def _bucket(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _chunk_plan(steps: int, n_clauses: int) -> Tuple[int, int]:
    """(cap, first_chunk) of the progressive chunk schedule, shared by both
    walksat entry points: the per-chunk step count is bounded by the caller
    budget AND by formula size (stop/skip are only polled between chunks,
    and a cancelled racer must drain fast — fewer steps for big formulas),
    and the first chunk never exceeds the cap, so a small ``steps`` budget
    is honoured instead of being rounded up to 256."""
    cap = max(64, min(steps, 2048, 2_000_000 // max(n_clauses, 1)))
    return cap, min(256, cap)


def _next_chunk(prev: int, cap: int, remaining: int) -> int:
    """Progressive chunk schedule: double from the first chunk up to
    ``cap``, then shrink back down (halving only, so the handful of jit
    entries the host engine needs is shared) to land on the step budget
    without overshooting by more than one minimal chunk."""
    c = min(prev * 2, cap)
    while c > 256 and c > remaining:
        c //= 2
    return c


def _next_chunk_jnp(prev, cap, remaining):
    """Traced twin of :func:`_next_chunk` for the device engine's
    while_loop (cap <= 2048, so 5 unrolled halvings always suffice)."""
    c = jnp.minimum(prev * 2, cap)
    for _ in range(5):
        c = jnp.where((c > 256) & (c > remaining), c // 2, c)
    return c


def _init_assign(key: jnp.ndarray, batch: int, n_vars_padded: int,
                 init: Optional[List[bool]]) -> jnp.ndarray:
    """Initial chain assignments [B, V+1]. Without ``init``: uniform
    random. With ``init`` (a warm start, e.g. the previous II's best
    near-miss under the shared variable numbering): chain 0 starts from it
    exactly and chain b flips a growing fraction (up to half) of the
    variables, so the batch explores a widening neighbourhood of the hint
    while keeping full random restarts in the tail.

    The hint is truncated/padded defensively: a sweep window can *shrink*
    (e.g. the previous window's II bucketed to a larger padded var count),
    so ``init`` may be longer or shorter than this window's variable
    space — extra entries are dropped, missing ones default to False."""
    if init is None:
        return jax.random.bernoulli(key, 0.5, (batch, n_vars_padded + 1))
    base = np.zeros(n_vars_padded + 1, bool)
    hint = np.asarray(init, bool)[:n_vars_padded]
    base[1:len(hint) + 1] = hint
    ps = jnp.linspace(0.0, 0.5, batch)[:, None]
    flips = jax.random.bernoulli(key, ps, (batch, n_vars_padded + 1))
    return jnp.asarray(base)[None, :] ^ flips


def pack_cnf_window(cnfs: List[CNF],
                    packs: Optional[List[Optional[HostPack]]] = None,
                    ) -> PackedCNF:
    """Pack K CNFs into one stacked PackedCNF padded to common shapes.

    Shorter clause lists are padded with the tautology clause (v1 ∨ ¬v1) —
    always exactly one true literal, so padded rows are never selected as
    unsat and never reach a solved flag. Padding rows are *excluded* from
    the occurrence lists, so break counts and incremental true-count
    updates are unaffected. Variable counts are padded to the max; extra
    vars occur in no clause and are never flipped.

    All dims are rounded up to coarse buckets so different windows (other
    kernels, other CGRA sizes) reuse the same jitted computation instead of
    paying a fresh XLA compile per instance shape.

    ``packs``, when given, supplies a precomputed :func:`pack_cnf_np` per
    CNF (``None`` entries are packed here) — the session-level cache path
    that makes warm window solves skip per-CNF packing entirely.
    """
    host: List[HostPack] = []
    for k, c in enumerate(cnfs):
        p = packs[k] if packs is not None else None
        host.append(p if p is not None else pack_cnf_np(c))
    K = len(host)
    V = _bucket(max(p.n_vars for p in host), 128)
    C = _bucket(max(p.n_clauses for p in host), 1024)
    L = max(p.cvars.shape[1] for p in host)
    O = max(p.ovars.shape[1] for p in host)
    L = _bucket(max(L, 2), 4)  # room for the (v1, ¬v1) padding tautology
    O = _bucket(O, 8)
    cvars = np.zeros((K, C, L), np.int32)
    csign = np.zeros((K, C, L), bool)
    ovars = np.full((K, V + 1, O), -1, np.int32)
    osign = np.zeros((K, V + 1, O), bool)
    for k, p in enumerate(host):
        c, l = p.cvars.shape
        cvars[k, :c, :l] = p.cvars
        csign[k, :c, :l] = p.csign
        # tautology padding for clause rows [c, C)
        cvars[k, c:, 0] = 1
        cvars[k, c:, 1] = 1
        csign[k, c:, 0] = True
        csign[k, c:, 1] = False
        v, o = p.ovars.shape
        ovars[k, :v, :o] = p.ovars
        osign[k, :v, :o] = p.osign
    return PackedCNF(jnp.asarray(cvars), jnp.asarray(csign),
                     jnp.asarray(ovars), jnp.asarray(osign), V, C)


def _maybe_shard_window(packed: PackedCNF, assign0: jnp.ndarray,
                        ) -> jnp.ndarray:
    """Shard the (II-window x restart-batch) grid over the device mesh.

    On multi-device hosts the restart batch is split across devices (each
    device walks an independent slice of chains; the clause tensors are
    small and replicated) and GSPMD propagates the layout through the
    jitted engines — the per-candidate solved/near-miss reductions become
    cross-device all-reduces. Single-device hosts (this CPU container)
    pass through untouched, so the code path is identical everywhere."""
    n_dev = jax.device_count()
    if n_dev <= 1 or assign0.shape[1] % n_dev != 0:
        return assign0
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()), ("dev",))
    return jax.device_put(assign0, NamedSharding(mesh, P(None, "dev", None)))


# ---------------------------------------------------------- device engine

@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _device_segment(poll_chunks: int, cb: float, kernels: Optional[str],
                    cvars, csign, ovars, osign, steps, cap, state):
    """Run up to ``poll_chunks`` chunks of the progressive schedule wholly
    on device, early-exiting when every live candidate has a solved chain.

    ``state`` carries the full walk: (assign [K,B,V+1], tc [K,B,C], key,
    done, chunk, solved [K], solved_assign [K,V+1] — the assignment of the
    first chain observed solved, snapshotted in the chunk it solved so a
    late poll returns the same model the per-chunk host engine would have,
    skip [K], best_unsat [K], best_assign [K,V+1] — best-over-all-chunks
    near-miss state, tracked only while a candidate is still pending).
    Only ``solved``/``done`` need to reach the host between segments; the
    big buffers stay device-resident for the next segment.
    """
    K = state[0].shape[0]

    def cond(st):
        _, _, _, done, _, solved, _, skip, _, _, polls = st
        return ((done < steps) & jnp.any(~(solved | skip))
                & (polls < poll_chunks))

    def body(st):
        (assign, tc, key, done, chunk, solved, solved_assign, skip,
         best_unsat, best_assign, polls) = st
        key, kc = jax.random.split(key)
        keys = jax.random.split(kc, K)
        assign, tc, _ = _window_chunk(cvars, csign, ovars, osign,
                                      assign, tc, keys, chunk, cb, kernels)
        chain_ok = ~jnp.any(tc == 0, axis=-1)           # [K, B]
        cand_ok = jnp.any(chain_ok, axis=-1)            # [K]
        fresh = cand_ok & ~solved
        row = jnp.argmax(chain_ok, axis=-1)             # first solved chain
        snap = assign[jnp.arange(K), row]
        solved_assign = jnp.where(fresh[:, None], snap, solved_assign)
        solved = solved | fresh
        # near-miss: best assignment over all chunks, per still-pending
        # candidate (solved/skipped candidates stop accumulating)
        n_unsat = jnp.sum(tc == 0, axis=-1)             # [K, B]
        bu = jnp.min(n_unsat, axis=-1)
        brow = jnp.argmin(n_unsat, axis=-1)
        improve = ~solved & ~skip & (bu < best_unsat)
        best_unsat = jnp.where(improve, bu, best_unsat)
        best_assign = jnp.where(improve[:, None],
                                assign[jnp.arange(K), brow], best_assign)
        done = done + chunk
        chunk = _next_chunk_jnp(chunk, cap, steps - done)
        return (assign, tc, key, done, chunk, solved, solved_assign, skip,
                best_unsat, best_assign, polls + 1)

    out = jax.lax.while_loop(cond, body, state + (jnp.int32(0),))
    return out[:-1]


def _solve_window_device(cnfs, live, packed, results, *, seed, steps, batch,
                         cb, stop, should_skip, on_sat, inits, near_miss,
                         on_near_miss):
    from . import SAT
    K = len(live)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    init_keys = jax.random.split(k0, K)
    assign0 = jnp.stack([
        _init_assign(init_keys[j], batch, packed.n_vars,
                     inits[live[j]] if inits is not None else None)
        for j in range(K)])
    assign0 = _maybe_shard_window(packed, assign0)
    kernels = _sat_kernels_mode()
    cap, chunk0 = _chunk_plan(steps, packed.n_clauses)
    tc0 = _window_tc(packed.cvars, packed.csign, assign0, kernels)
    v1 = packed.n_vars + 1
    state = (assign0, tc0, key,
             jnp.int32(0), jnp.int32(chunk0),
             jnp.zeros(K, bool), jnp.zeros((K, v1), bool),
             jnp.zeros(K, bool),
             jnp.full(K, _INT32_MAX, jnp.int32), jnp.zeros((K, v1), bool))
    skip_host = np.zeros(K, bool)
    pending = set(range(K))
    nm_emitted = np.full(K, _INT32_MAX, np.int64)   # last streamed quality
    done = 0
    while done < steps and pending:
        if stop is not None and stop():
            break
        if should_skip is not None:
            newly = [j for j in sorted(pending) if should_skip(live[j])]
            if newly:
                for j in newly:
                    pending.discard(j)
                    skip_host[j] = True
                if not pending:
                    break
                state = state[:7] + (jnp.asarray(skip_host),) + state[8:]
        state = _device_segment(_POLL_CHUNKS, cb, kernels,
                                packed.cvars, packed.csign,
                                packed.ovars, packed.osign,
                                jnp.int32(steps), jnp.int32(cap), state)
        # the host blocks only on the tiny status pair; the walk state
        # (assignments, true counts, near-miss buffers) stays on device
        solved_dev, done_dev = jax.block_until_ready((state[5], state[3]))
        solved_np = np.asarray(solved_dev)
        done = int(done_dev)
        for j in sorted(pending):
            if not solved_np[j]:
                continue
            i = live[j]
            model = [bool(b) for b in
                     np.asarray(state[6][j])[1:cnfs[i].n_vars + 1]]
            _validate_model(cnfs[i], model, f"device engine, candidate {i}")
            results[i] = (SAT, model)
            pending.discard(j)
            if on_sat is not None:
                on_sat(i, model)
        if on_near_miss is not None and pending:
            # stream near-miss improvements at each poll — the caller's
            # feedback channel (e.g. CDCL phase hints) sees them while
            # the walk is still running, not only at budget exhaustion
            bu = np.asarray(state[8])
            for j in sorted(pending):
                if bu[j] < nm_emitted[j]:
                    nm_emitted[j] = bu[j]
                    i = live[j]
                    on_near_miss(
                        i, int(bu[j]),
                        [bool(b) for b in
                         np.asarray(state[9][j])[1:cnfs[i].n_vars + 1]])
    if near_miss is not None and pending:
        bu = np.asarray(state[8])
        ba = np.asarray(state[9])
        for j in sorted(pending):
            if bu[j] >= _INT32_MAX:
                continue
            i = live[j]
            near_miss[i] = (int(bu[j]),
                            [bool(b) for b in ba[j][1:cnfs[i].n_vars + 1]])
    return results


# ------------------------------------------------------------ host engine

def _solve_window_host(cnfs, live, packed, results, *, seed, steps, batch,
                       cb, stop, should_skip, on_sat, inits, near_miss,
                       on_near_miss):
    """The per-chunk host loop (PR 1/2 reference engine): identical chunk
    schedule, PRNG stream, and near-miss bookkeeping as the device engine,
    with flags polled after every chunk."""
    from . import SAT
    K = len(live)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    init_keys = jax.random.split(k0, K)
    assign0 = jnp.stack([
        _init_assign(init_keys[j], batch, packed.n_vars,
                     inits[live[j]] if inits is not None else None)
        for j in range(K)])
    assign0 = _maybe_shard_window(packed, assign0)
    kernels = _sat_kernels_mode()
    cap, chunk = _chunk_plan(steps, packed.n_clauses)
    done = 0
    pending = set(range(K))
    # best-over-all-chunks near-miss per candidate (not final-chunk-only)
    nm_best = {j: (_INT32_MAX, None) for j in range(K)}
    while done < steps and pending:
        if stop is not None and stop():
            break
        key, kc = jax.random.split(key)
        keys = jax.random.split(kc, K)
        solved, assign, tc = _run_chains_window(
            packed.cvars, packed.csign, packed.ovars, packed.osign,
            packed.n_vars, chunk, cb, assign0, keys, kernels)
        solved_np = np.asarray(solved)
        for j in sorted(pending):
            i = live[j]
            if should_skip is not None and should_skip(i):
                pending.discard(j)
                continue
            if not solved_np[j].any():
                continue
            row = int(np.argmax(solved_np[j]))
            model = [bool(b) for b in
                     np.asarray(assign[j, row])[1:cnfs[i].n_vars + 1]]
            _validate_model(cnfs[i], model, f"host engine, candidate {i}")
            results[i] = (SAT, model)
            pending.discard(j)
            if on_sat is not None:
                on_sat(i, model)
        if (near_miss is not None or on_near_miss is not None) and pending:
            n_unsat = np.asarray(jnp.sum(tc == 0, axis=-1))   # [K, B]
            assign_np = None
            for j in sorted(pending):
                row = int(np.argmin(n_unsat[j]))
                if int(n_unsat[j, row]) < nm_best[j][0]:
                    if assign_np is None:
                        assign_np = np.asarray(assign)
                    nm_best[j] = (int(n_unsat[j, row]),
                                  assign_np[j, row].copy())
                    if on_near_miss is not None:
                        i = live[j]
                        on_near_miss(
                            i, nm_best[j][0],
                            [bool(b) for b in
                             nm_best[j][1][1:cnfs[i].n_vars + 1]])
        assign0 = assign
        done += chunk
        chunk = _next_chunk(chunk, cap, steps - done)
    if near_miss is not None:
        for j in sorted(pending):
            nu, arr = nm_best[j]
            if arr is None:
                continue
            i = live[j]
            near_miss[i] = (nu, [bool(b) for b in arr[1:cnfs[i].n_vars + 1]])
    return results


# -------------------------------------------------------------- front door

def solve_walksat_window(cnfs: List[CNF], *, seed: int = 0,
                         steps: int = 8192, batch: int = 24, cb: float = 2.3,
                         stop=None, should_skip=None, on_sat=None,
                         inits: Optional[List[Optional[List[bool]]]] = None,
                         near_miss: Optional[dict] = None,
                         on_near_miss=None,
                         engine: Optional[str] = None,
                         packed: Optional[PackedCNF] = None,
                         packs: Optional[List[Optional[HostPack]]] = None,
                         ) -> List[Tuple[str, Optional[List[bool]]]]:
    """Batched probSAT across a window of candidate-II CNFs.

    All K formulas walk concurrently inside one jitted computation (vmapped
    restarts over the stacked clause tensors). Incomplete: per-CNF result is
    SAT or UNKNOWN, never UNSAT (structurally-empty-clause CNFs excepted).

    ``stop()`` aborts the whole window; ``should_skip(i)`` marks candidate i
    as no longer interesting (e.g. its complete solver already finished);
    ``on_sat(i, model)`` fires as soon as candidate i is certified, so the
    caller can early-cancel other work while remaining candidates keep
    walking.

    ``inits[i]`` warm-starts candidate i's chains from a prior assignment
    (see ``_init_assign``); ``near_miss``, when given a dict, receives
    ``{i: (n_unsat, assignment)}`` — the best assignment each *still
    pending* candidate reached over the whole walk (solved and skipped
    candidates are excluded, so the session's warm-start dict is never
    polluted with stale or irrelevant assignments). ``on_near_miss(i,
    n_unsat, assignment)`` streams improvements *during* the walk (per
    host poll on the device engine, per chunk on the host engine) — the
    asynchronous feedback channel the solver portfolio uses to seed CDCL
    phase hints while the racer is still walking.

    ``engine`` selects the chunk driver: ``"device"`` (default) keeps the
    whole schedule in one jitted while_loop with the host polling a tiny
    status array every few chunks; ``"host"`` is the per-chunk reference
    loop. Both are bit-compatible for a fixed seed;
    ``REPRO_WALKSAT_ENGINE`` overrides the default.

    ``packed`` supplies a ready stacked window pack (used only when every
    candidate turns out live, i.e. it covers exactly the CNFs walked);
    ``packs`` supplies per-CNF host packs for the stacker. Both come from
    the ``SolverSession`` pack cache — a warm sweep leg re-solving an
    unchanged window skips packing entirely.
    """
    from . import SAT, UNKNOWN, UNSAT
    K = len(cnfs)
    results: List[Tuple[str, Optional[List[bool]]]] = [(UNKNOWN, None)] * K
    live = []
    for i, cnf in enumerate(cnfs):
        arena = getattr(cnf, "arena", None)
        if arena is not None:
            has_empty = bool((np.diff(arena.offs_view()) == 0).any())
        else:
            has_empty = any(len(c) == 0 for c in cnf.clauses)
        if getattr(cnf, "trivially_unsat", False) or has_empty:
            results[i] = (UNSAT, None)
        elif cnf.n_clauses == 0 or cnf.n_vars == 0:
            results[i] = (SAT, [False] * cnf.n_vars)
            if on_sat is not None:
                on_sat(i, results[i][1])
        else:
            live.append(i)
    if not live:
        return results
    if engine is None:
        engine = os.environ.get("REPRO_WALKSAT_ENGINE", "device")
    if engine not in ("device", "host"):
        raise ValueError(f"unknown walksat engine {engine!r}")
    if packed is None or len(live) != K:
        packed = pack_cnf_window(
            [cnfs[i] for i in live],
            [packs[i] for i in live] if packs is not None else None)
    run = _solve_window_device if engine == "device" else _solve_window_host
    return run(cnfs, live, packed, results, seed=seed, steps=steps,
               batch=batch, cb=cb, stop=stop, should_skip=should_skip,
               on_sat=on_sat, inits=inits, near_miss=near_miss,
               on_near_miss=on_near_miss)


def solve_walksat(cnf: CNF, *, seed: int = 0, steps: int = 20000,
                  batch: int = 64, cb: float = 2.3, stop=None,
                  init: Optional[List[bool]] = None,
                  near_miss: Optional[dict] = None,
                  engine: Optional[str] = None,
                  pack: Optional[HostPack] = None,
                  ) -> Tuple[str, Optional[List[bool]]]:
    """Single-CNF probSAT: the K=1 window. Shares the window engines, the
    bucketed padded pack (consecutive IIs of a sweep — and the incremental
    projections, whose handful of selector variables would otherwise change
    the tensor shapes — reuse one XLA compile), and the budget/formula-size
    chunk schedule, so a caller-provided ``steps`` is honoured exactly the
    same way in both entry points. ``near_miss`` receives ``{0: (n_unsat,
    assignment)}`` when the instance stays unsolved; ``pack`` supplies a
    cached :func:`pack_cnf_np` of the CNF."""
    res = solve_walksat_window(
        [cnf], seed=seed, steps=steps, batch=batch, cb=cb, stop=stop,
        inits=[init] if init is not None else None,
        near_miss=near_miss, engine=engine,
        packs=[pack] if pack is not None else None)
    return res[0]

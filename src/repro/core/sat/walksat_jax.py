"""Batched probSAT/WalkSAT in JAX — the TPU-native mapper search path.

The KMS CNF is lowered to dense padded tensors; a *batch* of candidate
assignments walks in parallel (one probSAT chain per batch row), so clause
evaluation becomes regular tensor work that the VPU/MXU executes well. On a
pod the batch is sharded over the mesh with shard_map (see portfolio.py);
the first chain to satisfy the formula wins.

This solver is incomplete: it can certify SAT but returns UNKNOWN instead of
UNSAT — the Fig. 3 loop then falls back to CDCL/Z3 for the UNSAT proof.

``pack_cnf``/``true_counts_ref`` are also the reference oracle for the
``kernels/clause_eval`` Pallas kernel.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..cnf import CNF


class PackedCNF(NamedTuple):
    cvars: jnp.ndarray   # [C, Lmax] int32 var ids (1-based), 0 = padding
    csign: jnp.ndarray   # [C, Lmax] bool, True = positive literal
    ovars: jnp.ndarray   # [V+1, Omax] int32 clause ids (0-based), -1 = padding
    osign: jnp.ndarray   # [V+1, Omax] bool sign of the var in that clause
    n_vars: int
    n_clauses: int


def pack_cnf(cnf: CNF) -> PackedCNF:
    lmax = max((len(c) for c in cnf.clauses), default=1)
    C = cnf.n_clauses
    cvars = np.zeros((C, lmax), np.int32)
    csign = np.zeros((C, lmax), bool)
    occ: List[List[Tuple[int, bool]]] = [[] for _ in range(cnf.n_vars + 1)]
    for ci, cl in enumerate(cnf.clauses):
        for j, lit in enumerate(cl):
            v = abs(lit)
            cvars[ci, j] = v
            csign[ci, j] = lit > 0
            occ[v].append((ci, lit > 0))
    omax = max((len(o) for o in occ), default=1)
    ovars = np.full((cnf.n_vars + 1, omax), -1, np.int32)
    osign = np.zeros((cnf.n_vars + 1, omax), bool)
    for v, lst in enumerate(occ):
        for j, (ci, s) in enumerate(lst):
            ovars[v, j] = ci
            osign[v, j] = s
    return PackedCNF(jnp.asarray(cvars), jnp.asarray(csign),
                     jnp.asarray(ovars), jnp.asarray(osign),
                     cnf.n_vars, C)


def true_counts_ref(packed: PackedCNF, assign: jnp.ndarray) -> jnp.ndarray:
    """Per-clause count of satisfied literals. assign: [V+1] bool -> [C] int32.

    Pure-jnp oracle; the Pallas ``clause_eval`` kernel computes the same
    quantity blockwise (see repro.kernels.clause_eval).
    """
    mask = packed.cvars > 0
    vals = assign[packed.cvars] == packed.csign
    return jnp.sum(jnp.where(mask, vals, False), axis=-1).astype(jnp.int32)


def true_counts_batch(packed: PackedCNF, assign: jnp.ndarray,
                      use_kernel: bool | None = None) -> jnp.ndarray:
    """Batched per-clause true counts [B, C]; routes to the Pallas
    clause_eval kernel on TPU (VMEM-tiled), jnp oracle elsewhere."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from ...kernels.clause_eval import true_counts as tc_kernel
        return tc_kernel(packed.cvars, packed.csign.astype(bool), assign)
    return jax.vmap(lambda a: true_counts_ref(packed, a))(assign)


def _chains_core(packed: PackedCNF, assign0: jnp.ndarray, key: jnp.ndarray,
                 steps: int, cb: float,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """probSAT chains. assign0: [B, V+1] bool. Returns (solved [B], assign,
    final per-clause true counts [B, C] — zero entries mark the unsat
    clauses, the near-miss signal for warm starts)."""

    def clause_sat(assign):                       # [V+1] -> [C] int32
        return true_counts_ref(packed, assign)

    def step(carry, _):
        assign, tc, key = carry                   # [B,V+1], [B,C]
        unsat = tc == 0                           # [B, C]
        any_unsat = jnp.any(unsat, axis=-1)       # [B]
        key, k1, k2 = jax.random.split(key, 3)
        # pick a random unsat clause per chain
        logits = jnp.where(unsat, 0.0, -1e30)
        cidx = jax.random.categorical(k1, logits, axis=-1)      # [B]
        vs = packed.cvars[cidx]                   # [B, Lmax]
        vmask = vs > 0
        # break count per candidate var: clauses where v is the sole support
        occ_c = packed.ovars[vs]                  # [B, Lmax, Omax]
        occ_s = packed.osign[vs]
        occ_valid = occ_c >= 0
        occ_cc = jnp.where(occ_valid, occ_c, 0)
        flat = occ_cc.reshape(occ_cc.shape[0], -1)              # [B, L*O]
        tc_at = jnp.take_along_axis(tc, flat, axis=-1).reshape(occ_c.shape)
        a_at = jnp.take_along_axis(assign, vs, axis=-1)         # [B, Lmax]
        supports = occ_s == a_at[..., None]       # var currently satisfies c'
        brk = jnp.sum(occ_valid & supports & (tc_at == 1), axis=-1)  # [B,Lmax]
        # probSAT polynomial heuristic: p ∝ (1 + brk)^-cb
        w = jnp.where(vmask, -cb * jnp.log1p(brk.astype(jnp.float32)), -1e30)
        pick = jax.random.categorical(k2, w, axis=-1)           # [B]
        v_flip = jnp.take_along_axis(vs, pick[:, None], axis=-1)[:, 0]
        v_flip = jnp.where(any_unsat, v_flip, 0)  # flip dummy var 0 if solved
        # apply flip + incremental true-count update via occurrence lists
        new_val = ~jnp.take_along_axis(assign, v_flip[:, None], axis=-1)[:, 0]
        assign = assign.at[jnp.arange(assign.shape[0]), v_flip].set(new_val)
        occ_cf = packed.ovars[v_flip]             # [B, Omax]
        occ_sf = packed.osign[v_flip]
        validf = occ_cf >= 0
        delta = jnp.where(occ_sf == new_val[:, None], 1, -1)
        delta = jnp.where(validf, delta, 0)
        tc = tc + jnp.zeros_like(tc).at[
            jnp.arange(tc.shape[0])[:, None], jnp.where(validf, occ_cf, 0)
        ].add(delta)
        return (assign, tc, key), None

    tc0 = jax.vmap(clause_sat)(assign0)
    (assign, tc, _), _ = jax.lax.scan(step, (assign0, tc0, key), None,
                                      length=steps)
    solved = ~jnp.any(tc == 0, axis=-1)
    return solved, assign, tc


_run_chains = jax.jit(_chains_core, static_argnums=(3, 4))


@functools.partial(jax.jit, static_argnums=(4, 5, 6))
def _run_chains_window(cvars: jnp.ndarray, csign: jnp.ndarray,
                       ovars: jnp.ndarray, osign: jnp.ndarray,
                       n_vars: int, steps: int, cb: float,
                       assign0: jnp.ndarray, keys: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """vmapped probSAT over a *window* of K CNFs (one per candidate II).

    cvars/csign: [K, C, Lmax]; ovars/osign: [K, V+1, Omax];
    assign0: [K, B, V+1]; keys: [K, 2]. Returns (solved [K, B], assign,
    per-clause true counts [K, B, C] — the near-miss signal).
    """
    def one(cv, cs, ov, os_, a0, k):
        packed = PackedCNF(cv, cs, ov, os_, n_vars, cv.shape[0])
        return _chains_core(packed, a0, k, steps, cb)
    return jax.vmap(one)(cvars, csign, ovars, osign, assign0, keys)


def _bucket(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _next_chunk(prev: int, cap: int, remaining: int) -> int:
    """Progressive chunk schedule: double from 256 up to ``cap``, then
    shrink back down (powers of two only, so the handful of jit entries is
    shared) to land on the step budget without overshooting by more than
    one minimal chunk."""
    c = min(prev * 2, cap)
    while c > 256 and c > remaining:
        c //= 2
    return c


def _init_assign(key: jnp.ndarray, batch: int, n_vars_padded: int,
                 init: Optional[List[bool]]) -> jnp.ndarray:
    """Initial chain assignments [B, V+1]. Without ``init``: uniform
    random. With ``init`` (a warm start, e.g. the previous II's best
    near-miss under the shared variable numbering): chain 0 starts from it
    exactly and chain b flips a growing fraction (up to half) of the
    variables, so the batch explores a widening neighbourhood of the hint
    while keeping full random restarts in the tail."""
    if init is None:
        return jax.random.bernoulli(key, 0.5, (batch, n_vars_padded + 1))
    base = np.zeros(n_vars_padded + 1, bool)
    base[1:len(init) + 1] = np.asarray(init, bool)[:n_vars_padded]
    ps = jnp.linspace(0.0, 0.5, batch)[:, None]
    flips = jax.random.bernoulli(key, ps, (batch, n_vars_padded + 1))
    return jnp.asarray(base)[None, :] ^ flips


def pack_cnf_window(cnfs: List[CNF]) -> PackedCNF:
    """Pack K CNFs into one stacked PackedCNF padded to common shapes.

    Shorter clause lists are padded with the tautology clause (v1 ∨ ¬v1) —
    always exactly one true literal, so padded rows are never selected as
    unsat and never reach a solved flag. Padding rows are *excluded* from
    the occurrence lists, so break counts and incremental true-count
    updates are unaffected. Variable counts are padded to the max; extra
    vars occur in no clause and are never flipped.

    All dims are rounded up to coarse buckets so different windows (other
    kernels, other CGRA sizes) reuse the same jitted computation instead of
    paying a fresh XLA compile per instance shape.
    """
    packs = [pack_cnf(c) for c in cnfs]
    K = len(packs)
    V = _bucket(max(p.n_vars for p in packs), 128)
    C = _bucket(max(p.n_clauses for p in packs), 1024)
    L = max(p.cvars.shape[1] for p in packs)
    O = max(p.ovars.shape[1] for p in packs)
    L = _bucket(max(L, 2), 4)  # room for the (v1, ¬v1) padding tautology
    O = _bucket(O, 8)
    cvars = np.zeros((K, C, L), np.int32)
    csign = np.zeros((K, C, L), bool)
    ovars = np.full((K, V + 1, O), -1, np.int32)
    osign = np.zeros((K, V + 1, O), bool)
    for k, p in enumerate(packs):
        c, l = p.cvars.shape
        cvars[k, :c, :l] = np.asarray(p.cvars)
        csign[k, :c, :l] = np.asarray(p.csign)
        # tautology padding for clause rows [c, C)
        cvars[k, c:, 0] = 1
        cvars[k, c:, 1] = 1
        csign[k, c:, 0] = True
        csign[k, c:, 1] = False
        v, o = p.ovars.shape
        ovars[k, :v, :o] = np.asarray(p.ovars)
        osign[k, :v, :o] = np.asarray(p.osign)
    return PackedCNF(jnp.asarray(cvars), jnp.asarray(csign),
                     jnp.asarray(ovars), jnp.asarray(osign), V, C)


def solve_walksat_window(cnfs: List[CNF], *, seed: int = 0,
                         steps: int = 8192, batch: int = 24, cb: float = 2.3,
                         stop=None, should_skip=None, on_sat=None,
                         inits: Optional[List[Optional[List[bool]]]] = None,
                         near_miss: Optional[dict] = None,
                         ) -> List[Tuple[str, Optional[List[bool]]]]:
    """Batched probSAT across a window of candidate-II CNFs.

    All K formulas walk concurrently inside one jitted computation (vmapped
    restarts over the stacked clause tensors). Incomplete: per-CNF result is
    SAT or UNKNOWN, never UNSAT (structurally-empty-clause CNFs excepted).

    ``stop()`` aborts the whole window; ``should_skip(i)`` marks candidate i
    as no longer interesting (e.g. its complete solver already finished);
    ``on_sat(i, model)`` fires as soon as candidate i is certified, so the
    caller can early-cancel other work while remaining candidates keep
    walking.

    ``inits[i]`` warm-starts candidate i's chains from a prior assignment
    (see ``_init_assign``); ``near_miss``, when given a dict, receives
    ``{i: (n_unsat, assignment)}`` — the best assignment each unsolved
    candidate reached, which the incremental ``SolverSession`` feeds to the
    next window as the warm start.
    """
    from . import SAT, UNKNOWN, UNSAT
    K = len(cnfs)
    results: List[Tuple[str, Optional[List[bool]]]] = [(UNKNOWN, None)] * K
    live = []
    for i, cnf in enumerate(cnfs):
        if getattr(cnf, "trivially_unsat", False) or \
                any(len(c) == 0 for c in cnf.clauses):
            results[i] = (UNSAT, None)
        elif cnf.n_clauses == 0 or cnf.n_vars == 0:
            results[i] = (SAT, [False] * cnf.n_vars)
            if on_sat is not None:
                on_sat(i, results[i][1])
        else:
            live.append(i)
    if not live:
        return results
    packed = pack_cnf_window([cnfs[i] for i in live])
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    init_keys = jax.random.split(k0, len(live))
    assign0 = jnp.stack([
        _init_assign(init_keys[j], batch, packed.n_vars,
                     inits[live[j]] if inits is not None else None)
        for j in range(len(live))])
    # bound wall-time per chunk (stop/skip are only polled between chunks,
    # and a cancelled racer must drain fast): fewer steps for big formulas.
    # Chunks start small and double so easy SAT instances exit after a few
    # hundred steps instead of paying the full cap; chunk sizes are powers
    # of two, so the handful of jit entries is shared across windows.
    cap = max(64, min(steps, 2048, 2_000_000 // max(packed.n_clauses, 1)))
    chunk = min(256, cap)
    done = 0
    pending = set(range(len(live)))
    tc = None
    while done < steps and pending:
        if stop is not None and stop():
            break
        key, kc = jax.random.split(key)
        keys = jax.random.split(kc, len(live))
        solved, assign, tc = _run_chains_window(
            packed.cvars, packed.csign, packed.ovars, packed.osign,
            packed.n_vars, chunk, cb, assign0, keys)
        solved_np = np.asarray(solved)
        for j in sorted(pending):
            i = live[j]
            if should_skip is not None and should_skip(i):
                pending.discard(j)
                continue
            if not solved_np[j].any():
                continue
            row = int(np.argmax(solved_np[j]))
            model = [bool(b) for b in
                     np.asarray(assign[j, row])[1:cnfs[i].n_vars + 1]]
            assert cnfs[i].check(model), "walksat returned a non-model"
            results[i] = (SAT, model)
            pending.discard(j)
            if on_sat is not None:
                on_sat(i, model)
        assign0 = assign
        done += chunk
        chunk = _next_chunk(chunk, cap, steps - done)
    if near_miss is not None and tc is not None:
        n_unsat = np.asarray(jnp.sum(tc == 0, axis=-1))      # [K_live, B]
        assign_np = np.asarray(assign0)
        for j in range(len(live)):
            i = live[j]
            row = int(np.argmin(n_unsat[j]))
            near_miss[i] = (int(n_unsat[j, row]),
                            [bool(b) for b in
                             assign_np[j, row][1:cnfs[i].n_vars + 1]])
    return results


def solve_walksat(cnf: CNF, *, seed: int = 0, steps: int = 20000,
                  batch: int = 64, cb: float = 2.3, stop=None,
                  init: Optional[List[bool]] = None,
                  near_miss: Optional[dict] = None,
                  ) -> Tuple[str, Optional[List[bool]]]:
    from . import SAT, UNKNOWN, UNSAT
    if getattr(cnf, "trivially_unsat", False) or \
            any(len(c) == 0 for c in cnf.clauses):
        return UNSAT, None
    if cnf.n_clauses == 0 or cnf.n_vars == 0:
        return SAT, [False] * cnf.n_vars
    # bucketed padded pack (the K=1 window): consecutive IIs of a sweep —
    # and the incremental projections, whose handful of selector variables
    # would otherwise change the tensor shapes — reuse one XLA compile
    w = pack_cnf_window([cnf])
    packed = PackedCNF(w.cvars[0], w.csign[0], w.ovars[0], w.osign[0],
                       w.n_vars, w.n_clauses)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    assign0 = _init_assign(k0, batch, packed.n_vars, init)
    # chunk the walk so we can stop early once a chain solves; chunks
    # start small and double (powers of two share jit cache entries), so
    # easy instances return after a few hundred steps
    cap = max(256, min(steps, 2048))
    chunk = min(256, cap)
    done = 0
    tc = None
    while done < steps:
        if stop is not None and stop():
            return UNKNOWN, None
        key, kc = jax.random.split(key)
        solved, assign, tc = _run_chains(packed, assign0, kc, chunk, cb)
        solved = np.asarray(solved)
        if solved.any():
            row = int(np.argmax(solved))
            model = np.asarray(assign[row])[1:cnf.n_vars + 1].tolist()
            assert cnf.check(model), "walksat returned a non-model"
            return SAT, [bool(b) for b in model]
        assign0 = assign
        done += chunk
        chunk = _next_chunk(chunk, cap, steps - done)
    if near_miss is not None and tc is not None:
        n_unsat = np.asarray(jnp.sum(tc == 0, axis=-1))
        row = int(np.argmin(n_unsat))
        near_miss[0] = (int(n_unsat[row]),
                        [bool(b) for b in
                         np.asarray(assign0[row])[1:cnf.n_vars + 1]])
    return UNKNOWN, None

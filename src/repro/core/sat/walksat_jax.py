"""Batched probSAT/WalkSAT in JAX — the TPU-native mapper search path.

The KMS CNF is lowered to dense padded tensors; a *batch* of candidate
assignments walks in parallel (one probSAT chain per batch row), so clause
evaluation becomes regular tensor work that the VPU/MXU executes well. On a
pod the batch is sharded over the mesh with shard_map (see portfolio.py);
the first chain to satisfy the formula wins.

This solver is incomplete: it can certify SAT but returns UNKNOWN instead of
UNSAT — the Fig. 3 loop then falls back to CDCL/Z3 for the UNSAT proof.

``pack_cnf``/``true_counts_ref`` are also the reference oracle for the
``kernels/clause_eval`` Pallas kernel.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..cnf import CNF


class PackedCNF(NamedTuple):
    cvars: jnp.ndarray   # [C, Lmax] int32 var ids (1-based), 0 = padding
    csign: jnp.ndarray   # [C, Lmax] bool, True = positive literal
    ovars: jnp.ndarray   # [V+1, Omax] int32 clause ids (0-based), -1 = padding
    osign: jnp.ndarray   # [V+1, Omax] bool sign of the var in that clause
    n_vars: int
    n_clauses: int


def pack_cnf(cnf: CNF) -> PackedCNF:
    lmax = max((len(c) for c in cnf.clauses), default=1)
    C = cnf.n_clauses
    cvars = np.zeros((C, lmax), np.int32)
    csign = np.zeros((C, lmax), bool)
    occ: List[List[Tuple[int, bool]]] = [[] for _ in range(cnf.n_vars + 1)]
    for ci, cl in enumerate(cnf.clauses):
        for j, lit in enumerate(cl):
            v = abs(lit)
            cvars[ci, j] = v
            csign[ci, j] = lit > 0
            occ[v].append((ci, lit > 0))
    omax = max((len(o) for o in occ), default=1)
    ovars = np.full((cnf.n_vars + 1, omax), -1, np.int32)
    osign = np.zeros((cnf.n_vars + 1, omax), bool)
    for v, lst in enumerate(occ):
        for j, (ci, s) in enumerate(lst):
            ovars[v, j] = ci
            osign[v, j] = s
    return PackedCNF(jnp.asarray(cvars), jnp.asarray(csign),
                     jnp.asarray(ovars), jnp.asarray(osign),
                     cnf.n_vars, C)


def true_counts_ref(packed: PackedCNF, assign: jnp.ndarray) -> jnp.ndarray:
    """Per-clause count of satisfied literals. assign: [V+1] bool -> [C] int32.

    Pure-jnp oracle; the Pallas ``clause_eval`` kernel computes the same
    quantity blockwise (see repro.kernels.clause_eval).
    """
    mask = packed.cvars > 0
    vals = assign[packed.cvars] == packed.csign
    return jnp.sum(jnp.where(mask, vals, False), axis=-1).astype(jnp.int32)


def true_counts_batch(packed: PackedCNF, assign: jnp.ndarray,
                      use_kernel: bool | None = None) -> jnp.ndarray:
    """Batched per-clause true counts [B, C]; routes to the Pallas
    clause_eval kernel on TPU (VMEM-tiled), jnp oracle elsewhere."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from ...kernels.clause_eval import true_counts as tc_kernel
        return tc_kernel(packed.cvars, packed.csign.astype(bool), assign)
    return jax.vmap(lambda a: true_counts_ref(packed, a))(assign)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _run_chains(packed: PackedCNF, assign0: jnp.ndarray, key: jnp.ndarray,
                steps: int, cb: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """probSAT chains. assign0: [B, V+1] bool. Returns (solved [B], assign)."""

    def clause_sat(assign):                       # [V+1] -> [C] int32
        return true_counts_ref(packed, assign)

    def step(carry, _):
        assign, tc, key = carry                   # [B,V+1], [B,C]
        unsat = tc == 0                           # [B, C]
        any_unsat = jnp.any(unsat, axis=-1)       # [B]
        key, k1, k2 = jax.random.split(key, 3)
        # pick a random unsat clause per chain
        logits = jnp.where(unsat, 0.0, -1e30)
        cidx = jax.random.categorical(k1, logits, axis=-1)      # [B]
        vs = packed.cvars[cidx]                   # [B, Lmax]
        vmask = vs > 0
        # break count per candidate var: clauses where v is the sole support
        occ_c = packed.ovars[vs]                  # [B, Lmax, Omax]
        occ_s = packed.osign[vs]
        occ_valid = occ_c >= 0
        occ_cc = jnp.where(occ_valid, occ_c, 0)
        flat = occ_cc.reshape(occ_cc.shape[0], -1)              # [B, L*O]
        tc_at = jnp.take_along_axis(tc, flat, axis=-1).reshape(occ_c.shape)
        a_at = jnp.take_along_axis(assign, vs, axis=-1)         # [B, Lmax]
        supports = occ_s == a_at[..., None]       # var currently satisfies c'
        brk = jnp.sum(occ_valid & supports & (tc_at == 1), axis=-1)  # [B,Lmax]
        # probSAT polynomial heuristic: p ∝ (1 + brk)^-cb
        w = jnp.where(vmask, -cb * jnp.log1p(brk.astype(jnp.float32)), -1e30)
        pick = jax.random.categorical(k2, w, axis=-1)           # [B]
        v_flip = jnp.take_along_axis(vs, pick[:, None], axis=-1)[:, 0]
        v_flip = jnp.where(any_unsat, v_flip, 0)  # flip dummy var 0 if solved
        # apply flip + incremental true-count update via occurrence lists
        new_val = ~jnp.take_along_axis(assign, v_flip[:, None], axis=-1)[:, 0]
        assign = assign.at[jnp.arange(assign.shape[0]), v_flip].set(new_val)
        occ_cf = packed.ovars[v_flip]             # [B, Omax]
        occ_sf = packed.osign[v_flip]
        validf = occ_cf >= 0
        delta = jnp.where(occ_sf == new_val[:, None], 1, -1)
        delta = jnp.where(validf, delta, 0)
        tc = tc + jnp.zeros_like(tc).at[
            jnp.arange(tc.shape[0])[:, None], jnp.where(validf, occ_cf, 0)
        ].add(delta)
        return (assign, tc, key), None

    tc0 = jax.vmap(clause_sat)(assign0)
    (assign, tc, _), _ = jax.lax.scan(step, (assign0, tc0, key), None,
                                      length=steps)
    solved = ~jnp.any(tc == 0, axis=-1)
    return solved, assign


def solve_walksat(cnf: CNF, *, seed: int = 0, steps: int = 20000,
                  batch: int = 64, cb: float = 2.3,
                  ) -> Tuple[str, Optional[List[bool]]]:
    from . import SAT, UNKNOWN, UNSAT
    if any(len(c) == 0 for c in cnf.clauses):
        return UNSAT, None
    if cnf.n_clauses == 0 or cnf.n_vars == 0:
        return SAT, [False] * cnf.n_vars
    packed = pack_cnf(cnf)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    assign0 = jax.random.bernoulli(k0, 0.5, (batch, cnf.n_vars + 1))
    # chunk the walk so we can stop early once a chain solves
    chunk = max(256, min(steps, 2048))
    done = 0
    while done < steps:
        key, kc = jax.random.split(key)
        solved, assign = _run_chains(packed, assign0, kc, chunk, cb)
        solved = np.asarray(solved)
        if solved.any():
            row = int(np.argmax(solved))
            model = np.asarray(assign[row])[1:].tolist()
            assert cnf.check(model), "walksat returned a non-model"
            return SAT, [bool(b) for b in model]
        assign0 = assign
        done += chunk
    return UNKNOWN, None

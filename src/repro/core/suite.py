"""Benchmark loop kernels (MiBench / Rodinia-style, paper §V).

The paper maps pragma-annotated loop bodies from MiBench and Rodinia. The
original C sources (and the authors' LLVM pass output) are not shipped here,
so each kernel below is a faithful *DFG-level* reconstruction of the loop
body the paper names: same computation family, realistic op mix, loads and
stores, and loop-carried dependencies. Every DFG is executable, so mappings
are always validated observationally against sequential semantics.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from .dfg import DFG

_REGISTRY: Dict[str, Callable[[], DFG]] = {}


def register(fn: Callable[[], DFG]) -> Callable[[], DFG]:
    _REGISTRY[fn.__name__] = fn
    return fn


def names() -> List[str]:
    return list(_REGISTRY)


def get(name: str) -> DFG:
    g = _REGISTRY[name]()
    g.validate()
    return g


def all_dfgs() -> Dict[str, DFG]:
    return {n: get(n) for n in names()}


def run_suite(cgra, cfg=None, sweep_width: int = 1,
              names_subset: Optional[List[str]] = None,
              service=None) -> Dict[str, object]:
    """Map every suite kernel on ``cgra`` and return {name: MappingResult}.

    ``sweep_width=1`` runs the paper-faithful sequential Fig. 3 loop;
    ``sweep_width>1`` runs the parallel II-sweep engine
    (``repro.core.sweep``). The two modes find the same II on every kernel
    (asserted by tests/test_sweep.py); this is the convenience entry point
    for batch runs over the whole suite.

    ``service`` (a ``repro.core.service.MappingService``) routes every
    kernel through the long-lived solver pool + mapping cache — a second
    ``run_suite`` pass through the same service starts warm (cache hits,
    reused sessions, core-pruned IIs). ``None`` preserves the standalone
    per-kernel behaviour.

    This is now a thin batch shim over the unified front door: each kernel
    becomes one ``MapRequest`` served by ``repro.core.api.compile`` (which
    also accepts fabric *names* and heterogeneous ``ArchSpec``s for
    ``cgra``).
    """
    from .api import MapRequest, compile as compile_request
    from .mapper import MapperConfig
    cfg = cfg or MapperConfig()
    out: Dict[str, object] = {}
    for name in (names_subset or names()):
        out[name] = compile_request(MapRequest(
            dfg=get(name), arch=cgra, config=cfg, sweep_width=sweep_width,
            service=service))
    return out


def _carry(g: DFG, nid: int, src: int, slot: int = 0, dist: int = 1) -> None:
    """Patch input ``slot`` of node ``nid`` to read ``src`` from ``dist``
    iterations earlier (loop-carried back-edge)."""
    ins = list(g.nodes[nid].ins)
    ins[slot] = (src, dist)
    g.nodes[nid].ins = tuple(ins)
    g.touch()


@register
def sha() -> DFG:
    """SHA-1 round flavour: rotate-left by 5/30, xor mixing, adds; carried
    working variables."""
    g = DFG("sha")
    a0 = g.add("const", imm=0x67452301, name="a0")
    iv = g.add("iv", name="i")
    w = g.add("load", [(iv, 0)], imm=100, name="w")
    s5 = g.add("shl", [(a0, 0), (g.add("const", imm=5, name="c5"), 0)], name="s5")
    r27 = g.add("shr", [(a0, 0), (g.add("const", imm=27, name="c27"), 0)], name="r27")
    rot5 = g.add("or", [(s5, 0), (r27, 0)], name="rot5")
    fx = g.add("xor", [(a0, 0), (w, 0)], name="fx")
    fa = g.add("and", [(fx, 0), (rot5, 0)], name="fa")
    t1 = g.add("add", [(rot5, 0), (fa, 0)], name="t1")
    t2 = g.add("add", [(t1, 0), (w, 0)], name="t2")
    e = g.add("add", [(t2, 0), (a0, 0)], name="e")
    st = g.add("store", [(iv, 0), (e, 0)], imm=200, name="st")
    # carried: a0 of next iteration is e
    _carry(g, s5, e, 0)
    _carry(g, r27, e, 0)
    _carry(g, fx, e, 0)
    _carry(g, e, e, 1)
    return g


@register
def sha2() -> DFG:
    """SHA-256 sigma flavour: two rotate-xor ladders + adds; longer chains."""
    g = DFG("sha2")
    iv = g.add("iv", name="i")
    x = g.add("load", [(iv, 0)], imm=0, name="x")
    c7 = g.add("const", imm=7, name="c7")
    c18 = g.add("const", imm=18, name="c18")
    c3 = g.add("const", imm=3, name="c3")
    r7 = g.add("shr", [(x, 0), (c7, 0)], name="r7")
    l25 = g.add("shl", [(x, 0), (c18, 0)], name="l25")
    rot1 = g.add("or", [(r7, 0), (l25, 0)], name="rot1")
    r18 = g.add("shr", [(x, 0), (c18, 0)], name="r18")
    l14 = g.add("shl", [(x, 0), (c7, 0)], name="l14")
    rot2 = g.add("or", [(r18, 0), (l14, 0)], name="rot2")
    sh3 = g.add("shr", [(x, 0), (c3, 0)], name="sh3")
    x1 = g.add("xor", [(rot1, 0), (rot2, 0)], name="x1")
    s0 = g.add("xor", [(x1, 0), (sh3, 0)], name="s0")
    acc = g.add("add", [(s0, 0), (s0, 0)], name="acc")
    w16 = g.add("load", [(iv, 0)], imm=300, name="w16")
    t = g.add("add", [(acc, 0), (w16, 0)], name="t")
    st = g.add("store", [(iv, 0), (t, 0)], imm=400, name="st")
    _carry(g, acc, acc, 1)   # running sum
    return g


@register
def gsm() -> DFG:
    """GSM add/mult with saturation: mul, shift, clamp via min/max."""
    g = DFG("gsm")
    iv = g.add("iv", name="i")
    a = g.add("load", [(iv, 0)], imm=0, name="a")
    b = g.add("load", [(iv, 0)], imm=100, name="b")
    m = g.add("mul", [(a, 0), (b, 0)], name="m")
    c1 = g.add("const", imm=1, name="c1")
    cmax = g.add("const", imm=32767, name="cmax")
    cmin = g.add("const", imm=-32768, name="cmin")
    sh = g.add("shr", [(m, 0), (c1, 0)], name="sh")
    lo = g.add("max", [(sh, 0), (cmin, 0)], name="lo")
    hi = g.add("min", [(lo, 0), (cmax, 0)], name="hi")
    st = g.add("store", [(iv, 0), (hi, 0)], imm=200, name="st")
    return g


@register
def patricia() -> DFG:
    """Patricia trie bit test: load node, extract bit, select child, reload."""
    g = DFG("patricia")
    iv = g.add("iv", name="i")
    p = g.add("load", [(iv, 0)], imm=0, name="p")
    key = g.add("load", [(iv, 0)], imm=100, name="key")
    c31 = g.add("const", imm=31, name="c31")
    c1 = g.add("const", imm=1, name="c1")
    bitpos = g.add("and", [(p, 0), (c31, 0)], name="bitpos")
    sh = g.add("shr", [(key, 0), (bitpos, 0)], name="sh")
    bit = g.add("and", [(sh, 0), (c1, 0)], name="bit")
    l = g.add("add", [(p, 0), (c1, 0)], name="l")
    r = g.add("add", [(p, 0), (bit, 0)], name="r")
    nxt = g.add("select", [(bit, 0), (l, 0), (r, 0)], name="nxt")
    cmp = g.add("lt", [(nxt, 0), (key, 0)], name="cmp")
    acc = g.add("add", [(cmp, 0), (cmp, 0)], name="acc")
    st = g.add("store", [(iv, 0), (acc, 0)], imm=200, name="st")
    _carry(g, acc, acc, 1)
    return g


@register
def bitcount() -> DFG:
    """Kernighan popcount step: n &= n-1; count++ (carried n and count)."""
    g = DFG("bitcount")
    iv = g.add("iv", name="i")
    n0 = g.add("load", [(iv, 0)], imm=0, name="n0")
    c1 = g.add("const", imm=1, name="c1")
    nm1 = g.add("sub", [(n0, 0), (c1, 0)], name="nm1")
    nn = g.add("and", [(n0, 0), (nm1, 0)], name="nn")
    ne0 = g.add("ne", [(nn, 0), (g.add("const", imm=0, name="c0"), 0)], name="ne0")
    cnt = g.add("add", [(ne0, 0), (ne0, 0)], name="cnt")
    st = g.add("store", [(iv, 0), (cnt, 0)], imm=100, name="st")
    _carry(g, cnt, cnt, 1)
    return g


@register
def backprop() -> DFG:
    """Rodinia backprop weight update: w += lr * delta * x, layered loads."""
    g = DFG("backprop")
    iv = g.add("iv", name="i")
    x = g.add("load", [(iv, 0)], imm=0, name="x")
    delta = g.add("load", [(iv, 0)], imm=100, name="delta")
    w = g.add("load", [(iv, 0)], imm=200, name="w")
    lr = g.add("const", imm=3, name="lr")
    dx = g.add("mul", [(delta, 0), (x, 0)], name="dx")
    upd = g.add("mul", [(dx, 0), (lr, 0)], name="upd")
    mom = g.add("mul", [(w, 0), (lr, 0)], name="mom")
    s1 = g.add("add", [(upd, 0), (mom, 0)], name="s1")
    wn = g.add("add", [(w, 0), (s1, 0)], name="wn")
    st = g.add("store", [(iv, 0), (wn, 0)], imm=200, name="st")
    err = g.add("add", [(upd, 0), (upd, 0)], name="err")
    _carry(g, err, err, 1)
    return g


@register
def nw() -> DFG:
    """Needleman-Wunsch cell: max of three neighbours + score, store."""
    g = DFG("nw")
    iv = g.add("iv", name="i")
    nw_ = g.add("load", [(iv, 0)], imm=0, name="nw")
    n_ = g.add("load", [(iv, 0)], imm=100, name="n")
    w_ = g.add("load", [(iv, 0)], imm=200, name="w")
    sc = g.add("load", [(iv, 0)], imm=300, name="sc")
    pen = g.add("const", imm=1, name="pen")
    diag = g.add("add", [(nw_, 0), (sc, 0)], name="diag")
    up = g.add("sub", [(n_, 0), (pen, 0)], name="up")
    left = g.add("sub", [(w_, 0), (pen, 0)], name="left")
    m1 = g.add("max", [(diag, 0), (up, 0)], name="m1")
    m2 = g.add("max", [(m1, 0), (left, 0)], name="m2")
    st = g.add("store", [(iv, 0), (m2, 0)], imm=400, name="st")
    return g


@register
def srand() -> DFG:
    """LCG pseudo-random step: seed = (a*seed + c) & mask (carried seed)."""
    g = DFG("srand")
    a = g.add("const", imm=1103515245, name="a")
    c = g.add("const", imm=12345, name="c")
    mask = g.add("const", imm=0x7FFFFFFF, name="mask")
    iv = g.add("iv", name="i")
    mul = g.add("mul", [(a, 0), (a, 0)], name="mul")
    addc = g.add("add", [(mul, 0), (c, 0)], name="addc")
    seed = g.add("and", [(addc, 0), (mask, 0)], name="seed")
    st = g.add("store", [(iv, 0), (seed, 0)], imm=0, name="st")
    _carry(g, mul, seed, 1)
    return g


@register
def hotspot() -> DFG:
    """Rodinia hotspot 5-point stencil: weighted neighbour sum + update."""
    g = DFG("hotspot")
    iv = g.add("iv", name="i")
    c_ = g.add("load", [(iv, 0)], imm=0, name="c")
    n_ = g.add("load", [(iv, 0)], imm=100, name="n")
    s_ = g.add("load", [(iv, 0)], imm=200, name="s")
    e_ = g.add("load", [(iv, 0)], imm=300, name="e")
    w_ = g.add("load", [(iv, 0)], imm=400, name="w")
    p_ = g.add("load", [(iv, 0)], imm=500, name="p")
    ns = g.add("add", [(n_, 0), (s_, 0)], name="ns")
    ew = g.add("add", [(e_, 0), (w_, 0)], name="ew")
    c2 = g.add("const", imm=2, name="c2")
    cc = g.add("mul", [(c_, 0), (c2, 0)], name="cc")
    nsc = g.add("sub", [(ns, 0), (cc, 0)], name="nsc")
    ewc = g.add("sub", [(ew, 0), (cc, 0)], name="ewc")
    lap = g.add("add", [(nsc, 0), (ewc, 0)], name="lap")
    heat = g.add("add", [(lap, 0), (p_, 0)], name="heat")
    out = g.add("add", [(c_, 0), (heat, 0)], name="out")
    st = g.add("store", [(iv, 0), (out, 0)], imm=600, name="st")
    return g


@register
def basicmath() -> DFG:
    """Cubic polynomial step (Horner) with carried accumulator."""
    g = DFG("basicmath")
    iv = g.add("iv", name="i")
    a3 = g.add("const", imm=2, name="a3")
    a2 = g.add("const", imm=-5, name="a2")
    a1 = g.add("const", imm=7, name="a1")
    a0 = g.add("const", imm=-11, name="a0")
    h1 = g.add("mul", [(a3, 0), (iv, 0)], name="h1")
    h2 = g.add("add", [(h1, 0), (a2, 0)], name="h2")
    h3 = g.add("mul", [(h2, 0), (iv, 0)], name="h3")
    h4 = g.add("add", [(h3, 0), (a1, 0)], name="h4")
    h5 = g.add("mul", [(h4, 0), (iv, 0)], name="h5")
    h6 = g.add("add", [(h5, 0), (a0, 0)], name="h6")
    acc = g.add("add", [(h6, 0), (h6, 0)], name="acc")
    st = g.add("store", [(iv, 0), (acc, 0)], imm=0, name="st")
    _carry(g, acc, acc, 1)
    return g


@register
def stringsearch() -> DFG:
    """Boyer-Moore-Horspool flavour: compare text/pattern chars, update skip."""
    g = DFG("stringsearch")
    iv = g.add("iv", name="i")
    t = g.add("load", [(iv, 0)], imm=0, name="t")
    p = g.add("load", [(iv, 0)], imm=100, name="p")
    eq = g.add("eq", [(t, 0), (p, 0)], name="eq")
    c1 = g.add("const", imm=1, name="c1")
    sk = g.add("load", [(t, 0)], imm=200, name="sk")
    adv = g.add("select", [(eq, 0), (c1, 0), (sk, 0)], name="adv")
    pos = g.add("add", [(adv, 0), (adv, 0)], name="pos")
    st = g.add("store", [(iv, 0), (pos, 0)], imm=300, name="st")
    _carry(g, pos, pos, 1)
    return g

"""ASAP / ALAP / Mobility Schedule / Kernel Mobility Schedule (paper §IV-B).

The KMS is the paper's custom structure: the Mobility Schedule folded by II.
A node whose mobility window is [asap, alap] has one KMS *candidate* per time
slot t in that window, encoded as (cycle = t mod II, iteration = t // II).
The KMS is "a superset of all possible kernels".

Timing model: every function here accepts per-node latencies (``lat``, a
{node id: cycles} mapping from :func:`node_latencies`; ``None`` = the
paper's all-unit model). A producer issued at t delivers its result at
t + lat, so ASAP/ALAP windows stretch, RecMII sums true latencies around
each dependency cycle, and the schedule length counts the last *completion*
rather than the last issue. With every latency 1 all formulas reduce
exactly to the paper's — the downstream CNF is bit-identical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import networkx as nx

from .arch import op_class
from .cgra import CGRA
from .dfg import DFG

# simple_cycles enumeration bound: a dense DFG has exponentially many
# simple cycles; past this many, rec_mii switches to the exact
# positive-cycle feasibility search (see _rec_mii_feasible) instead of
# hanging the mapper before it ever reaches the solver.
REC_MII_CYCLE_CAP = 20_000


class Infeasible(ValueError):
    """Structural proof that *no* II can ever map this DFG on this fabric
    (e.g. an op class with zero capable PEs). Raised by :func:`res_mii` /
    :func:`min_ii`; the mapping engines convert it into a structured
    ``MappingResult.infeasible`` verdict instead of running a doomed
    II sweep, and ``repro.core.api.compile`` surfaces it as a clean
    front-door error."""

    def __init__(self, msg: str, *, op_class: Optional[str] = None,
                 n_ops: int = 0):
        super().__init__(msg)
        self.op_class = op_class
        self.n_ops = n_ops


def node_latencies(dfg: DFG, cgra=None) -> Dict[int, int]:
    """Per-node issue->result latencies on ``cgra`` (``ArchSpec`` or the
    legacy ``CGRA`` adapter, both exposing ``lat(op_class)``). ``None`` —
    or a fabric without a latency table — is the paper's unit model."""
    lat_fn = getattr(cgra, "lat", None) if cgra is not None else None
    if lat_fn is None:
        return {nid: 1 for nid in dfg.nodes}
    return {nid: lat_fn(op_class(nd.op)) for nid, nd in dfg.nodes.items()}


def asap_alap(dfg: DFG, lat: Optional[Dict[int, int]] = None,
              ) -> Tuple[Dict[int, int], Dict[int, int], int]:
    """Forward-edge (distance-0) ASAP/ALAP (paper Fig. 4), latency-aware.

    Returns (asap, alap, schedule_length L). A node issued at t completes
    at t + lat[n]; L is the earliest completion of the whole body and ALAP
    is relative to it, so sinks finish exactly at L. With unit latencies
    this is the paper's table: L = critical path length, sinks at L-1.
    """
    order = dfg.topo_order()
    if lat is None:
        lat = {nid: 1 for nid in order}
    asap = {nid: 0 for nid in order}
    for nid in order:
        for src in dfg.preds(nid):
            asap[nid] = max(asap[nid], asap[src] + lat[src])
    length = max((asap[nid] + lat[nid] for nid in order), default=0)
    alap = {nid: length - lat[nid] for nid in order}
    for nid in reversed(order):
        for dst in dfg.succs(nid):
            alap[nid] = min(alap[nid], alap[dst] - lat[nid])
    return asap, alap, length


def res_mii(dfg: DFG, cgra: CGRA) -> int:
    """Per-resource-class ResMII: beyond the paper's node-count bound, each
    op class (alu / mem / mul — see ``repro.core.arch.op_class``) is
    bottlenecked by the PEs that support it, so a heterogeneous fabric's
    lower bound is max over classes of ceil(#ops / #capable PEs). On the
    paper's homogeneous CGRA this reduces exactly to the old
    node-count + memory-line bound.

    Raises :class:`Infeasible` when some op class present in the DFG has
    *zero* capable PEs — there is no finite II bound for that, and the
    old ``max(supporters, 1)`` fallback silently sent callers into a
    sweep that could never succeed."""
    mii = math.ceil(dfg.n / cgra.n_pes)
    counts: Dict[str, int] = {}
    for nd in dfg.nodes.values():
        cls = op_class(nd.op)
        counts[cls] = counts.get(cls, 0) + 1
    for cls, cnt in sorted(counts.items()):
        supporters = len(cgra.pes_for_class(cls))
        if supporters == 0:
            raise Infeasible(
                f"{dfg.name}: {cnt} {cls!r} op(s) but no {cls}-capable PE "
                f"on {cgra} — no II can map this DFG on this fabric",
                op_class=cls, n_ops=cnt)
        mii = max(mii, math.ceil(cnt / supporters))
    return max(mii, 1)


def _rec_mii_feasible(nodes, edges, lat: Dict[int, int], ii: int) -> bool:
    """True iff ``ii`` satisfies every recurrence: no positive cycle in
    the dependency graph under edge weights lat[s] - dist*ii (Bellman-Ford
    longest-path relaxation, O(V*E) — the polynomial fallback when simple-
    cycle enumeration is capped)."""
    d = {n: 0 for n in nodes}
    for _ in range(len(nodes)):
        changed = False
        for s, t, dd in edges:
            w = d[s] + lat[s] - dd * ii
            if w > d[t]:
                d[t] = w
                changed = True
        if not changed:
            return True
    return all(d[s] + lat[s] - dd * ii <= d[t] for s, t, dd in edges)


def rec_mii(dfg: DFG, lat: Optional[Dict[int, int]] = None,
            max_cycles: int = REC_MII_CYCLE_CAP) -> int:
    """max over dependency cycles of ceil(latency / distance), where
    latency is the *sum of true per-node latencies* around the cycle and
    distance the sum of per-edge loop-carried distances.

    Parallel edges between one node pair each close their own cycle; the
    bound uses the smallest distance among them per hop, which is exactly
    the max of the per-edge bounds (ceil is antitone in the distance), so
    no parallel edge's constraint is lost. Enumeration of simple cycles is
    capped at ``max_cycles``: past that, the exact answer is recovered by
    binary-searching the smallest II with no positive cycle under
    (latency - distance*II) edge weights — dense DFGs can no longer hang
    MII computation.
    """
    if lat is None:
        lat = {nid: 1 for nid in dfg.nodes}
    g = nx.DiGraph()
    g.add_nodes_from(dfg.nodes)
    dist: Dict[Tuple[int, int], int] = {}
    for s, d, dd in dfg.edges():
        key = (s, d)
        # min over parallel edges: each such edge contributes its own
        # cycle bound, and the smallest distance dominates them all
        if key not in dist or dd < dist[key]:
            dist[key] = dd
        g.add_edge(s, d)
    best = 1
    capped = False
    for n_seen, cyc in enumerate(nx.simple_cycles(g)):
        if n_seen >= max_cycles:
            capped = True
            break
        latency = sum(lat[n] for n in cyc)
        distance = sum(dist[(cyc[i], cyc[(i + 1) % len(cyc)])]
                       for i in range(len(cyc)))
        if distance > 0:
            best = max(best, math.ceil(latency / distance))
    if capped:
        # exact polynomial fallback: feasibility is monotone in II, and
        # any cycle's bound is <= the total latency sum (distance >= 1)
        edges = [(s, d, dd) for (s, d), dd in dist.items()]
        lo, hi = best, max(best, sum(lat.values()))
        while lo < hi:
            mid = (lo + hi) // 2
            if _rec_mii_feasible(list(dfg.nodes), edges, lat, mid):
                hi = mid
            else:
                lo = mid + 1
        best = lo
    return best


def min_ii(dfg: DFG, cgra: CGRA) -> int:
    """MII = max(ResMII, RecMII) under the fabric's latency model.
    Raises :class:`Infeasible` when no II can ever work (see res_mii)."""
    return max(res_mii(dfg, cgra), rec_mii(dfg, node_latencies(dfg, cgra)))


@dataclass
class KMS:
    """Kernel Mobility Schedule for one candidate II."""
    ii: int
    length: int                                  # mobility-schedule length L
    n_folds: int                                 # ceil(L / II) iterations
    asap: Dict[int, int]
    alap: Dict[int, int]
    # node -> list of candidate (cycle, iteration) pairs, cycle in [0, II)
    candidates: Dict[int, List[Tuple[int, int]]]

    def flat_time(self, cycle: int, iteration: int) -> int:
        return iteration * self.ii + cycle

    def rows(self) -> List[List[Tuple[int, int]]]:
        """KMS rows (paper Fig. 5): row c -> [(node, iteration), ...]."""
        out: List[List[Tuple[int, int]]] = [[] for _ in range(self.ii)]
        for nid, cands in self.candidates.items():
            for c, it in cands:
                out[c].append((nid, it))
        for row in out:
            row.sort()
        return out


def mobility_schedule(dfg: DFG, lat: Optional[Dict[int, int]] = None,
                      ) -> List[List[int]]:
    """Paper Fig. 4 MS: row t lists nodes whose [asap, alap] window covers t."""
    asap, alap, length = asap_alap(dfg, lat)
    return [[nid for nid in sorted(dfg.nodes)
             if asap[nid] <= t <= alap[nid]] for t in range(length)]


def build_kms(dfg: DFG, ii: int,
              lat: Optional[Dict[int, int]] = None) -> KMS:
    asap, alap, length = asap_alap(dfg, lat)
    n_folds = max(1, math.ceil(length / ii))
    cands = {
        nid: [(t % ii, t // ii) for t in range(asap[nid], alap[nid] + 1)]
        for nid in dfg.nodes
    }
    return KMS(ii=ii, length=length, n_folds=n_folds, asap=asap, alap=alap,
               candidates=cands)

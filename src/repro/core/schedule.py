"""ASAP / ALAP / Mobility Schedule / Kernel Mobility Schedule (paper §IV-B).

The KMS is the paper's custom structure: the Mobility Schedule folded by II.
A node whose mobility window is [asap, alap] has one KMS *candidate* per time
slot t in that window, encoded as (cycle = t mod II, iteration = t // II).
The KMS is "a superset of all possible kernels".
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import networkx as nx

from .arch import op_class
from .cgra import CGRA
from .dfg import DFG


def asap_alap(dfg: DFG) -> Tuple[Dict[int, int], Dict[int, int], int]:
    """Forward-edge (distance-0) ASAP/ALAP with unit latencies (paper Fig. 4).

    Returns (asap, alap, schedule_length L). ALAP is relative to the critical
    path length, i.e. sinks sit at L-1.
    """
    order = dfg.topo_order()
    asap = {nid: 0 for nid in order}
    for nid in order:
        for src in dfg.preds(nid):
            asap[nid] = max(asap[nid], asap[src] + 1)
    length = max(asap.values()) + 1 if asap else 0
    alap = {nid: length - 1 for nid in order}
    for nid in reversed(order):
        for dst in dfg.succs(nid):
            alap[nid] = min(alap[nid], alap[dst] - 1)
    return asap, alap, length


def res_mii(dfg: DFG, cgra: CGRA) -> int:
    """Per-resource-class ResMII: beyond the paper's node-count bound, each
    op class (alu / mem / mul — see ``repro.core.arch.op_class``) is
    bottlenecked by the PEs that support it, so a heterogeneous fabric's
    lower bound is max over classes of ceil(#ops / #capable PEs). On the
    paper's homogeneous CGRA this reduces exactly to the old
    node-count + memory-line bound."""
    mii = math.ceil(dfg.n / cgra.n_pes)
    counts: Dict[str, int] = {}
    for nd in dfg.nodes.values():
        cls = op_class(nd.op)
        counts[cls] = counts.get(cls, 0) + 1
    for cls, cnt in counts.items():
        supporters = len(cgra.pes_for_class(cls))
        mii = max(mii, math.ceil(cnt / max(supporters, 1)))
    return max(mii, 1)


def rec_mii(dfg: DFG) -> int:
    """max over dependency cycles of ceil(latency / distance)."""
    g = nx.DiGraph()
    g.add_nodes_from(dfg.nodes)
    dist: Dict[Tuple[int, int], int] = {}
    for s, d, dd in dfg.edges():
        key = (s, d)
        if key in dist:
            dist[key] = min(dist[key], dd)
        else:
            dist[key] = dd
        g.add_edge(s, d)
    best = 1
    for cyc in nx.simple_cycles(g):
        latency = len(cyc)  # unit latency per node
        distance = sum(dist[(cyc[i], cyc[(i + 1) % len(cyc)])]
                       for i in range(len(cyc)))
        if distance > 0:
            best = max(best, math.ceil(latency / distance))
    return best


def min_ii(dfg: DFG, cgra: CGRA) -> int:
    return max(res_mii(dfg, cgra), rec_mii(dfg))


@dataclass
class KMS:
    """Kernel Mobility Schedule for one candidate II."""
    ii: int
    length: int                                  # mobility-schedule length L
    n_folds: int                                 # ceil(L / II) iterations
    asap: Dict[int, int]
    alap: Dict[int, int]
    # node -> list of candidate (cycle, iteration) pairs, cycle in [0, II)
    candidates: Dict[int, List[Tuple[int, int]]]

    def flat_time(self, cycle: int, iteration: int) -> int:
        return iteration * self.ii + cycle

    def rows(self) -> List[List[Tuple[int, int]]]:
        """KMS rows (paper Fig. 5): row c -> [(node, iteration), ...]."""
        out: List[List[Tuple[int, int]]] = [[] for _ in range(self.ii)]
        for nid, cands in self.candidates.items():
            for c, it in cands:
                out[c].append((nid, it))
        for row in out:
            row.sort()
        return out


def mobility_schedule(dfg: DFG) -> List[List[int]]:
    """Paper Fig. 4 MS: row t lists nodes whose [asap, alap] window covers t."""
    asap, alap, length = asap_alap(dfg)
    return [[nid for nid in sorted(dfg.nodes)
             if asap[nid] <= t <= alap[nid]] for t in range(length)]


def build_kms(dfg: DFG, ii: int) -> KMS:
    asap, alap, length = asap_alap(dfg)
    n_folds = max(1, math.ceil(length / ii))
    cands = {
        nid: [(t % ii, t // ii) for t in range(asap[nid], alap[nid] + 1)]
        for nid in dfg.nodes
    }
    return KMS(ii=ii, length=length, n_folds=n_folds, asap=asap, alap=alap,
               candidates=cands)

"""The SAT-MapIt iterative mapping loop (paper Fig. 3).

    II = MII
    loop:
        KMS  <- fold mobility schedule by II
        CNF  <- C1 & C2 & C3 over the KMS
        SAT? -> register allocation -> success
        UNSAT / regalloc failure -> II += 1

Beyond-paper option (--routing): the paper's stated limitation is that no
routing nodes are inserted (§V, sha on 5x5: SoA reaches II=2 with a route
node, SAT-MapIt only II=3). With ``routing=True`` the mapper, before
conceding an II, retries with pass-through ``route`` nodes spliced into the
highest-fanout edges — recovering exactly that case family.
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .cgra import CGRA
from .dfg import DFG
from .encode import EncoderSession
from .regalloc import RegAllocResult, allocate
from .sat import SAT, UNSAT, solve
from .schedule import Infeasible, min_ii
from .simulator import verify_mapping


@dataclass
class MapperConfig:
    solver: str = "auto"          # auto | z3 | cdcl | walksat | portfolio
    amo: str = "pairwise"         # paper's encoding; "sequential" = Sinz
    max_ii: Optional[int] = None  # default: MII + 16
    routing: bool = False
    max_route_nodes: int = 3
    timeout_s: float = 4000.0     # paper's experiment timeout
    verify_iters: int = 6
    seed: int = 0
    # beyond-paper: seed CDCL phase saving from a (possibly partial)
    # heuristic placement at the same II — guides the search toward
    # structured assignments. CDCL backend only.
    warm_start: bool = False
    # assumption-based incremental core: one persistent layered formula +
    # live solver across the whole II sweep (learned-clause retention,
    # WalkSAT warm starts). False = the cold encode+solve-per-II reference
    # path (the paper-faithful Fig. 3 loop).
    incremental: bool = True
    # learnt-clause database cap for the persistent CDCL (None = keep all;
    # the mapping service sets a bound so long-lived sessions stay small)
    max_learnt: Optional[int] = None
    # sweep-only: race a second cold CDCL per candidate, started from the
    # *opposite* saved phases of the persistent session leg; whichever leg
    # delivers first decides the II (IIAttempt.via == "cdcl-flip" when the
    # flipped racer wins). CDCL sessions only; staged like the WalkSAT
    # racer so easy windows never pay for it.
    race_flip: bool = True
    # learned II guidance (repro.core.guide): a registered guide name or
    # an .npz checkpoint path. Sweep-only and *sound* — the prediction
    # chooses window extents (how many candidate IIs encode/race per
    # round), never which IIs are tried: the guided final II is identical
    # to the unguided one on every input. A string (not a guide object) so
    # configs stay hashable for the service cache and the store key.
    guide: Optional[str] = None


@dataclass
class IIAttempt:
    ii: int
    n_vars: int
    n_clauses: int
    status: str
    solve_time: float
    encode_time: float
    route_nodes: int = 0
    regalloc_ok: Optional[bool] = None
    # incremental-core reuse statistics (None on the cold path)
    via: str = ""                            # backend/leg that decided this II
    #   via == "cdcl-flip": the sweep's second racing solver (cold CDCL
    #   started from the opposite saved phases) beat the persistent
    #   session leg to this II's verdict
    #   via == "core": this II was *pruned* — a failed-assumption core
    #   recorded earlier on the same session already refutes it, so the
    #   UNSAT status is replayed without a solve (solve_time == 0)
    learned_retained: Optional[int] = None   # clauses carried into the solve
    conflicts: Optional[int] = None          # conflicts spent on this II
    warm_hamming: Optional[int] = None       # walksat init vs final model
    evicted: Optional[int] = None            # learnt clauses evicted so far
    # the complete solve that decided this II was seeded with a racer
    # near-miss as CDCL saved phases (None on paths without the session)
    phase_hinted: Optional[bool] = None


@dataclass
class MappingResult:
    success: bool
    ii: Optional[int] = None
    placement: Dict[int, Tuple[int, int, int]] = field(default_factory=dict)
    regalloc: Optional[RegAllocResult] = None
    dfg: Optional[DFG] = None          # final DFG (may contain route nodes)
    cgra: Optional[CGRA] = None
    attempts: List[IIAttempt] = field(default_factory=list)
    total_time: float = 0.0
    mii: int = 0
    timed_out: bool = False
    # structural-infeasibility verdict (e.g. an op class with zero capable
    # PEs): the human-readable reason, set instead of running a doomed II
    # sweep. None for every feasible request.
    infeasible: Optional[str] = None
    # per-request reuse statistics when the request was served by a
    # MappingService (repro.core.service.RequestStats); None otherwise
    service: Optional[object] = None
    # structured, machine-readable warnings (each {"kind": ..., ...}):
    # e.g. routing retries silently forcing the sequential engine. Read
    # with getattr(res, "warnings", []) when results may come from old
    # pickled store records that predate the field.
    warnings: List[Dict] = field(default_factory=list)
    # what the learned guide (cfg.guide) predicted and how the sweep used
    # it ({"guide", "offset", "order", "hopeless", "used"}); None when the
    # request ran unguided
    guidance: Optional[Dict] = None

    @property
    def n_route_nodes(self) -> int:
        return 0 if self.dfg is None else sum(
            1 for nd in self.dfg.nodes.values() if nd.op == "route")


def _try_ii(dfg: DFG, cgra: CGRA, ii: int, cfg: MapperConfig,
            deadline: float, attempts: List[IIAttempt], route_nodes: int = 0,
            sess=None,
            ) -> Optional[Tuple[Dict[int, Tuple[int, int, int]], RegAllocResult]]:
    """One Fig. 3 iteration. With ``sess`` (a persistent
    ``repro.core.sat.portfolio.SolverSession``) the II is decided by an
    assumption solve on the session's one live formula/solver; without it,
    a fresh CNF is encoded and solved cold (the reference path)."""
    if sess is not None:
        t0 = time.time()
        sess.ensure_ii(ii)
        t_enc = time.time() - t0
        st = sess.stats_for(ii)
        t0 = time.time()
        hint = None
        if cfg.warm_start and sess.complete_method == "cdcl":
            hint = _heuristic_phase_hint(
                dfg, cgra, _session_var_of(sess, ii), st["vars"], ii,
                cfg.seed)
        status, model, stats = sess.solve_ii(ii, phase_hint=hint)
        att = IIAttempt(ii=ii, n_vars=st["vars"], n_clauses=st["clauses"],
                        status=status, solve_time=time.time() - t0,
                        encode_time=t_enc, route_nodes=route_nodes,
                        via=stats.via,
                        learned_retained=stats.learned_retained,
                        conflicts=stats.conflicts,
                        warm_hamming=stats.warm_hamming,
                        evicted=stats.evicted,
                        phase_hinted=stats.phase_hinted)
        attempts.append(att)
        if status != SAT:
            return None
        placement = sess.enc.decode(ii, model)
    else:
        t0 = time.time()
        session = EncoderSession(dfg, cgra, cfg.amo)
        enc = session.encode(ii)
        t_enc = time.time() - t0
        t0 = time.time()
        hint = None
        if cfg.warm_start and cfg.solver == "cdcl":
            hint = _heuristic_phase_hint(dfg, cgra, enc.var_of.get,
                                         enc.cnf.n_vars, ii, cfg.seed)
        status, model = solve(enc.cnf, cfg.solver, seed=cfg.seed,
                              phase_hint=hint)
        att = IIAttempt(ii=ii, n_vars=enc.stats["vars"],
                        n_clauses=enc.stats["clauses"], status=status,
                        solve_time=time.time() - t0, encode_time=t_enc,
                        route_nodes=route_nodes)
        attempts.append(att)
        if status != SAT:
            return None
        placement = enc.decode(model)
    ra = allocate(dfg, cgra, placement, ii)
    att.regalloc_ok = ra.ok
    if not ra.ok:
        return None
    return placement, ra


def note_pruned_ii(sess, ii: int, attempts: List[IIAttempt],
                   route_nodes: int = 0) -> None:
    """Replay an UNSAT verdict for ``ii`` from the session's recorded
    failed-assumption cores — no encode, no solve. Shared by the
    sequential loop and the sweep engine (both count it as a pruned II)."""
    inc = sess.enc.inc
    if inc.has_layer(ii):
        st = sess.stats_for(ii)
        n_vars, n_clauses = st["vars"], st["clauses"]
    else:   # all_unsat latched before this layer was ever encoded
        n_vars, n_clauses = inc.n_vars, inc.n_clauses
    sess.pruned_total += 1
    attempts.append(IIAttempt(
        ii=ii, n_vars=n_vars, n_clauses=n_clauses, status=UNSAT,
        solve_time=0.0, encode_time=0.0, route_nodes=route_nodes,
        via="core"))


def _session_var_of(sess, ii: int):
    """(n, p, c, it) -> var lookup over a SolverSession's shared layout."""
    var_of_t = sess.enc.session._ensure_layout().var_of_t
    return lambda key: var_of_t.get((key[0], key[1], key[3] * ii + key[2]))


def _heuristic_phase_hint(dfg: DFG, cgra: CGRA, var_lookup, n_vars: int,
                          ii: int, seed: int) -> Optional[list]:
    """Phase-saving seed for CDCL from one heuristic placement attempt at
    the same II (partial placements still help: unplaced nodes keep the
    default phase). ``var_lookup((n, p, c, it)) -> var or None`` abstracts
    over cold encodings and the incremental session's shared layout."""
    import random

    from .baseline import _attempt
    placement = _attempt(dfg, cgra, ii, random.Random(seed), max_ejects=50)
    if placement is None:
        return None
    hint = [False] * n_vars
    for n, (p, c, it) in placement.items():
        var = var_lookup((n, p, c, it))
        if var is not None:
            hint[var - 1] = True
    return hint


def _insert_route(dfg: DFG, edge: Tuple[int, int, int]) -> DFG:
    """Splice a route (pass-through) node into edge (s, d, delta)."""
    s, d, delta = edge
    g = copy.deepcopy(dfg)
    r = g.add("route", [(s, 0)], name=f"rt{s}_{d}")
    node = g.nodes[d]
    new_ins = []
    replaced = False
    for src, dist in node.ins:
        if not replaced and src == s and dist == delta:
            new_ins.append((r, delta))
            replaced = True
        else:
            new_ins.append((src, dist))
    node.ins = tuple(new_ins)
    g.touch()
    return g


def _route_candidates(dfg: DFG) -> List[Tuple[int, int, int]]:
    """Edges ranked by how hard they make placement: high-fanout sources
    first (all consumers must crowd around one PE)."""
    fanout: Dict[int, int] = {}
    for s, d, delta in dfg.edges():
        fanout[s] = fanout.get(s, 0) + 1
    edges = [e for e in dfg.edges() if fanout[e[0]] >= 2]
    edges.sort(key=lambda e: -fanout[e[0]])
    return edges


def map_loop(dfg: DFG, cgra: CGRA, cfg: MapperConfig | None = None,
             sweep_width: int = 1, service=None,
             session=None) -> MappingResult:
    """Find the minimal feasible II.

    ``sweep_width=1`` is the paper-faithful sequential reference (this
    function's body). ``sweep_width>1`` delegates to the parallel II-sweep
    engine (``repro.core.sweep``), which encodes a window of candidate IIs
    through one shared EncoderSession and solves them concurrently —
    returning the same II as the sequential path. Routing retries
    (``cfg.routing``) are sequential-only and force ``sweep_width=1``.

    ``service`` (a ``repro.core.service.MappingService``) routes the
    request through the long-lived solver pool + mapping cache; ``None``
    — the default — preserves the standalone behaviour. ``session``
    injects an existing warm ``SolverSession`` whose formula matches this
    (dfg, cgra, amo) shape — the service uses it to share one persistent
    solver across requests; IIs the session has already refuted via a
    failed-assumption core are skipped without a solve (via="core"
    attempts).
    """
    cfg = cfg or MapperConfig()
    if service is not None:
        return service.map(dfg, cgra, cfg, sweep_width=sweep_width)
    if sweep_width > 1 and not cfg.routing:
        from .sweep import map_sweep   # local import: sweep imports us
        return map_sweep(dfg, cgra, cfg, sweep_width=sweep_width,
                         session=session)
    warnings: List[Dict] = []
    if sweep_width > 1 and cfg.routing:
        # routing retries splice route nodes into the DFG mid-II, which
        # serialises the search — the parallel sweep cannot honour them.
        # This used to silently downgrade to the sequential engine; keep
        # the (correct) downgrade but say so in the result.
        warnings.append({
            "kind": "routing_forces_sequential",
            "requested_sweep_width": sweep_width,
            "effective_sweep_width": 1,
            "detail": "cfg.routing=True is sequential-only; the request "
                      "ran the Fig. 3 loop instead of the parallel sweep",
        })
    dfg.validate()
    t_start = time.time()
    deadline = t_start + cfg.timeout_s
    try:
        mii = min_ii(dfg, cgra)
    except Infeasible as e:
        # structural infeasibility (op class with zero capable PEs): a
        # structured verdict instead of a 17-attempt doomed sweep
        return MappingResult(success=False, cgra=cgra, infeasible=str(e),
                             total_time=time.time() - t_start,
                             warnings=warnings)
    max_ii = cfg.max_ii if cfg.max_ii is not None else mii + 16
    res = MappingResult(success=False, mii=mii, cgra=cgra,
                        warnings=warnings)

    # the persistent incremental core: one layered formula + live solver
    # for the whole loop. Routing retries splice nodes into the DFG (a
    # different formula), so those attempts always take the cold path.
    sess = session
    if sess is None and cfg.incremental:
        from .sat.portfolio import SolverSession
        sess = SolverSession(EncoderSession(dfg, cgra, cfg.amo),
                             method=cfg.solver, seed=cfg.seed,
                             max_learnt=cfg.max_learnt)

    for ii in range(mii, max_ii + 1):
        if time.time() > deadline:
            res.timed_out = True
            break
        if sess is not None and sess.is_proven_unsat(ii):
            # a recorded failed-assumption core already refutes this II on
            # this session's formula: replay UNSAT without a solve. The
            # routing branch below still runs — route nodes change the
            # DFG, so a pruned plain II may yet map with routing.
            note_pruned_ii(sess, ii, res.attempts)
            got = None
            if sess.all_unsat and not cfg.routing:
                break   # empty core: every candidate II is refuted
        else:
            got = _try_ii(dfg, cgra, ii, cfg, deadline, res.attempts,
                          sess=sess)
        cur_dfg = dfg
        if got is None and cfg.routing:
            # beyond-paper: retry this II with routing nodes spliced in
            g = dfg
            for k, edge in enumerate(_route_candidates(dfg)):
                if k >= cfg.max_route_nodes or time.time() > deadline:
                    break
                g = _insert_route(g, edge)
                got = _try_ii(g, cgra, ii, cfg, deadline, res.attempts,
                              route_nodes=k + 1)
                if got is not None:
                    cur_dfg = g
                    break
        if got is not None:
            placement, ra = got
            chk = verify_mapping(
                cur_dfg, cgra, placement, ii, n_iters=cfg.verify_iters,
                node_subset=set(dfg.nodes) if cur_dfg is not dfg else None)
            if not chk.ok:
                raise AssertionError(
                    f"mapper produced an invalid mapping at II={ii}: "
                    f"{chk.errors[:3]}")
            res.success = True
            res.ii = ii
            res.placement = placement
            res.regalloc = ra
            res.dfg = cur_dfg
            break

    res.total_time = time.time() - t_start
    return res

"""Persistent mapping service: solver pool + canonical-DFG mapping cache.

The Fig. 3 loop made incremental *within* one kernel's II sweep (PR 2)
still rebuilds everything — layout, layered formula, live solver — on
every ``map_loop``/``run_suite``/``map_cgra`` call. A long-lived serving
process does better: repeated and structurally-similar requests should
skip encode+solve entirely or start warm. :class:`MappingService` is that
process-lifetime owner:

  * **mapping cache** — requests are keyed by the canonical DFG signature
    (full structural identity: ops, immediates, edges) plus the CGRA
    topology signature and the mapper config; an identical request
    returns the cached :class:`~repro.core.mapper.MappingResult` without
    touching a solver (``via="cache"``).
  * **solver pool** — cache misses are routed to a pooled
    :class:`~repro.core.sat.portfolio.SolverSession` keyed by
    (topology signature, DFG *shape class*): the shape class is exactly
    what the SAT encoding depends on (per-node mem-capability and the
    edge/distance structure — ops and immediates are irrelevant to the
    clauses), so any two requests in one class share a single persistent
    layered formula and live solver. A reused session starts with every
    learnt clause, variable activity, saved phase, and warm-start
    assignment its earlier requests derived — and with their
    failed-assumption cores, so the II sweep *skips* IIs the session has
    already refuted (``via="core"`` attempts, no solve).
  * **bounded memory** — pool sessions cap the persistent CDCL's learnt
    database (``max_learnt``, see ``CDCLSolver._reduce_db``) and the pool
    and cache are LRU-bounded, so a service process survives thousands of
    sweeps without unbounded growth.

``map_loop(..., service=svc)``, ``map_sweep(..., service=svc)`` and
``run_suite(..., service=svc)`` all route here; ``service=None`` (the
default everywhere) preserves the standalone one-shot behaviour.
``get_service()`` returns a process-wide default instance (used by
``launch/map_cgra.py --service`` and ``launch/serve.py``).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from copy import copy
from dataclasses import astuple, dataclass, field
from typing import Dict, Hashable, Optional, Tuple

from .cgra import CGRA
from .dfg import DFG
from .encode import EncoderSession
from .mapper import MapperConfig, MappingResult, map_loop
from .sat.portfolio import SolverSession
from .store import MappingStore

# ----------------------------------------------------------------- keys


def topology_signature(cgra) -> Tuple:
    """Everything the encoding, register allocator, and simulator read off
    the fabric: geometry, inter-PE reachability, per-PE capability sets,
    and per-PE register counts. Both the legacy :class:`CGRA` adapter and
    the declarative :class:`repro.core.arch.ArchSpec` expose it as
    ``signature()`` — equivalent homogeneous fabrics share one signature
    (and therefore one pooled session) regardless of front-end class."""
    return cgra.signature()


def _memo_sig(dfg: DFG, key: Tuple, compute):
    """Memoize a signature on the DFG instance (``DFG._sig_cache``, cleared
    by ``add``/``touch``) — both signatures walk every node and edge, and
    under serving load they dominate the cache-hit path otherwise."""
    cache = getattr(dfg, "_sig_cache", None)
    if cache is None:
        return compute()
    sig = cache.get(key)
    if sig is None:
        sig = cache[key] = compute()
    return sig


def shape_signature(dfg: DFG, arch=None) -> Tuple:
    """The DFG *shape class*: exactly what the SAT encoding depends on.

    The clause families (C1/C2/C3) read node count, per-node allowed-PE
    sets, and the edge/distance structure (ASAP/ALAP windows and MII
    derive from these) — never the opcodes or immediates themselves. Two
    DFGs with equal shape signatures therefore produce *identical* CNFs
    under one variable numbering, so they can share a pooled
    ``SolverSession`` (learnt clauses, phases, warm starts, and
    proven-UNSAT cores all transfer soundly).

    With ``arch`` the per-node component is the node's actual allowed-PE
    tuple on that fabric plus its op *latency* there (op-class capability
    and timing aware — on a heterogeneous fabric an ``add``-shaped and a
    ``mul``-shaped DFG must *not* share a session, and on a fabric with
    2-cycle multipliers two DFGs that differ only in which nodes are muls
    produce different C3 windows even when every PE runs every class);
    without it, the homogeneous-fabric abstraction (memory ops are the
    only capability split, all latencies 1) is used."""
    def compute() -> Tuple:
        if arch is None:
            nodes = tuple(
                (nid, dfg.nodes[nid].is_mem, len(dfg.nodes[nid].ins))
                for nid in sorted(dfg.nodes))
        else:
            lat_of = getattr(arch, "lat_of", lambda op: 1)
            nodes = tuple(
                (nid, arch.pes_for(dfg.nodes[nid].op),
                 lat_of(dfg.nodes[nid].op), len(dfg.nodes[nid].ins))
                for nid in sorted(dfg.nodes))
        edges = tuple(sorted(dfg.edges()))
        return (len(dfg.nodes), nodes, edges)

    key = ("shape", None if arch is None else arch.signature())
    return _memo_sig(dfg, key, compute)


def dfg_signature(dfg: DFG) -> Tuple:
    """Full canonical identity of the mapping *request*: shape plus ops
    and immediates (the simulator oracle and therefore the verified
    result depend on them). Node names are display-only and excluded, so
    re-traced copies of the same loop body hit the cache."""
    def compute() -> Tuple:
        nodes = tuple((nid, dfg.nodes[nid].op, dfg.nodes[nid].imm,
                       dfg.nodes[nid].ins) for nid in sorted(dfg.nodes))
        return (nodes,)
    return _memo_sig(dfg, ("dfg",), compute)


def near_shape_key(shape_sig: Tuple, delta: int = 1) -> Tuple:
    """Relax a shape signature to its (shape, delta) lattice bucket.

    The exact shape class demands identical per-node windows and edges —
    sound for *session sharing* (same CNF), but needlessly strict for
    *warm-start transfer*: a kernel variant with one rewired edge explores
    an almost-identical placement space. The near key keeps what the
    search landscape is made of — node/edge counts (quantised by
    ``delta+1``), the multiset of node kinds (capability/latency/indegree,
    node ids dropped), and the set of loop-carried distances — and drops
    the exact wiring. Two shapes in one bucket get *heuristic* state only
    (a donor session's best assignment as WalkSAT/phase seed via
    ``SolverSession.adopt_warm``); clauses, learnt facts, and UNSAT cores
    never cross buckets, so admission is always sound."""
    n, nodes, edges = shape_sig
    q = max(1, int(delta) + 1)
    kinds = tuple(sorted(set(node[1:] for node in nodes)))
    dists = tuple(sorted(set(e[2] for e in edges)))
    return (n // q, len(edges) // q, kinds, dists)


# ---------------------------------------------------------------- stats


@dataclass
class RequestStats:
    """Per-request reuse report, attached to ``MappingResult.service``."""
    via: str                       # "cache" | "disk" | "warm" | "cold"
    cache_hit: bool = False
    session_reused: bool = False
    near_seeded: bool = False      # fresh session warm-seeded from a
    #                                near-shape neighbour's best assignment
    iis_pruned: int = 0            # IIs skipped via failed-assumption cores
    clauses_evicted: int = 0       # learnt clauses evicted during this request
    learned_retained: int = 0      # learnt DB size after the request
    near_misses: int = 0           # racer near-misses banked as warm state
    phase_hints: int = 0           # CDCL solves seeded from that warm state
    request_time: float = 0.0


@dataclass
class ServiceStats:
    """Cumulative service counters (monotone over the process lifetime)."""
    requests: int = 0
    cache_hits: int = 0
    disk_hits: int = 0             # served from the shared disk store
    disk_writes: int = 0           # results persisted to the disk store
    near_hits: int = 0             # fresh sessions seeded from a near-shape
    #                                neighbour (the lattice admission rate)
    cores_preloaded: int = 0       # proven-UNSAT IIs adopted from the store
    cores_persisted: int = 0       # newly proven IIs written to the store
    sessions_created: int = 0
    sessions_reused: int = 0
    iis_pruned: int = 0
    clauses_evicted: int = 0
    near_misses: int = 0
    phase_hints: int = 0
    pack_reuses: int = 0           # walksat dense-pack cache hits
    pack_evictions: int = 0        # LRU drops from per-session pack caches
    cache_evictions: int = 0
    session_evictions: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dict(self.__dict__)


@dataclass
class _PoolEntry:
    session: SolverSession
    lock: threading.Lock = field(default_factory=threading.Lock)
    requests: int = 0
    near_seeded: bool = False      # created warm off a lattice neighbour


# -------------------------------------------------------------- service


class MappingService:
    """Long-lived mapping front end: cache first, warm pooled session
    second, cold session only for a topology/shape never seen before.

    Thread-safe: the pool/cache dictionaries are guarded by one service
    lock, and each pooled session carries its own lock so concurrent
    requests for *different* shapes solve in parallel while two requests
    for the same shape serialise on their shared solver (its trail and
    learnt database are single-threaded state).
    """

    def __init__(self, max_sessions: int = 64, cache_size: int = 512,
                 max_learnt: Optional[int] = 100_000,
                 store: Optional[MappingStore] = None,
                 near_delta: int = 0):
        self.max_sessions = max_sessions
        self.cache_size = cache_size
        self.max_learnt = max_learnt
        # shared persistence (tentpole L1): results and proven-UNSAT cores
        # survive the process and are visible to sibling worker processes
        self.store = store
        # near-shape admission (tentpole L2): 0 disables; k>0 buckets shape
        # classes on the (shape, delta=k) lattice for warm-start transfer
        self.near_delta = near_delta
        self._pool: "OrderedDict[Hashable, _PoolEntry]" = OrderedDict()
        self._cache: "OrderedDict[Hashable, MappingResult]" = OrderedDict()
        # near-shape bucket -> exact session key of the latest session in
        # that bucket (the warm-state donor for the next new neighbour)
        self._near_index: Dict[Hashable, Hashable] = {}
        # RLock, not Lock: the async front door fans many threads into one
        # service, and the cache-insert path re-enters via properties
        self._lock = threading.RLock()
        self.stats = ServiceStats()

    # ------------------------------------------------------------ internals
    def _session_for(self, dfg: DFG, cgra: CGRA, cfg: MapperConfig,
                     ) -> Tuple[_PoolEntry, bool, Hashable]:
        """Get-or-create the pooled session for this request's
        (topology, shape class, solver-relevant config) key. The resolved
        learnt-DB cap is part of the key: a request that asks for a
        different memory bound must not silently inherit (or impose) a
        pooled session's cap."""
        cap = cfg.max_learnt if cfg.max_learnt is not None \
            else self.max_learnt
        shape = shape_signature(dfg, cgra)
        key = (topology_signature(cgra), shape,
               cfg.amo, cfg.solver, cfg.seed, cap)
        with self._lock:
            entry = self._pool.get(key)
            if entry is not None:
                self._pool.move_to_end(key)
                self.stats.sessions_reused += 1
                return entry, True, key
            entry = _PoolEntry(SolverSession(
                EncoderSession(dfg, cgra, cfg.amo), method=cfg.solver,
                seed=cfg.seed, max_learnt=cap))
            if self.store is not None:
                # adopt IIs any process ever proved UNSAT for this exact
                # session key — yesterday's lower bounds prune today's
                # sweep before the first solve
                for ii, core in self.store.cores_for(key).items():
                    entry.session.note_core(ii, list(core))
                    self.stats.cores_preloaded += 1
            if self.near_delta > 0:
                # heuristic-only warm transfer inside the lattice bucket
                nkey = key[:1] + (near_shape_key(shape, self.near_delta),) \
                    + key[2:]
                donor_key = self._near_index.get(nkey)
                donor = self._pool.get(donor_key) \
                    if donor_key is not None else None
                if donor is not None:
                    warm = donor.session.warm_snapshot()
                    if warm is not None:
                        entry.session.adopt_warm(warm)
                        entry.near_seeded = True
                        self.stats.near_hits += 1
                self._near_index[nkey] = key
            self._pool[key] = entry
            self.stats.sessions_created += 1
            while len(self._pool) > self.max_sessions:
                self._pool.popitem(last=False)
                self.stats.session_evictions += 1
            return entry, False, key

    def _cache_key(self, dfg: DFG, cgra: CGRA, cfg: MapperConfig,
                   sweep_width: int) -> Hashable:
        return (dfg_signature(dfg), topology_signature(cgra),
                astuple(cfg), sweep_width)

    # --------------------------------------------------------------- API
    def map(self, dfg: DFG, cgra: CGRA, cfg: Optional[MapperConfig] = None,
            sweep_width: int = 1, use_cache: bool = True) -> MappingResult:
        """Serve one mapping request.

        Identical requests (same canonical DFG, topology, config) return
        the cached result; same-*shape* requests reuse the pooled warm
        session (core-pruned IIs, retained learnt clauses); everything
        else runs a cold session that immediately joins the pool.
        ``use_cache=False`` forces a solve while still using the pool —
        the warm-vs-cold comparison knob for benchmarks. The returned
        result carries a :class:`RequestStats` in ``.service``; cached
        results are shallow copies sharing placement/attempt objects, so
        treat them as read-only.
        """
        cfg = cfg or MapperConfig()
        t0 = time.time()
        key = self._cache_key(dfg, cgra, cfg, sweep_width)
        with self._lock:
            self.stats.requests += 1
            if use_cache and key in self._cache:
                self._cache.move_to_end(key)
                self.stats.cache_hits += 1
                hit = copy(self._cache[key])
                hit.service = RequestStats(
                    via="cache", cache_hit=True,
                    request_time=time.time() - t0)
                return hit

        if use_cache and self.store is not None:
            disk = self.store.get_mapping(key)
            if isinstance(disk, MappingResult):
                # cold process, warm store: promote into the memory cache
                # so the next identical request never touches the disk
                disk.service = RequestStats(
                    via="disk", cache_hit=True,
                    request_time=time.time() - t0)
                with self._lock:
                    self.stats.disk_hits += 1
                    self._cache[key] = disk
                    self._cache.move_to_end(key)
                    while len(self._cache) > self.cache_size:
                        self._cache.popitem(last=False)
                        self.stats.cache_evictions += 1
                return copy(disk)

        if not cfg.incremental:
            # cold escape hatch: the paper-faithful per-II reference path,
            # no session pooling (still cached — determinism is cheap)
            res = map_loop(dfg, cgra, cfg, sweep_width=sweep_width)
            res.service = RequestStats(via="cold",
                                       request_time=time.time() - t0)
        else:
            entry, reused, skey = self._session_for(dfg, cgra, cfg)
            with entry.lock:
                sess = entry.session
                entry.requests += 1
                pruned0 = sess.pruned_total
                evicted0 = sess.clauses_evicted
                nm0 = sess.near_miss_updates
                ph0 = sess.phase_hints_served
                pr0 = sess.pack_reuses
                pe0 = sess.pack_evictions
                cores0 = set(sess.proven_unsat)
                res = map_loop(dfg, cgra, cfg, sweep_width=sweep_width,
                               session=sess)
                res.service = RequestStats(
                    via="warm" if reused else "cold",
                    session_reused=reused,
                    near_seeded=entry.near_seeded and not reused,
                    iis_pruned=sess.pruned_total - pruned0,
                    clauses_evicted=sess.clauses_evicted - evicted0,
                    learned_retained=sess.learnt_db_size,
                    near_misses=sess.near_miss_updates - nm0,
                    phase_hints=sess.phase_hints_served - ph0,
                    request_time=time.time() - t0)
                new_cores = {ii: sess.proven_unsat[ii]
                             for ii in set(sess.proven_unsat) - cores0}
                pack_reuses = sess.pack_reuses - pr0
                pack_evictions = sess.pack_evictions - pe0
                witnesses = {}
                if self.store is not None:
                    for ii in new_cores:
                        try:
                            witnesses[ii] = sess.project(ii)
                        except Exception:
                            witnesses[ii] = None
            if self.store is not None:
                # persist this sweep's freshly proven-UNSAT IIs with their
                # refuted projection as a re-solvable witness — tomorrow's
                # cold sessions (any process) preload them as lower bounds
                for ii, core in sorted(new_cores.items()):
                    if self.store.put_core(skey, ii, core,
                                           witness=witnesses.get(ii)):
                        with self._lock:
                            self.stats.cores_persisted += 1
            with self._lock:
                self.stats.iis_pruned += res.service.iis_pruned
                self.stats.clauses_evicted += res.service.clauses_evicted
                self.stats.near_misses += res.service.near_misses
                self.stats.phase_hints += res.service.phase_hints
                self.stats.pack_reuses += pack_reuses
                self.stats.pack_evictions += pack_evictions

        if not res.timed_out:
            # a timed-out verdict reflects this request's budget, not the
            # problem — let an identical later request retry with its own
            with self._lock:
                self._cache[key] = res
                self._cache.move_to_end(key)
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
                    self.stats.cache_evictions += 1
            if self.store is not None and self.store.put_mapping(key, res):
                with self._lock:
                    self.stats.disk_writes += 1
        return res

    # ---------------------------------------------------------- inspection
    @property
    def n_sessions(self) -> int:
        with self._lock:
            return len(self._pool)

    @property
    def n_cached(self) -> int:
        with self._lock:
            return len(self._cache)

    def describe(self) -> Dict[str, int]:
        d = self.stats.snapshot()
        d["sessions"] = self.n_sessions
        d["cached_results"] = self.n_cached
        if self.store is not None:
            d["store"] = self.store.describe()
        return d


# ------------------------------------------------- process-wide default

_DEFAULT: Optional[MappingService] = None
_DEFAULT_LOCK = threading.Lock()


def get_service() -> MappingService:
    """The process-wide default service (launch drivers share it so every
    report/request in one process benefits from the same warm pool)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MappingService()
        return _DEFAULT


def reset_service() -> None:
    """Drop the process-wide default (tests)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = None

"""jaxpr -> DFG frontend.

The paper extracts loop DFGs from LLVM IR via a custom pass. The JAX-native
equivalent: trace a scalar loop body written in JAX, convert its jaxpr to a
DFG. Loop-carried state becomes distance-1 back-edges; the induction
variable is the first argument.

    def body(i, acc):
        x = i * 3 + acc
        return (x ^ (x >> 2),)

    dfg = trace_loop_body(body, n_carry=1)

The resulting DFG is executable (DFG.execute), so a mapping produced by
SAT-MapIt for it is validated against the traced function itself. Memory
ops are modelled as extra per-iteration inputs/outputs (`loads=k` appends k
load nodes passed after the carries; returned extra values become stores).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .dfg import DFG

_PRIM_MAP = {
    "add": "add", "sub": "sub", "mul": "mul",
    "max": "max", "min": "min",
    "and": "and", "or": "or", "xor": "xor",
    "shift_left": "shl",
    "shift_right_logical": "shr",
    "shift_right_arithmetic": "shr",
    "rem": "rem", "div": "div",
    "lt": "lt", "le": "le", "eq": "eq", "ne": "ne",
    "gt": "lt", "ge": "le",  # operands swapped below
}
_ALIAS_PRIMS = {"convert_element_type", "stop_gradient", "copy",
                "broadcast_in_dim", "squeeze", "reshape"}


def trace_loop_body(fn: Callable, n_carry: int = 0, loads: int = 0,
                    name: str = "jax_loop") -> Tuple[DFG, Dict[int, int]]:
    """Trace ``fn(i, *carries, *loaded)`` into a DFG.

    Returns (dfg, carry_map) where carry_map maps carry index -> node id of
    the value that feeds the next iteration (useful for simulation init).
    """
    args = [jnp.int32(0)] * (1 + n_carry + loads)
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr
    g = DFG(name)
    env: Dict[object, int] = {}
    consts: Dict[int, int] = {}

    def const_node(val: int) -> int:
        v = int(val)
        if v not in consts:
            consts[v] = g.add("const", imm=v, name=f"c{v}")
        return consts[v]

    # inputs: induction variable, carried values, loads
    iv = g.add("iv", name="i")
    env[id(jaxpr.invars[0])] = iv
    carry_vars = jaxpr.invars[1:1 + n_carry]
    pending_carry_uses: List[Tuple[int, int, int]] = []  # (node, slot, carry_ix)
    for ci, var in enumerate(carry_vars):
        env[id(var)] = -(ci + 1)  # sentinel, patched after outputs known
    for li, var in enumerate(jaxpr.invars[1 + n_carry:]):
        env[id(var)] = g.add("load", [(iv, 0)], imm=100 * (li + 1),
                             name=f"ld{li}")

    def read(atom) -> int:
        if hasattr(atom, "val"):  # Literal
            return const_node(atom.val)
        return env[id(atom)]

    def process(eqns) -> None:
        for eqn in eqns:
            prim = eqn.primitive.name
            if prim in _ALIAS_PRIMS:
                env[id(eqn.outvars[0])] = read(eqn.invars[0])
                continue
            if prim in ("pjit", "jit", "closed_call", "custom_jvp_call",
                        "custom_vjp_call"):
                inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                if getattr(inner, "consts", None):
                    for cv, cval in zip(ij.constvars, inner.consts):
                        env[id(cv)] = const_node(int(cval))
                for iv, atom in zip(ij.invars, eqn.invars):
                    env[id(iv)] = read(atom)
                process(ij.eqns)
                for ov, iov in zip(eqn.outvars, ij.outvars):
                    env[id(ov)] = read(iov)
                continue
            if prim == "select_n":
                # select_n(pred, case0, case1): pred==1 -> case1
                c, a0, a1 = (read(x) for x in eqn.invars)
                ins = [(c, 0), (a1, 0), (a0, 0)]
                nid = _add_patched(g, "select", ins, pending_carry_uses)
            elif prim in ("gt", "ge"):
                a, b = (read(x) for x in eqn.invars)
                nid = _add_patched(g, _PRIM_MAP[prim], [(b, 0), (a, 0)],
                                   pending_carry_uses)
            elif prim in _PRIM_MAP:
                ins = [(read(x), 0) for x in eqn.invars]
                nid = _add_patched(g, _PRIM_MAP[prim], ins,
                                   pending_carry_uses)
            elif prim == "integer_pow":
                a = read(eqn.invars[0])
                p = eqn.params["y"]
                nid = a
                for _ in range(p - 1):
                    nid = _add_patched(g, "mul", [(nid, 0), (a, 0)],
                                       pending_carry_uses)
            elif prim == "neg":
                nid = _add_patched(g, "neg", [(read(eqn.invars[0]), 0)],
                                   pending_carry_uses)
            elif prim == "not":
                nid = _add_patched(g, "not", [(read(eqn.invars[0]), 0)],
                                   pending_carry_uses)
            else:
                raise NotImplementedError(
                    f"primitive {prim!r} has no CGRA mapping (scalar loop "
                    f"bodies only; matmul-shaped compute is not a modulo-"
                    f"scheduling target)")
            env[id(eqn.outvars[0])] = nid

    process(jaxpr.eqns)

    # outputs: first n_carry are next-iteration carries, rest are stores
    out_nodes: List[int] = []
    for var in jaxpr.outvars:
        nid = read(var)
        out_nodes.append(nid)
    carry_map: Dict[int, int] = {}
    for ci in range(n_carry):
        src = out_nodes[ci]
        if src < 0:  # pass-through carry: route it
            src = g.add("route", [(iv, 0)], name=f"carry{ci}_rt")
        carry_map[ci] = src
    # patch carried uses with distance-1 back-edges
    for nid, slot, sentinel in pending_carry_uses:
        ci = -sentinel - 1
        ins = list(g.nodes[nid].ins)
        ins[slot] = (carry_map[ci], 1)
        g.nodes[nid].ins = tuple(ins)
        g.touch()
    # stores for non-carry outputs
    for si, nid in enumerate(out_nodes[n_carry:]):
        if nid < 0:
            nid = carry_map[-nid - 1]
        g.add("store", [(iv, 0), (nid, 0)], imm=1000 * (si + 1),
              name=f"st{si}")
    g.validate()
    return g, carry_map


def _add_patched(g: DFG, op: str, ins, pending) -> int:
    """g.add that tolerates carry sentinels (negative ids) in ins."""
    clean = []
    patches = []
    for slot, (src, dist) in enumerate(ins):
        if src < 0:
            patches.append((slot, src))
            clean.append((0, 0))  # temporary: node 0 always exists (iv)
        else:
            clean.append((src, dist))
    nid = g.add(op, clean)
    for slot, sentinel in patches:
        pending.append((nid, slot, sentinel))
    return nid

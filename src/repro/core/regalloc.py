"""Register allocation (paper §IV-D).

Post-SAT phase: for every PE, build the interference graph of the values
produced there and colour it with that PE's local registers (per-PE counts
via ``arch.regs(p)`` — heterogeneous fabrics give different PEs different
register files).

Lifetimes honour the fabric's per-op-class *latency* model: a value exists
from its producer's completion, t_n + lat(n), to its last consumption
(multi-cycle producers therefore lengthen downstream lifetimes relative to
the issue slot). Lifetimes are *cyclic* intervals on the II-cycle kernel
circle; the C3 timing window bounds every completion-relative lifetime by
II - 1, so a value never interferes with its own next-iteration instance.
With all latencies 1 every interval, bypass decision, and pressure count
below is identical to the original issue-based formulation.

Output-register bypass (the paper's Eq. 5 delivery mode): if every consumer
of a value reads it strictly before the next result lands on the producer
PE's output register, the value lives only in that output register and
needs no local register. The allocator models both modes and prefers
bypass — resolving the Eq. 4 / Eq. 5 disjunction that the SAT phase
leaves open.

Failure (any PE needs more colours than its register count) sends the
Fig. 3 loop to II+1.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .cgra import CGRA
from .dfg import DFG
from .schedule import node_latencies


@dataclass
class RegAllocResult:
    ok: bool
    # node -> register index on its producer PE (absent -> output-reg bypass)
    regs: Dict[int, int] = field(default_factory=dict)
    bypass: List[int] = field(default_factory=list)
    max_pressure: int = 0
    failed_pe: Optional[int] = None


def _lifetime(dfg: DFG, t: Dict[int, int], n: int, ii: int) -> int:
    """Cycles from production to last consumption (0 = no consumer)."""
    last = 0
    for s, d, delta in dfg.edges():
        if s == n:
            last = max(last, t[d] - t[n] + delta * ii)
    return last


def allocate(dfg: DFG, cgra: CGRA,
             placement: Dict[int, Tuple[int, int, int]], ii: int,
             ) -> RegAllocResult:
    t = {n: it * ii + c for n, (p, c, it) in placement.items()}
    lat = node_latencies(dfg, cgra)
    pe_of = {n: placement[n][0] for n in placement}
    # kernel-cycle occupancy per PE output register: results land at the
    # producer's *completion* cycle, issue + lat (== issue + 1 on the
    # paper's unit-latency fabric)
    writes: Dict[int, List[int]] = {}
    for n, (p, c, it) in placement.items():
        writes.setdefault(p, []).append((c + lat[n]) % ii)

    res = RegAllocResult(ok=True)
    for p in range(cgra.n_pes):
        mine = [n for n in placement if pe_of[n] == p]
        if not mine:
            continue
        wcycles = sorted(writes[p])
        intervals: Dict[int, Tuple[int, int]] = {}  # n -> (start mod II, len)
        for n in mine:
            life = _lifetime(dfg, t, n, ii)
            if life == 0:
                res.bypass.append(n)
                continue
            # completion-relative lifetime: the value exists from the
            # write at t_n + lat(n) through the last read (C3 bounds it
            # by II - 1, so it never meets its own next instance)
            life_w = max(life - lat[n], 0)
            w0 = (t[n] + lat[n]) % ii
            # gap until the next write on this PE's output register
            gap = ii  # producer itself re-writes II cycles later
            for k in range(1, ii):
                if (w0 + k) % ii in wcycles:
                    gap = k
                    break
            if life_w < gap:
                res.bypass.append(n)       # Eq. 5 delivery: output reg only
            else:
                # live [t_n+lat, t_n+life] on the kernel circle
                intervals[n] = (w0, life_w + 1)
        # cyclic-interval interference graph
        ns = list(intervals)
        adj = {n: set() for n in ns}
        for i in range(len(ns)):
            for j in range(i + 1, len(ns)):
                if _cyclic_overlap(intervals[ns[i]], intervals[ns[j]], ii):
                    adj[ns[i]].add(ns[j])
                    adj[ns[j]].add(ns[i])
        colours = _greedy_colour(ns, adj)
        pressure = max(colours.values(), default=-1) + 1
        res.max_pressure = max(res.max_pressure, pressure)
        if pressure > cgra.regs(p):
            return RegAllocResult(ok=False, max_pressure=pressure,
                                  failed_pe=p)
        res.regs.update(colours)
    return res


def _cyclic_overlap(a: Tuple[int, int], b: Tuple[int, int], ii: int) -> bool:
    """Do intervals [s, s+len) on the circle of size II overlap?"""
    (sa, la), (sb, lb) = a, b
    if la >= ii or lb >= ii:
        return True
    for base in (0,):  # unroll circle into two copies
        a0, a1 = sa, sa + la
        b0, b1 = sb, sb + lb
        for shift_a in (0, ii):
            for shift_b in (0, ii):
                lo = max(a0 + shift_a, b0 + shift_b)
                hi = min(a1 + shift_a, b1 + shift_b)
                if lo < hi:
                    return True
    return False


def _greedy_colour(ns: List[int], adj: Dict[int, set]) -> Dict[int, int]:
    """Smallest-last (degeneracy) ordering + greedy colouring."""
    order: List[int] = []
    deg = {n: len(adj[n]) for n in ns}
    alive = set(ns)
    while alive:
        n = min(alive, key=lambda x: (deg[x], x))
        order.append(n)
        alive.remove(n)
        for m in adj[n]:
            if m in alive:
                deg[m] -= 1
    colours: Dict[int, int] = {}
    for n in reversed(order):
        used = {colours[m] for m in adj[n] if m in colours}
        c = 0
        while c in used:
            c += 1
        colours[n] = c
    return colours

"""CNF containers + cardinality encodings on a flat clause arena.

Variables are positive ints (DIMACS convention); a literal is ±var. The
paper's C1 uses the naive pairwise at-most-one (its Eq. 1 ``M(n)`` set); we
also provide the Sinz sequential encoding as a beyond-paper option — it turns
O(k^2) binary clauses into O(k) ternary ones, which dominates encode time on
big KMS instances.

Clause storage is a :class:`ClauseArena`: one append-only int32 literal
buffer plus an int64 clause-offset index (CSR layout). Clause ``i`` is
``lits[offs[i]:offs[i+1]]``; insertion order is the clause order. The arena
is the single source of truth — the encoder extends it in bulk, the walksat
packer reshapes it without per-clause iteration, and the CDCL worker ships
it across the process pool as two numpy arrays. ``CNF.clauses`` stays
available as a list-of-tuples *view* so existing call sites (iteration,
slicing, membership, equality) keep working unchanged.

``IncrementalCNF`` is the layered container behind the assumption-based
solver core: a shared *base* layer of unguarded clauses plus named delta
layers whose clauses carry a fresh selector literal, so one persistent
formula covers every candidate II of a sweep and "try II=k" is an
assumption solve rather than a fresh encode.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass
from typing import Dict, Hashable, Iterable, Iterator, List, Optional, \
    Sequence, Tuple

import numpy as np


class EmptyClauseError(ValueError):
    """Raised when an empty clause reaches ``CNF.add(*lits)``.

    ``add`` is the literal-varargs fast path and cannot represent "the
    formula is trivially UNSAT" — that is ``add_clause([])``'s job, which
    also sets ``trivially_unsat`` so backends fail fast. A bare ``assert``
    here would be stripped under ``python -O`` and let the empty clause
    slip in silently, corrupting UNSAT detection (same failure mode as the
    ``NonModelError`` guard in the walksat layer).
    """


class ArenaFormatError(ValueError):
    """A serialised :class:`ClauseArena` blob failed validation (bad magic,
    truncation, CRC mismatch, or broken CSR invariants). The disk store
    treats this as "quarantine the record", never as a crash."""


class ClauseArena:
    """Append-only CSR clause store: int32 literals + int64 row offsets.

    Invariants:
      * ``offs[0] == 0`` and ``offs`` is non-decreasing with ``n + 1``
        live entries; clause ``i`` is ``lits[offs[i]:offs[i+1]]``.
      * rows are never mutated or removed once appended — growth is
        amortised-doubling realloc of the two buffers only, so trimmed
        views taken before an append remain valid snapshots.
    """

    __slots__ = ("_lits", "_offs", "_n", "_top")

    def __init__(self):
        self._lits = np.empty(64, dtype=np.int32)
        self._offs = np.zeros(17, dtype=np.int64)
        self._n = 0     # live clause count
        self._top = 0   # live literal count

    @classmethod
    def from_arrays(cls, lits: np.ndarray, offs: np.ndarray) -> "ClauseArena":
        """Adopt (copies of) a (lits, offs) CSR pair, e.g. from a pickle."""
        out = cls.__new__(cls)
        out._lits = np.ascontiguousarray(lits, dtype=np.int32).copy()
        offs = np.ascontiguousarray(offs, dtype=np.int64)
        out._offs = offs.copy()
        out._n = offs.size - 1
        out._top = int(offs[-1]) if offs.size else 0
        return out

    # ------------------------------------------------------------- growth
    def _reserve_lits(self, extra: int) -> None:
        need = self._top + extra
        if need > self._lits.size:
            new = np.empty(max(need, self._lits.size * 2), dtype=np.int32)
            new[:self._top] = self._lits[:self._top]
            self._lits = new

    def _reserve_rows(self, extra: int) -> None:
        need = self._n + 1 + extra
        if need > self._offs.size:
            new = np.empty(max(need, self._offs.size * 2), dtype=np.int64)
            new[:self._n + 1] = self._offs[:self._n + 1]
            self._offs = new

    # ------------------------------------------------------------- append
    def add(self, lits: Sequence[int]) -> None:
        """Append one clause (any sequence of ints, may be empty)."""
        k = len(lits)
        self._reserve_rows(1)
        self._reserve_lits(k)
        top = self._top
        self._lits[top:top + k] = lits
        self._top = top + k
        self._n += 1
        self._offs[self._n] = self._top

    def extend_flat(self, flat: np.ndarray, lens: np.ndarray) -> None:
        """Bulk-append: ``flat`` concatenates rows whose lengths are ``lens``."""
        k = int(lens.size)
        if k == 0:
            return
        total = int(flat.size)
        self._reserve_rows(k)
        self._reserve_lits(total)
        n, top = self._n, self._top
        self._lits[top:top + total] = flat
        self._offs[n + 1:n + 1 + k] = top + np.cumsum(lens)
        self._n = n + k
        self._top = top + total

    def extend_rows(self, rows: Iterable[Sequence[int]]) -> None:
        for r in rows:
            self.add(r)

    # -------------------------------------------------------------- reads
    def __len__(self) -> int:
        return self._n

    @property
    def n_lits(self) -> int:
        return self._top

    def lits_view(self) -> np.ndarray:
        """Trimmed literal buffer ``[n_lits]`` — treat as read-only."""
        return self._lits[:self._top]

    def offs_view(self) -> np.ndarray:
        """Trimmed offsets ``[n_clauses + 1]`` — treat as read-only."""
        return self._offs[:self._n + 1]

    def lens(self) -> np.ndarray:
        return np.diff(self.offs_view())

    def clause(self, i: int) -> Tuple[int, ...]:
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError("clause index out of range")
        a, b = int(self._offs[i]), int(self._offs[i + 1])
        return tuple(self._lits[a:b].tolist())

    def iter_tuples(self, start: int = 0, stop: Optional[int] = None,
                    ) -> Iterator[Tuple[int, ...]]:
        stop = self._n if stop is None else stop
        offs = self._offs[start:stop + 1].tolist()
        if not offs:
            return
        flat = self._lits[offs[0]:offs[-1]].tolist()
        base = offs[0]
        for i in range(len(offs) - 1):
            yield tuple(flat[offs[i] - base:offs[i + 1] - base])

    def iter_lists(self) -> Iterator[List[int]]:
        """Rows as plain-int lists (one ``tolist`` total — the fast path
        for consumers that re-normalise per clause, e.g. CDCL intake)."""
        offs = self._offs[:self._n + 1].tolist()
        flat = self._lits[:self._top].tolist()
        for i in range(self._n):
            yield flat[offs[i]:offs[i + 1]]

    def max_var(self) -> int:
        return int(np.abs(self.lits_view()).max()) if self._top else 0

    def padded_rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """Audit hook: the clause stream as one dense ``[n_clauses, Lmax]``
        int64 matrix (rows zero-padded on the right — 0 is never a
        literal) plus the per-row lengths. This is the whole-arena view
        the static CNF auditor (``repro.analysis.cnf_audit``) vectorises
        over: row-wise sorts, uniqueness, and membership tests become
        single numpy ops instead of per-clause Python loops."""
        lens = self.lens()
        n = self._n
        if n == 0:
            return np.zeros((0, 0), dtype=np.int64), lens
        lmax = int(lens.max()) if lens.size else 0
        pad = np.zeros((n, lmax), dtype=np.int64)
        rows = np.repeat(np.arange(n), lens)
        cols = np.arange(self._top) - np.repeat(self.offs_view()[:-1], lens)
        pad[rows, cols] = self.lits_view()
        return pad, lens

    def copy(self) -> "ClauseArena":
        out = ClauseArena.__new__(ClauseArena)
        out._lits = self._lits[:self._top].copy()
        out._offs = self._offs[:self._n + 1].copy()
        out._n = self._n
        out._top = self._top
        return out

    # ------------------------------------------------------- serialisation
    # Binary layout (little-endian, 8-byte aligned arrays — designed so a
    # reader holding an mmap of a store file can np.frombuffer the two
    # array segments without copying):
    #
    #   b"CArn" | u32 version | u64 n_clauses | u64 n_lits
    #   | offs  int64[n_clauses + 1]
    #   | lits  int32[n_lits]   (+ 4 pad bytes when n_lits is odd)
    #   | u32 crc32 over everything above
    _SER_MAGIC = b"CArn"
    _SER_VERSION = 1
    _SER_HEAD = struct.Struct("<4sIQQ")

    def to_bytes(self) -> bytes:
        """Serialise the arena; ``from_bytes`` round-trips stream-exactly
        (identical ``offs``/``lits`` arrays, hence identical clause
        stream — empty clauses and guard literals included)."""
        offs = np.ascontiguousarray(self.offs_view(), dtype="<i8")
        lits = np.ascontiguousarray(self.lits_view(), dtype="<i4")
        head = self._SER_HEAD.pack(self._SER_MAGIC, self._SER_VERSION,
                                   self._n, self._top)
        pad = b"\x00\x00\x00\x00" if self._top % 2 else b""
        body = head + offs.tobytes() + lits.tobytes() + pad
        return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)

    @classmethod
    def from_bytes(cls, data: bytes) -> "ClauseArena":
        """Rebuild an arena serialised by :meth:`to_bytes`.

        Raises :class:`ArenaFormatError` on any mismatch — bad magic,
        truncation, CRC failure, or violated CSR invariants — so a store
        reading a corrupted record can quarantine it instead of crashing
        (or worse, silently adopting a garbled clause stream)."""
        data = bytes(data)
        head_n = cls._SER_HEAD.size
        if len(data) < head_n + 4:
            raise ArenaFormatError("arena blob truncated (header)")
        magic, version, n, top = cls._SER_HEAD.unpack_from(data)
        if magic != cls._SER_MAGIC:
            raise ArenaFormatError("bad arena magic")
        if version != cls._SER_VERSION:
            raise ArenaFormatError(f"unsupported arena version {version}")
        pad = 4 if top % 2 else 0
        need = head_n + 8 * (n + 1) + 4 * top + pad + 4
        if len(data) != need:
            raise ArenaFormatError(
                f"arena blob length {len(data)} != expected {need}")
        crc = struct.unpack_from("<I", data, need - 4)[0]
        if zlib.crc32(data[:need - 4]) & 0xFFFFFFFF != crc:
            raise ArenaFormatError("arena CRC mismatch")
        offs = np.frombuffer(data, dtype="<i8", count=n + 1, offset=head_n)
        lits = np.frombuffer(data, dtype="<i4", count=top,
                             offset=head_n + 8 * (n + 1))
        if n < 0 or top < 0 or offs.size == 0 or offs[0] != 0 \
                or int(offs[-1]) != top or (np.diff(offs) < 0).any():
            raise ArenaFormatError("arena CSR invariants violated")
        return cls.from_arrays(lits.astype(np.int32),
                               offs.astype(np.int64))


class _ClausesView:
    """List-of-tuples facade over a CNF's arena.

    Supports the whole legacy surface: iteration, ``len``, indexing,
    slicing (returns a plain list of tuples), membership, equality against
    another view or a list, and ``append``. Bound to the CNF (not the
    arena object) so it stays valid if the arena is swapped wholesale.
    """

    __slots__ = ("_cnf",)

    def __init__(self, cnf: "CNF"):
        self._cnf = cnf

    @property
    def _arena(self) -> ClauseArena:
        return self._cnf.arena

    def __len__(self) -> int:
        return len(self._arena)

    def __iter__(self) -> Iterator[Tuple[int, ...]]:
        return self._arena.iter_tuples()

    def __getitem__(self, idx):
        a = self._arena
        if isinstance(idx, slice):
            start, stop, step = idx.indices(len(a))
            if step == 1:
                return list(a.iter_tuples(start, stop))
            return [a.clause(i) for i in range(start, stop, step)]
        return a.clause(idx)

    def __contains__(self, item) -> bool:
        key = tuple(item)
        return any(t == key for t in self)

    def __eq__(self, other) -> bool:
        if isinstance(other, _ClausesView):
            a, b = self._arena, other._arena
            return (len(a) == len(b)
                    and np.array_equal(a.offs_view(), b.offs_view())
                    and np.array_equal(a.lits_view(), b.lits_view()))
        if isinstance(other, (list, tuple)):
            if len(other) != len(self):
                return False
            return all(mine == tuple(theirs)
                       for mine, theirs in zip(self, other))
        return NotImplemented

    __hash__ = None  # mutable container, like list

    def append(self, lits: Sequence[int]) -> None:
        self._arena.add(tuple(lits))

    def iter_lists(self) -> Iterator[List[int]]:
        return self._arena.iter_lists()

    def max_var(self) -> int:
        return self._arena.max_var()

    def __repr__(self) -> str:
        return f"_ClausesView({list(self)!r})"


def _append_guard(flat: np.ndarray, lens: np.ndarray, sel: int,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Append ``-sel`` to every row of a flat clause block (vectorised)."""
    k = int(lens.size)
    out_lens = lens + 1
    out = np.empty(int(flat.size) + k, dtype=np.int32)
    ends = np.cumsum(out_lens)
    out[ends - 1] = -sel
    mask = np.ones(out.size, dtype=bool)
    mask[ends - 1] = False
    out[mask] = flat
    return out, out_lens


# pairwise AMO groups emit via numpy above this size; below it the plain
# Python double loop beats array dispatch overhead (stream is identical)
_PAIRWISE_BULK_MIN = 9


class CNF:
    def __init__(self):
        self.n_vars = 0
        self.arena = ClauseArena()
        # set when an empty clause is recorded: the formula is trivially
        # UNSAT and every backend may (and should) fail fast on it
        self.trivially_unsat = False

    # ------------------------------------------------------- clause views
    @property
    def clauses(self) -> _ClausesView:
        return _ClausesView(self)

    @clauses.setter
    def clauses(self, value) -> None:
        if isinstance(value, ClauseArena):
            self.arena = value.copy()
        elif isinstance(value, _ClausesView):
            self.arena = value._arena.copy()
        else:
            a = ClauseArena()
            a.extend_rows(tuple(c) for c in value)
            self.arena = a

    def new_var(self) -> int:
        self.n_vars += 1
        return self.n_vars

    def new_vars(self, k: int) -> List[int]:
        return [self.new_var() for _ in range(k)]

    def add(self, *lits: int) -> None:
        if not lits:
            raise EmptyClauseError(
                "empty clause added directly (use add_clause([]))")
        self.arena.add(lits)

    def add_clause(self, lits: Sequence[int]) -> None:
        lits = tuple(lits)
        if not lits:
            self.trivially_unsat = True
        self.arena.add(lits)

    def extend_flat(self, flat: np.ndarray, lens: np.ndarray) -> None:
        """Bulk ``add_clause``: ``flat`` int32 concatenated rows, ``lens``
        per-row lengths. Zero-length rows mark ``trivially_unsat`` exactly
        like ``add_clause([])``."""
        lens = np.asarray(lens, dtype=np.int64)
        if lens.size == 0:
            return
        if not lens.all():
            self.trivially_unsat = True
        self.arena.extend_flat(np.asarray(flat, dtype=np.int32), lens)

    # ------------------------------------------------------------ cardinality
    def at_least_one(self, lits: Sequence[int]) -> None:
        self.add_clause(list(lits))

    def at_most_one(self, lits: Sequence[int], encoding: str = "pairwise",
                    pairwise_limit: int = 4) -> None:
        """Encode sum(lits) <= 1.

        ``"pairwise"`` is the paper's M(n) set: one binary clause per pair,
        O(k^2) clauses, no fresh variables. ``"sequential"`` is Sinz's
        LTSEQ with k-1 register variables and O(k) ternary clauses — but
        it *falls back to pairwise when* ``len(lits) <= pairwise_limit``
        (default 4): at k=4 pairwise costs 6 binary clauses while LTSEQ
        costs 3 fresh variables + 8 clauses, so tiny groups are strictly
        cheaper pairwise. ``pairwise_limit`` exposes that crossover so the
        encoder benchmark can sweep it; 1 disables the fallback entirely.

        Large pairwise groups are emitted as one vectorised block (same
        clause stream as the loop, bit for bit).
        """
        lits = list(lits)
        k = len(lits)
        if k <= 1:
            return
        if encoding == "pairwise" or k <= pairwise_limit:
            if k < _PAIRWISE_BULK_MIN:
                for i in range(k):
                    for j in range(i + 1, k):
                        self.add(-lits[i], -lits[j])
            else:
                neg = -np.asarray(lits, dtype=np.int32)
                iu, ju = np.triu_indices(k, 1)
                flat = np.empty(iu.size * 2, dtype=np.int32)
                flat[0::2] = neg[iu]
                flat[1::2] = neg[ju]
                self.extend_flat(flat, np.full(iu.size, 2, dtype=np.int64))
        elif encoding == "sequential":
            # Sinz 2005 LTSEQ: registers s_i == "some lit among first i+1 true"
            s = self.new_vars(k - 1)
            self.add(-lits[0], s[0])
            for i in range(1, k - 1):
                self.add(-lits[i], s[i])
                self.add(-s[i - 1], s[i])
                self.add(-lits[i], -s[i - 1])
            self.add(-lits[-1], -s[-1])
        else:
            raise ValueError(f"unknown AMO encoding {encoding!r}")

    def exactly_one(self, lits: Sequence[int], encoding: str = "pairwise",
                    pairwise_limit: int = 4) -> None:
        self.at_least_one(lits)
        self.at_most_one(lits, encoding, pairwise_limit=pairwise_limit)

    # ---------------------------------------------------------------- stats
    @property
    def n_clauses(self) -> int:
        return len(self.arena)

    def stats(self) -> Dict[str, int]:
        return {"vars": self.n_vars, "clauses": self.n_clauses,
                "lits": self.arena.n_lits}

    def to_dimacs(self) -> str:
        head = f"p cnf {self.n_vars} {self.n_clauses}\n"
        body = "\n".join(" ".join(map(str, c)) + " 0"
                         for c in self.arena.iter_tuples())
        return head + body + "\n"

    def check(self, assignment: Sequence[bool]) -> bool:
        """assignment[v-1] is the value of var v. True iff all clauses sat."""
        arena = self.arena
        n = len(arena)
        if n == 0:
            return True
        lens = arena.lens()
        if not lens.all():
            return False  # an empty clause is unsatisfiable
        lits = arena.lits_view()
        vals = np.asarray(assignment, dtype=bool)
        idx = np.abs(lits) - 1
        if int(idx.max()) >= vals.size:
            raise IndexError("assignment shorter than highest variable")
        true_lit = vals[idx] == (lits > 0)
        sat = np.logical_or.reduceat(true_lit, arena.offs_view()[:-1])
        return bool(sat.all())


@dataclass
class _IncLayer:
    selector: int                   # selector var guarding every clause
    start: int                      # [start, end) slice of self.clauses
    end: int
    var_start: int                  # vars created before this layer
    var_end: int


class IncrementalCNF(CNF):
    """Layered CNF for assumption-based incremental solving.

    Clauses added outside any layer form the shared *base* (unguarded —
    active in every solve). ``begin_layer(key)`` allocates a fresh selector
    variable ``s``; until ``end_layer()`` every added clause ``C`` is stored
    as ``C ∨ ¬s``, so the layer is inert unless the solve assumes ``s``.
    Layers are never removed — a solver that keeps the whole formula loaded
    retains every learned clause across layer switches, because assumptions
    are decisions, not axioms: anything the solver derives is a consequence
    of the (guarded) clause database alone and stays valid forever.

    ``assumptions_for(key)`` activates exactly one layer (and explicitly
    deactivates the others, so a solve is precisely base+delta regardless of
    solver phase defaults); ``project(key)`` materialises the equivalent
    plain :class:`CNF` for backends without assumption support (the batched
    WalkSAT) and for cold-path equivalence checks.
    """

    def __init__(self):
        super().__init__()
        self._layers: Dict[Hashable, _IncLayer] = {}
        self._open: Optional[_IncLayer] = None
        self._open_key: Optional[Hashable] = None
        self.n_base_vars = 0   # frozen at the first begin_layer()

    # ------------------------------------------------------------- layers
    def begin_layer(self, key: Hashable) -> int:
        """Open delta layer ``key``; returns its selector variable."""
        if self._open is not None:
            raise AssertionError("nested layers are not supported")
        if key in self._layers:
            raise AssertionError(f"layer {key!r} already encoded")
        if not self._layers:
            self.n_base_vars = self.n_vars
        sel = self.new_var()
        n = len(self.arena)
        self._open = _IncLayer(selector=sel, start=n, end=n,
                               var_start=self.n_vars, var_end=self.n_vars)
        self._open_key = key
        return sel

    def end_layer(self) -> None:
        if self._open is None:
            raise AssertionError("no open layer")
        self._open.end = len(self.arena)
        self._open.var_end = self.n_vars
        self._layers[self._open_key] = self._open
        self._open = None
        self._open_key = None

    def add_clause(self, lits: Sequence[int]) -> None:
        lits = tuple(lits)
        if self._open is not None:
            # an empty clause inside a layer is not a global contradiction:
            # it only forbids activating this layer, i.e. unit(¬selector)
            self.arena.add(lits + (-self._open.selector,))
            return
        if self._layers:
            raise AssertionError("base is frozen once the first layer exists")
        if not lits:
            self.trivially_unsat = True
        self.arena.add(lits)

    def add(self, *lits: int) -> None:
        if not lits:
            raise EmptyClauseError(
                "empty clause added directly (use add_clause([]))")
        self.add_clause(lits)

    def extend_flat(self, flat: np.ndarray, lens: np.ndarray) -> None:
        """Bulk ``add_clause`` — inside an open layer every row gets the
        ``¬selector`` guard appended (vectorised), matching the per-clause
        path bit for bit."""
        lens = np.asarray(lens, dtype=np.int64)
        if lens.size == 0:
            return
        flat = np.asarray(flat, dtype=np.int32)
        if self._open is not None:
            flat, lens = _append_guard(flat, lens, self._open.selector)
            self.arena.extend_flat(flat, lens)
            return
        if self._layers:
            raise AssertionError("base is frozen once the first layer exists")
        if not lens.all():
            self.trivially_unsat = True
        self.arena.extend_flat(flat, lens)

    # ------------------------------------------------------------ queries
    def layer_keys(self) -> List[Hashable]:
        return list(self._layers)

    def has_layer(self, key: Hashable) -> bool:
        return key in self._layers

    def selector(self, key: Hashable) -> int:
        return self._layers[key].selector

    def assumptions_for(self, key: Hashable) -> List[int]:
        """Assumption literals that activate exactly layer ``key``."""
        on = self._layers[key].selector
        return [on] + [-l.selector for k, l in self._layers.items()
                       if k != key]

    def layer_slice(self, key: Hashable) -> Tuple[int, int]:
        lay = self._layers[key]
        return lay.start, lay.end

    def layer_var_ranges(self) -> Dict[Hashable, Tuple[int, int, int]]:
        """Audit hook: ``{key: (selector, var_start, var_end)}`` per layer.

        A layer's variables are its selector (allocated first, so
        ``selector == var_start``) plus any aux vars created while it was
        open — the full range is ``var_start <= v <= var_end``.
        ``project(other_key)`` strips this layer's clauses
        entirely, so these variables legitimately occur in no clause of
        the projection — the CNF auditor uses this map to tell that
        expected deadness apart from a genuinely dangling variable."""
        return {k: (lay.selector, lay.var_start, lay.var_end)
                for k, lay in self._layers.items()}

    def project(self, key: Hashable) -> CNF:
        """Plain CNF equivalent to base + layer ``key`` (guards stripped).

        Variable numbering is preserved (selector/other-layer variables
        simply occur in no clause), so models are interchangeable with
        assumption solves over the full formula. Vectorised: base rows are
        one memcpy, layer rows drop their trailing guard literal with one
        masked copy (the guard position of every row is verified).
        """
        if self._open is not None:
            raise AssertionError("close the open layer before projecting")
        lay = self._layers[key]
        out = CNF()
        out.n_vars = self.n_vars
        offs = self.arena.offs_view()
        lits = self.arena.lits_view()
        base_end = min(l.start for l in self._layers.values())
        if base_end:
            base_lens = np.diff(offs[:base_end + 1])
            out.extend_flat(lits[:int(offs[base_end])], base_lens)
        s, e = lay.start, lay.end
        if e > s:
            row_offs = offs[s:e + 1]
            guard_pos = row_offs[1:] - 1
            if not (lits[guard_pos] == -lay.selector).all():
                raise AssertionError("layer guard literal mismatch")
            lo = int(row_offs[0])
            seg = lits[lo:int(row_offs[-1])]
            keep = np.ones(seg.size, dtype=bool)
            keep[guard_pos - lo] = False
            out.extend_flat(seg[keep], np.diff(row_offs) - 1)
        return out

    def layer_stats(self, key: Hashable) -> Dict[str, int]:
        lay = self._layers[key]
        base_end = min(l.start for l in self._layers.values())
        return {"vars": self.n_vars,
                "base_clauses": base_end,
                "delta_clauses": lay.end - lay.start,
                "clauses": base_end + (lay.end - lay.start)}

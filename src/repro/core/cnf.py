"""CNF container + cardinality encodings.

Variables are positive ints (DIMACS convention); a literal is ±var. The
paper's C1 uses the naive pairwise at-most-one (its Eq. 1 ``M(n)`` set); we
also provide the Sinz sequential encoding as a beyond-paper option — it turns
O(k^2) binary clauses into O(k) ternary ones, which dominates encode time on
big KMS instances.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple


class CNF:
    def __init__(self):
        self.n_vars = 0
        self.clauses: List[Tuple[int, ...]] = []

    def new_var(self) -> int:
        self.n_vars += 1
        return self.n_vars

    def new_vars(self, k: int) -> List[int]:
        return [self.new_var() for _ in range(k)]

    def add(self, *lits: int) -> None:
        assert lits, "empty clause added directly (use add_false)"
        self.clauses.append(tuple(lits))

    def add_clause(self, lits: Sequence[int]) -> None:
        self.clauses.append(tuple(lits))

    # ------------------------------------------------------------ cardinality
    def at_least_one(self, lits: Sequence[int]) -> None:
        self.add_clause(list(lits))

    def at_most_one(self, lits: Sequence[int], encoding: str = "pairwise") -> None:
        lits = list(lits)
        if len(lits) <= 1:
            return
        if encoding == "pairwise" or len(lits) <= 4:
            for i in range(len(lits)):
                for j in range(i + 1, len(lits)):
                    self.add(-lits[i], -lits[j])
        elif encoding == "sequential":
            # Sinz 2005 LTSEQ: registers s_i == "some lit among first i+1 true"
            s = self.new_vars(len(lits) - 1)
            self.add(-lits[0], s[0])
            for i in range(1, len(lits) - 1):
                self.add(-lits[i], s[i])
                self.add(-s[i - 1], s[i])
                self.add(-lits[i], -s[i - 1])
            self.add(-lits[-1], -s[-1])
        else:
            raise ValueError(f"unknown AMO encoding {encoding!r}")

    def exactly_one(self, lits: Sequence[int], encoding: str = "pairwise") -> None:
        self.at_least_one(lits)
        self.at_most_one(lits, encoding)

    # ---------------------------------------------------------------- stats
    @property
    def n_clauses(self) -> int:
        return len(self.clauses)

    def stats(self) -> Dict[str, int]:
        return {"vars": self.n_vars, "clauses": self.n_clauses,
                "lits": sum(len(c) for c in self.clauses)}

    def to_dimacs(self) -> str:
        head = f"p cnf {self.n_vars} {self.n_clauses}\n"
        body = "\n".join(" ".join(map(str, c)) + " 0" for c in self.clauses)
        return head + body + "\n"

    def check(self, assignment: Sequence[bool]) -> bool:
        """assignment[v-1] is the value of var v. True iff all clauses sat."""
        for cl in self.clauses:
            if not any((lit > 0) == assignment[abs(lit) - 1] for lit in cl):
                return False
        return True

"""CNF containers + cardinality encodings.

Variables are positive ints (DIMACS convention); a literal is ±var. The
paper's C1 uses the naive pairwise at-most-one (its Eq. 1 ``M(n)`` set); we
also provide the Sinz sequential encoding as a beyond-paper option — it turns
O(k^2) binary clauses into O(k) ternary ones, which dominates encode time on
big KMS instances.

``IncrementalCNF`` is the layered container behind the assumption-based
solver core: a shared *base* layer of unguarded clauses plus named delta
layers whose clauses carry a fresh selector literal, so one persistent
formula covers every candidate II of a sweep and "try II=k" is an
assumption solve rather than a fresh encode.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple


class CNF:
    def __init__(self):
        self.n_vars = 0
        self.clauses: List[Tuple[int, ...]] = []
        # set when an empty clause is recorded: the formula is trivially
        # UNSAT and every backend may (and should) fail fast on it
        self.trivially_unsat = False

    def new_var(self) -> int:
        self.n_vars += 1
        return self.n_vars

    def new_vars(self, k: int) -> List[int]:
        return [self.new_var() for _ in range(k)]

    def add(self, *lits: int) -> None:
        assert lits, "empty clause added directly (use add_clause([]))"
        self.clauses.append(tuple(lits))

    def add_clause(self, lits: Sequence[int]) -> None:
        lits = tuple(lits)
        if not lits:
            self.trivially_unsat = True
        self.clauses.append(lits)

    # ------------------------------------------------------------ cardinality
    def at_least_one(self, lits: Sequence[int]) -> None:
        self.add_clause(list(lits))

    def at_most_one(self, lits: Sequence[int], encoding: str = "pairwise") -> None:
        lits = list(lits)
        if len(lits) <= 1:
            return
        if encoding == "pairwise" or len(lits) <= 4:
            for i in range(len(lits)):
                for j in range(i + 1, len(lits)):
                    self.add(-lits[i], -lits[j])
        elif encoding == "sequential":
            # Sinz 2005 LTSEQ: registers s_i == "some lit among first i+1 true"
            s = self.new_vars(len(lits) - 1)
            self.add(-lits[0], s[0])
            for i in range(1, len(lits) - 1):
                self.add(-lits[i], s[i])
                self.add(-s[i - 1], s[i])
                self.add(-lits[i], -s[i - 1])
            self.add(-lits[-1], -s[-1])
        else:
            raise ValueError(f"unknown AMO encoding {encoding!r}")

    def exactly_one(self, lits: Sequence[int], encoding: str = "pairwise") -> None:
        self.at_least_one(lits)
        self.at_most_one(lits, encoding)

    # ---------------------------------------------------------------- stats
    @property
    def n_clauses(self) -> int:
        return len(self.clauses)

    def stats(self) -> Dict[str, int]:
        return {"vars": self.n_vars, "clauses": self.n_clauses,
                "lits": sum(len(c) for c in self.clauses)}

    def to_dimacs(self) -> str:
        head = f"p cnf {self.n_vars} {self.n_clauses}\n"
        body = "\n".join(" ".join(map(str, c)) + " 0" for c in self.clauses)
        return head + body + "\n"

    def check(self, assignment: Sequence[bool]) -> bool:
        """assignment[v-1] is the value of var v. True iff all clauses sat."""
        for cl in self.clauses:
            if not any((lit > 0) == assignment[abs(lit) - 1] for lit in cl):
                return False
        return True


@dataclass
class _IncLayer:
    selector: int                   # selector var guarding every clause
    start: int                      # [start, end) slice of self.clauses
    end: int
    var_start: int                  # vars created before this layer
    var_end: int


class IncrementalCNF(CNF):
    """Layered CNF for assumption-based incremental solving.

    Clauses added outside any layer form the shared *base* (unguarded —
    active in every solve). ``begin_layer(key)`` allocates a fresh selector
    variable ``s``; until ``end_layer()`` every added clause ``C`` is stored
    as ``C ∨ ¬s``, so the layer is inert unless the solve assumes ``s``.
    Layers are never removed — a solver that keeps the whole formula loaded
    retains every learned clause across layer switches, because assumptions
    are decisions, not axioms: anything the solver derives is a consequence
    of the (guarded) clause database alone and stays valid forever.

    ``assumptions_for(key)`` activates exactly one layer (and explicitly
    deactivates the others, so a solve is precisely base+delta regardless of
    solver phase defaults); ``project(key)`` materialises the equivalent
    plain :class:`CNF` for backends without assumption support (the batched
    WalkSAT) and for cold-path equivalence checks.
    """

    def __init__(self):
        super().__init__()
        self._layers: Dict[Hashable, _IncLayer] = {}
        self._open: Optional[_IncLayer] = None
        self._open_key: Optional[Hashable] = None
        self.n_base_vars = 0   # frozen at the first begin_layer()

    # ------------------------------------------------------------- layers
    def begin_layer(self, key: Hashable) -> int:
        """Open delta layer ``key``; returns its selector variable."""
        assert self._open is None, "nested layers are not supported"
        assert key not in self._layers, f"layer {key!r} already encoded"
        if not self._layers:
            self.n_base_vars = self.n_vars
        sel = self.new_var()
        self._open = _IncLayer(selector=sel, start=len(self.clauses),
                               end=len(self.clauses),
                               var_start=self.n_vars, var_end=self.n_vars)
        self._open_key = key
        return sel

    def end_layer(self) -> None:
        assert self._open is not None, "no open layer"
        self._open.end = len(self.clauses)
        self._open.var_end = self.n_vars
        self._layers[self._open_key] = self._open
        self._open = None
        self._open_key = None

    def add_clause(self, lits: Sequence[int]) -> None:
        lits = tuple(lits)
        if self._open is not None:
            # an empty clause inside a layer is not a global contradiction:
            # it only forbids activating this layer, i.e. unit(¬selector)
            self.clauses.append(lits + (-self._open.selector,))
            return
        assert not self._layers, "base is frozen once the first layer exists"
        if not lits:
            self.trivially_unsat = True
        self.clauses.append(lits)

    def add(self, *lits: int) -> None:
        assert lits, "empty clause added directly (use add_clause([]))"
        self.add_clause(lits)

    # ------------------------------------------------------------ queries
    def layer_keys(self) -> List[Hashable]:
        return list(self._layers)

    def has_layer(self, key: Hashable) -> bool:
        return key in self._layers

    def selector(self, key: Hashable) -> int:
        return self._layers[key].selector

    def assumptions_for(self, key: Hashable) -> List[int]:
        """Assumption literals that activate exactly layer ``key``."""
        on = self._layers[key].selector
        return [on] + [-l.selector for k, l in self._layers.items()
                       if k != key]

    def layer_slice(self, key: Hashable) -> Tuple[int, int]:
        lay = self._layers[key]
        return lay.start, lay.end

    def project(self, key: Hashable) -> CNF:
        """Plain CNF equivalent to base + layer ``key`` (guards stripped).

        Variable numbering is preserved (selector/other-layer variables
        simply occur in no clause), so models are interchangeable with
        assumption solves over the full formula.
        """
        assert self._open is None, "close the open layer before projecting"
        lay = self._layers[key]
        out = CNF()
        out.n_vars = self.n_vars
        base_end = min(l.start for l in self._layers.values())
        for cl in self.clauses[:base_end]:
            out.add_clause(cl)
        sel = lay.selector
        for cl in self.clauses[lay.start:lay.end]:
            assert cl[-1] == -sel
            out.add_clause(cl[:-1])
        return out

    def layer_stats(self, key: Hashable) -> Dict[str, int]:
        lay = self._layers[key]
        base_end = min(l.start for l in self._layers.values())
        return {"vars": self.n_vars,
                "base_clauses": base_end,
                "delta_clauses": lay.end - lay.start,
                "clauses": base_end + (lay.end - lay.start)}

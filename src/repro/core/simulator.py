"""Cycle-level CGRA simulator — the ground-truth oracle for mappings.

Given a placement {node: (pe, cycle, iteration)} at a given II, this module
  1. statically checks the mapping invariants (C1/C2/C3 semantics:
     single placement, one node per (PE, kernel cycle), neighbour adjacency,
     and the non-rotating-register timing window — under the fabric's
     per-op-class *latency* model: an edge s->d with loop distance delta
     must satisfy lat(s) <= t_d - t_s + delta*II <= II + lat(s) - 1, the
     consumer issuing no earlier than the producer's result exists and no
     later than the producer's next kernel instance rewrites it), and
  2. *executes* the modulo schedule: instance (n, i) of node n for loop
     iteration i issues at absolute cycle i*II + t_n on PE p_n and
     completes lat(n) cycles later; memory ops commit in absolute
     *completion* order. The resulting per-iteration values and final
     memory are compared against ``DFG.execute`` — a mapping is correct
     iff pipelined execution is observationally equal to sequential
     execution. (All latencies 1 — the paper's fabric — reproduces the
     original checks and memory order exactly.)

Also emits prolog / kernel / epilog instruction tables (paper Fig. 2b/2c).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .arch import op_class
from .cgra import CGRA
from .dfg import DFG
from .schedule import node_latencies


@dataclass
class MappingCheck:
    ok: bool
    errors: List[str] = field(default_factory=list)


@dataclass
class KernelCode:
    ii: int
    n_stages: int
    # kernel[c][pe] = node id or None
    kernel: List[List[Optional[int]]]
    prolog: List[List[Optional[Tuple[int, int]]]]   # rows of (node, iter)
    epilog_stages: int

    def render(self, dfg: DFG) -> str:
        def cell(x):
            if x is None:
                return "    ."
            nid = x if isinstance(x, int) else x[0]
            return f"{(dfg.nodes[nid].name or 'n%d' % nid):>5}"
        lines = [f"II={self.ii} stages={self.n_stages}", "-- kernel --"]
        for c, row in enumerate(self.kernel):
            lines.append(f"c{c}: " + " ".join(cell(x) for x in row))
        return "\n".join(lines)


def static_check(dfg: DFG, cgra: CGRA, placement: Dict[int, Tuple[int, int, int]],
                 ii: int) -> MappingCheck:
    errs: List[str] = []
    if set(placement) != set(dfg.nodes):
        errs.append("placement does not cover all nodes")
        return MappingCheck(False, errs)
    lat = node_latencies(dfg, cgra)
    slots: Dict[Tuple[int, int], int] = {}
    wslots: Dict[Tuple[int, int], int] = {}
    for n in sorted(placement):
        p, c, it = placement[n]
        if not (0 <= p < cgra.n_pes):
            errs.append(f"node {n}: bad PE {p}")
        if not (0 <= c < ii):
            errs.append(f"node {n}: kernel cycle {c} outside [0,{ii})")
        if not cgra.can_execute(p, dfg.nodes[n].op):
            errs.append(f"{op_class(dfg.nodes[n].op)} node {n} "
                        f"({dfg.nodes[n].op}) on incapable PE {p}")
        key = (p, c)
        if key in slots:
            errs.append(f"PE/cycle clash: nodes {slots[key]} and {n} at {key}")
        slots[key] = n
        # output-register write port: two mixed-latency nodes on one PE
        # may issue in different cycles yet *complete* in the same one —
        # a simultaneous double write no real fabric supports (with equal
        # latencies this is subsumed by the issue-slot clash above)
        wkey = (p, (c + lat[n]) % ii)
        if wkey in wslots and placement[wslots[wkey]][1] != c:
            errs.append(f"output-register write clash: nodes "
                        f"{wslots[wkey]} and {n} on PE {p} both complete "
                        f"at kernel cycle {wkey[1]}")
        wslots[wkey] = n
    t = {n: it * ii + c for n, (p, c, it) in placement.items()}
    for s, d, delta in dfg.edges():
        ps, pd = placement[s][0], placement[d][0]
        if not cgra.reachable(ps, pd):
            errs.append(f"edge {s}->{d}: PEs {ps},{pd} not adjacent")
        # the consumer may not issue before the producer's result exists
        # (lat(s) cycles after its issue) nor after the producer's next
        # kernel instance rewrites it; lat == 1 is the paper's [1, II]
        span = t[d] - t[s] + delta * ii
        lo, hi = lat[s], ii + lat[s] - 1
        if not (lo <= span <= hi):
            errs.append(
                f"edge {s}->{d} (dist {delta}, lat {lat[s]}): span {span} "
                f"outside [{lo},{hi}] (t_s={t[s]}, t_d={t[d]})")
    return MappingCheck(not errs, errs)


def execute_mapping(dfg: DFG, cgra: CGRA,
                    placement: Dict[int, Tuple[int, int, int]], ii: int,
                    n_iters: int, mem: Dict[int, int] | None = None,
                    init: Dict[int, int] | None = None,
                    ) -> Tuple[List[Dict[int, int]], Dict[int, int]]:
    """Execute the pipelined schedule. Memory ops commit in absolute
    *completion*-cycle order, issue + lat(n) (ties: iteration, node id) —
    this is what the hardware would do, and what exposes illegal
    reordering w.r.t. sequential semantics. With unit latencies every
    completion is issue + 1, i.e. exactly the original issue order."""
    mem = dict(mem or {})
    init = init or {}
    t = {n: it * ii + c for n, (p, c, it) in placement.items()}
    lat = node_latencies(dfg, cgra)
    # absolute completion order of (cycle, iteration, node)
    sched = sorted((i * ii + t[n] + lat[n], i, n)
                   for i in range(n_iters) for n in dfg.nodes)
    vals: List[Dict[int, int]] = [dict() for _ in range(n_iters)]
    for _, i, n in sched:
        node = dfg.nodes[n]
        args = []
        for src, dist in node.ins:
            j = i - dist
            if j >= 0:
                args.append(vals[j][src])
            else:
                args.append(init.get(src, 0))
        from .dfg import _wrap
        vals[i][n] = _wrap(dfg._eval(node, args, i, mem))
    return vals, mem


def verify_mapping(dfg: DFG, cgra: CGRA,
                   placement: Dict[int, Tuple[int, int, int]], ii: int,
                   n_iters: int = 6, mem: Dict[int, int] | None = None,
                   init: Dict[int, int] | None = None,
                   node_subset: Optional[set] = None) -> MappingCheck:
    """Static checks + observational equivalence with sequential execution.

    ``node_subset``: compare only these nodes' values (used when routing
    nodes were inserted — they have no counterpart in the original DFG)."""
    chk = static_check(dfg, cgra, placement, ii)
    if not chk.ok:
        return chk
    seq_vals, seq_mem = dfg.execute(n_iters, mem=mem, init=init)
    pip_vals, pip_mem = execute_mapping(dfg, cgra, placement, ii, n_iters,
                                        mem=mem, init=init)
    errs: List[str] = []
    nodes = node_subset if node_subset is not None else set(dfg.nodes)
    for i in range(n_iters):
        for n in nodes:
            if seq_vals[i][n] != pip_vals[i][n]:
                errs.append(f"iter {i} node {n}: "
                            f"seq={seq_vals[i][n]} pipelined={pip_vals[i][n]}")
    if seq_mem != pip_mem:
        errs.append(f"final memory differs: {seq_mem} vs {pip_mem}")
    return MappingCheck(not errs, errs[:20])


def emit_code(dfg: DFG, cgra: CGRA,
              placement: Dict[int, Tuple[int, int, int]], ii: int) -> KernelCode:
    t = {n: it * ii + c for n, (p, c, it) in placement.items()}
    lat = node_latencies(dfg, cgra)
    # stages cover through the last *completion* (== last issue + 1 on the
    # paper's unit-latency fabric)
    length = max(t[n] + lat[n] for n in t)
    n_stages = -(-length // ii)
    kernel: List[List[Optional[int]]] = [
        [None] * cgra.n_pes for _ in range(ii)]
    for n, (p, c, it) in placement.items():
        kernel[c][p] = n
    # prolog: absolute cycles 0 .. (n_stages-1)*II - 1 over iterations 0..
    prolog: List[List[Optional[Tuple[int, int]]]] = []
    for abs_c in range((n_stages - 1) * ii):
        row: List[Optional[Tuple[int, int]]] = [None] * cgra.n_pes
        for n, (p, c, it) in placement.items():
            for i in range(n_stages):
                if i * ii + t[n] == abs_c:
                    row[p] = (n, i)
        prolog.append(row)
    return KernelCode(ii=ii, n_stages=n_stages, kernel=kernel, prolog=prolog,
                      epilog_stages=n_stages - 1)

# The paper's primary contribution — the SAT-based modulo-scheduling
# mapper — lives in this package. Public API, one front door:
#
#   arch()/ArchSpec          declarative fabrics (repro.core.arch)
#   MapRequest -> compile()  the unified mapping request pipeline
#   CGRA/cgra_from_name      legacy homogeneous front end (thin adapter)
#   map_loop/MapperConfig    paper-faithful engine entry points
#
# `compile` shadows the builtin inside this namespace only; import it
# explicitly (`from repro.core import compile`) or use the api module.
from .arch import ArchSpec, arch, op_class                    # noqa: F401
from .cgra import CGRA, cgra_from_name                        # noqa: F401
from .api import MapRequest, compile                          # noqa: F401
from .mapper import MapperConfig, MappingResult, map_loop     # noqa: F401
from .schedule import Infeasible                              # noqa: F401

"""Mapping-campaign engine: procedural DFG corpus + sharded cell dataset.

The mapper's II sweep burns most of its wall-clock refuting IIs below the
true minimum, and the serving tier (PR 8) can absorb far more traffic than
the 11 suite kernels generate. This module is the *data flywheel* that
closes the loop (following the Gerador exemplar, SNIPPETS.md §3, and
GenMap's population-scale framing, §1–2):

  * **corpus** — :func:`random_dfg` grows loop DFGs from a seeded,
    level-structured grammar (op-class mix, loop-carried-dependence depth,
    fan-out / reconvergence knobs), and :func:`mutate_dfg` derives variants
    of existing kernels (op swaps, edge rewires, node growth, back-edge
    re-distancing, pure relabelings). Everything is driven by one
    ``random.Random`` stream — the same seed reproduces the corpus
    byte-for-byte in any process (no ``hash()``, no set iteration order).
  * **dedup** — :func:`canonical_key` canonicalises a DFG (Weisfeiler-
    Lehman colour refinement with individualise-and-refine tie-breaking)
    and keys it by the existing :func:`~repro.core.service.dfg_signature`
    of the canonical form, so isomorphic mutants (any node relabeling)
    collapse to one corpus entry.
  * **dataset** — :class:`CampaignDataset` appends one compact
    :class:`CellRecord` per mapped (DFG × fabric) cell to sharded logs
    that reuse the exact :mod:`repro.core.store` record framing (CRC'd
    frames, torn-tail tolerance, 8-byte alignment): canonical keys, the
    feature vector, per-II attempt outcomes, final II vs MII, wall-clock,
    and — for cells the sweep refuted outright — the MII projection's
    ``ClauseArena.to_bytes`` as a re-solvable UNSAT witness.
  * **campaign** — :func:`run_campaign` fans the (corpus × fabric
    gallery) grid through a :class:`~repro.core.workers.WorkerPool`
    (affinity-sharded multi-process solves over one shared store) and
    streams records into the dataset as results land.

The dataset feeds :mod:`repro.core.guide`: a small jax MLP trained on
these records predicts each cell's feasible II, and the sweep uses the
prediction *soundly* — window seeding and candidate ordering only, never
skipping an II without a proven core.
"""
from __future__ import annotations

import copy
import math
import os
import pickle
import struct
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .dfg import DFG, Node
from .schedule import (Infeasible, asap_alap, node_latencies, rec_mii,
                       res_mii)
from .service import dfg_signature, topology_signature
from .store import (_FileLock, _HEAD, StoreCorruption, iter_framed,
                    key_hash, write_framed)

# campaign-cell record type in the shared store framing (MappingStore's
# scanner skips unknown rtypes, so these frames are forward-compatible
# with every reader of the format)
RT_CELL = 4

# ------------------------------------------------------------ canonical form


def _refine_colors(dfg: DFG, colors: Dict[int, object],
                   out_edges: Dict[int, List[Tuple[int, int, int]]],
                   ) -> Dict[int, int]:
    """Weisfeiler-Lehman colour refinement to a fixpoint. In-edges keep
    their slot order (operand position is semantic: sub/select/store are
    not commutative); out-edges contribute as a sorted multiset. Colours
    are re-ranked each round by *sorting the signature values*, never by
    ``hash()`` — the result is identical across processes."""
    n = len(dfg.nodes)
    for _ in range(n + 1):
        sigs = {}
        for nid, nd in dfg.nodes.items():
            ins_sig = tuple((dist, colors[src]) for src, dist in nd.ins)
            outs_sig = tuple(sorted(
                (dist, slot, colors[dst])
                for dst, slot, dist in out_edges[nid]))
            sigs[nid] = (colors[nid], ins_sig, outs_sig)
        ranks = {s: i for i, s in enumerate(sorted(set(sigs.values())))}
        new = {nid: ranks[sigs[nid]] for nid in dfg.nodes}
        if new == colors:
            return new
        colors = new
    return colors


def _relabel_nodes(dfg: DFG, order: List[int]) -> DFG:
    """Rebuild ``dfg`` with node ids renumbered by position in ``order``
    (names dropped: they are display-only and excluded from signatures)."""
    idx = {old: new for new, old in enumerate(order)}
    g = DFG(dfg.name)
    for new, old in enumerate(order):
        nd = dfg.nodes[old]
        g.nodes[new] = Node(new, nd.op,
                            tuple((idx[src], dist) for src, dist in nd.ins),
                            nd.imm, "")
    g.touch()
    return g


def canonical_dfg(dfg: DFG, budget: int = 128) -> DFG:
    """A canonical relabeling of ``dfg``: isomorphic DFGs (same structure
    under any node-id permutation) produce the *same* canonical form, so
    ``dfg_signature(canonical_dfg(g))`` is an isomorphism-invariant key.

    WL refinement separates almost every node of a realistic DFG; ties
    are broken by individualise-and-refine — each member of the first
    ambiguous colour class is individualised in turn, refinement recurses,
    and the lexicographically smallest resulting signature wins (truly
    automorphic nodes tie harmlessly: every branch yields the same form).
    ``budget`` caps the explored leaves; past it, remaining ties fall back
    to a deterministic (but only best-effort canonical) ordering — dedup
    then *over-keeps*, which is safe."""
    out_edges: Dict[int, List[Tuple[int, int, int]]] = {
        nid: [] for nid in dfg.nodes}
    for nid, nd in dfg.nodes.items():
        for slot, (src, dist) in enumerate(nd.ins):
            out_edges[src].append((nid, slot, dist))
    init: Dict[int, object] = {
        nid: (nd.op, nd.imm, len(nd.ins))
        for nid, nd in dfg.nodes.items()}
    base = _refine_colors(dfg, init, out_edges)

    best: List[Optional[Tuple[Tuple, List[int]]]] = [None]
    leaves = [0]

    def consider(order: List[int]) -> None:
        sig = dfg_signature(_relabel_nodes(dfg, order))
        if best[0] is None or sig < best[0][0]:
            best[0] = (sig, order)

    def search(colors: Dict[int, int]) -> None:
        groups: Dict[int, List[int]] = {}
        for nid, c in colors.items():
            groups.setdefault(c, []).append(nid)
        ambiguous = [c for c in sorted(groups) if len(groups[c]) > 1]
        if not ambiguous:
            leaves[0] += 1
            consider(sorted(dfg.nodes, key=lambda nid: colors[nid]))
            return
        if leaves[0] >= budget:
            # best-effort fallback: stable but not isomorphism-invariant
            leaves[0] += 1
            consider(sorted(dfg.nodes,
                            key=lambda nid: (colors[nid], nid)))
            return
        cls = groups[ambiguous[0]]
        for nid in sorted(cls):
            if leaves[0] >= budget:
                break
            forced = dict(colors)
            forced[nid] = -1          # unique smallest colour
            search(_refine_colors(dfg, forced, out_edges))

    search(base)
    if best[0] is None:
        raise RuntimeError("canonical_dfg: refinement search exhausted its "
                           "budget without producing a labelling")
    return _relabel_nodes(dfg, best[0][1])


def canonical_key(dfg: DFG) -> bytes:
    """Isomorphism-invariant digest of a DFG — the corpus dedup key and
    the ``dfg_key`` stored in every campaign cell record."""
    return key_hash(("campaign-dfg", dfg_signature(canonical_dfg(dfg))))


# ------------------------------------------------------------------ corpus

_ALU_OPS = ("add", "sub", "and", "or", "xor", "shl", "shr", "min", "max",
            "lt", "eq", "ne")

MUTATION_KINDS = ("relabel", "op", "imm", "rewire", "grow", "carry")


@dataclass(frozen=True)
class CorpusSpec:
    """Knobs of the seeded DFG grammar (one frozen spec = one corpus)."""
    seed: int = 0
    n_random: int = 96            # procedurally generated DFGs
    n_mutants: int = 64           # mutation attempts over the parent pool
    include_suite: bool = True    # seed the parent pool with suite kernels
    min_nodes: int = 6
    max_nodes: int = 18
    p_mem: float = 0.22           # op-class mix: P(load/store)
    p_mul: float = 0.12           # P(mul)
    p_select: float = 0.05        # P(3-input select)
    recent_window: int = 4        # input locality: how far back a chained
    #                               input reaches (controls path depth)
    p_far_edge: float = 0.30      # P(an input reaches *anywhere*) — the
    #                               fan-out / reconvergence knob
    p_carry: float = 0.65         # P(a DFG gets loop-carried back-edges)
    max_carry: int = 2            # loop-carried-dependence depth (max dist)


@dataclass
class CorpusItem:
    name: str
    dfg: DFG
    key: bytes                    # canonical (isomorphism-invariant) key
    kind: str                     # "suite" | "random" | "mutant:<kind>"


def random_dfg(rng, spec: CorpusSpec, name: str = "rand") -> DFG:
    """One grammar-generated loop DFG: iv/const sources, a level-built
    body whose op classes follow the spec's mix, input locality controlled
    by ``recent_window`` (chains) vs ``p_far_edge`` (fan-out and
    reconvergent paths), and optional loop-carried back-edges of distance
    1..``max_carry``. Always validates and executes."""
    g = DFG(name)
    n_target = rng.randint(spec.min_nodes, spec.max_nodes)
    values: List[int] = [g.add("iv", name="i")]
    for _ in range(rng.randint(1, 3)):
        values.append(g.add("const", imm=rng.randint(-64, 64)))

    def pick() -> int:
        if rng.random() < spec.p_far_edge:
            return values[rng.randrange(len(values))]
        lo = max(0, len(values) - spec.recent_window)
        return values[rng.randrange(lo, len(values))]

    while g.n < n_target:
        r = rng.random()
        if r < spec.p_mem:
            if rng.random() < 0.5:
                nid = g.add("load", [(pick(), 0)],
                            imm=rng.randrange(0, 512, 64))
            else:
                nid = g.add("store", [(pick(), 0), (pick(), 0)],
                            imm=rng.randrange(0, 512, 64))
        elif r < spec.p_mem + spec.p_mul:
            nid = g.add("mul", [(pick(), 0), (pick(), 0)])
        elif r < spec.p_mem + spec.p_mul + spec.p_select:
            nid = g.add("select", [(pick(), 0), (pick(), 0), (pick(), 0)])
        else:
            op = _ALU_OPS[rng.randrange(len(_ALU_OPS))]
            nid = g.add(op, [(pick(), 0), (pick(), 0)])
        values.append(nid)

    if rng.random() < spec.p_carry:
        # Loop-carried deps run from a *late* producer back to an *early*
        # consumer: the C2 window t_d - t_s <= (1-dist)*II + lat - 1 means
        # a dist-1 edge needs the consumer no later than the producer and
        # a dist-2 edge needs >= II cycles of slack, so endpoints are
        # chosen asap-aware (a uniform choice makes ~half the corpus
        # structurally unmappable at every II — bad training signal).
        asap, _alap, _L = asap_alap(g)
        targets = sorted((nid for nid in g.nodes if g.nodes[nid].ins),
                         key=lambda nid: (asap[nid], nid))
        for _ in range(rng.randint(1, 2)):
            dst = targets[rng.randrange(max(1, len(targets) // 2))]
            dist = 1 if (spec.max_carry < 2 or rng.random() < 0.8) \
                else rng.randint(2, spec.max_carry)
            late = [nid for nid in g.nodes
                    if asap[nid] >= asap[dst] + (dist - 1)]
            if not late:
                dist, late = 1, [nid for nid in g.nodes
                                 if asap[nid] >= asap[dst]]
            src = late[rng.randrange(len(late))]
            ins = list(g.nodes[dst].ins)
            ins[rng.randrange(len(ins))] = (src, dist)
            g.nodes[dst].ins = tuple(ins)
        g.touch()
    g.validate()
    return g


def mutate_dfg(dfg: DFG, rng, kind: Optional[str] = None,
               spec: Optional[CorpusSpec] = None) -> Tuple[DFG, str]:
    """One mutation of ``dfg`` -> (mutant, kind). ``relabel`` permutes
    node ids (an isomorphic copy — the dedup stress case); the others
    change structure or semantics: ``op`` swaps an ALU opcode, ``imm``
    perturbs a constant, ``rewire`` re-sources a forward edge (topo-safe),
    ``grow`` appends a consumer node, ``carry`` re-distances or adds a
    loop-carried back-edge."""
    spec = spec or CorpusSpec()
    kind = kind or MUTATION_KINDS[rng.randrange(len(MUTATION_KINDS))]
    if kind == "relabel":
        order = list(dfg.nodes)
        rng.shuffle(order)
        g = _relabel_nodes(dfg, order)
        g.name = dfg.name + "~relabel"
        return g, kind

    g = copy.deepcopy(dfg)
    g.name = dfg.name + "~" + kind
    if kind == "op":
        cands = [nid for nid, nd in g.nodes.items() if nd.op in _ALU_OPS]
        if cands:
            nid = cands[rng.randrange(len(cands))]
            choices = [op for op in _ALU_OPS if op != g.nodes[nid].op]
            g.nodes[nid].op = choices[rng.randrange(len(choices))]
    elif kind == "imm":
        cands = [nid for nid, nd in g.nodes.items() if nd.op == "const"]
        if cands:
            nid = cands[rng.randrange(len(cands))]
            g.nodes[nid].imm += rng.randint(1, 97)
    elif kind == "rewire":
        topo = g.topo_order()
        pos = {nid: i for i, nid in enumerate(topo)}
        cands = [(nid, slot) for nid, nd in g.nodes.items()
                 for slot, (_src, dist) in enumerate(nd.ins)
                 if dist == 0 and pos[nid] > 0]
        if cands:
            nid, slot = cands[rng.randrange(len(cands))]
            earlier = topo[:pos[nid]]
            src = earlier[rng.randrange(len(earlier))]
            ins = list(g.nodes[nid].ins)
            ins[slot] = (src, 0)
            g.nodes[nid].ins = tuple(ins)
    elif kind == "grow":
        a = rng.randrange(g.n)
        b = rng.randrange(g.n)
        op = _ALU_OPS[rng.randrange(len(_ALU_OPS))]
        g.add(op, [(a, 0), (b, 0)])
    elif kind == "carry":
        back = [(nid, slot) for nid, nd in g.nodes.items()
                for slot, (_src, dist) in enumerate(nd.ins) if dist > 0]
        if back:
            nid, slot = back[rng.randrange(len(back))]
            ins = list(g.nodes[nid].ins)
            src, _dist = ins[slot]
            ins[slot] = (src, rng.randint(1, max(2, spec.max_carry)))
            g.nodes[nid].ins = tuple(ins)
        else:
            targets = [nid for nid in g.nodes if g.nodes[nid].ins]
            if targets:
                nid = targets[rng.randrange(len(targets))]
                ins = list(g.nodes[nid].ins)
                slot = rng.randrange(len(ins))
                ins[slot] = (rng.randrange(g.n),
                             rng.randint(1, max(1, spec.max_carry)))
                g.nodes[nid].ins = tuple(ins)
    else:
        raise ValueError(f"unknown mutation kind {kind!r}")
    g.touch()
    g.validate()
    return g, kind


def build_corpus(spec: CorpusSpec,
                 ) -> Tuple[List[CorpusItem], Dict[str, int]]:
    """Generate the deduplicated corpus for ``spec``: suite kernels (when
    included), ``n_random`` grammar DFGs, and ``n_mutants`` mutations of
    uniformly chosen parents. Returns ``(items, stats)`` where stats
    counts generated/unique/duplicate DFGs — ``duplicates > 0`` is the
    expected steady state because relabel mutants collapse onto their
    parents by construction."""
    import random as _random
    rng = _random.Random(spec.seed)
    items: List[CorpusItem] = []
    seen: Dict[bytes, str] = {}
    generated = 0

    def admit(name: str, dfg: DFG, kind: str) -> bool:
        nonlocal generated
        generated += 1
        key = canonical_key(dfg)
        if key in seen:
            return False
        seen[key] = name
        items.append(CorpusItem(name=name, dfg=dfg, key=key, kind=kind))
        return True

    if spec.include_suite:
        from . import suite
        for name in suite.names():
            admit(name, suite.get(name), "suite")
    for i in range(spec.n_random):
        admit(f"rand{i:04d}", random_dfg(rng, spec, f"rand{i:04d}"),
              "random")
    parents = list(items)
    for i in range(spec.n_mutants):
        if not parents:
            break
        parent = parents[rng.randrange(len(parents))]
        try:
            mutant, kind = mutate_dfg(parent.dfg, rng, spec=spec)
        except ValueError:
            continue                  # a rewire made a forward cycle
        admit(f"{parent.name}~m{i:03d}", mutant, f"mutant:{kind}")
    stats = {"generated": generated, "unique": len(items),
             "duplicates": generated - len(items)}
    return items, stats


def corpus_digest(items: Sequence[CorpusItem]) -> str:
    """SHA-256 over the canonical encoding of every item's canonical key
    and signature — equal digests mean byte-identical corpora (the
    cross-process determinism contract)."""
    import hashlib
    h = hashlib.sha256()
    for item in items:
        h.update(item.key)
        h.update(canonical_key(item.dfg))
    return h.hexdigest()


# ---------------------------------------------------------------- features

N_FEATURES = 31


def cell_features(dfg: DFG, fabric) -> np.ndarray:
    """Fixed-length float32 feature vector for one (DFG, fabric) cell:
    DFG statistics, the KMS mobility histogram (per-node ``alap - asap``
    window sizes — the II-independent shape of the paper's KMS), and
    fabric geometry/capability/latency summary. This is the *input
    contract* of :mod:`repro.core.guide` — extend only by appending and
    bumping ``N_FEATURES``."""
    from .arch import op_class
    lat = node_latencies(dfg, fabric)
    asap, alap, length = asap_alap(dfg, lat)
    n = max(1, dfg.n)
    edges = dfg.edges()
    back = [(s, d, dist) for s, d, dist in edges if dist > 0]
    fanout: Dict[int, int] = {}
    for s, _d, _dist in edges:
        fanout[s] = fanout.get(s, 0) + 1
    cls_counts = {"alu": 0, "mem": 0, "mul": 0}
    n_source = 0
    for nd in dfg.nodes.values():
        if nd.op in ("const", "iv"):
            n_source += 1
        cls_counts[op_class(nd.op)] += 1
    mob = np.array([alap[nid] - asap[nid] for nid in dfg.nodes],
                   dtype=np.int64)
    hist = np.bincount(np.clip(mob, 0, 5), minlength=6).astype(np.float32)
    hist /= n
    rmii = res_mii(dfg, fabric)
    rcmii = rec_mii(dfg, lat)
    mii = max(rmii, rcmii)
    rows = getattr(fabric, "rows", 0)
    cols = getattr(fabric, "cols", 0)
    n_pes = max(1, fabric.n_pes)
    deg = np.mean([len(fabric.neighbors(p))
                   for p in range(fabric.n_pes)]) if fabric.n_pes else 0.0
    regs = min(fabric.regs(p) for p in range(fabric.n_pes))
    lat_max = max(lat.values()) if lat else 1
    feats = [
        # --- DFG stats
        float(dfg.n),
        float(len(edges)),
        float(len(back)),
        float(max((dist for _s, _d, dist in back), default=0)),
        float(length),
        float(cls_counts["alu"]) / n,
        float(cls_counts["mem"]) / n,
        float(cls_counts["mul"]) / n,
        float(n_source) / n,
        float(max(fanout.values(), default=0)),
        float(sum(fanout.values())) / n,
        float(sum(1 for v in fanout.values() if v >= 2)) / n,
        # --- KMS mobility histogram + summary
        *hist.tolist(),                                       # 6 buckets
        float(mob.mean()) if mob.size else 0.0,
        float(mob.max()) if mob.size else 0.0,
        # --- lower bounds
        float(rmii),
        float(rcmii),
        float(mii),
        # --- fabric
        float(rows),
        float(cols),
        float(n_pes),
        float(len(fabric.pes_for_class("mem"))) / n_pes,
        float(len(fabric.pes_for_class("mul"))) / n_pes,
        float(deg),
        float(regs),
        float(lat_max),
    ]
    out = np.asarray(feats, dtype=np.float32)
    if out.shape != (N_FEATURES,):
        raise ValueError(f"cell feature vector has shape {out.shape}, "
                         f"expected ({N_FEATURES},) — keep N_FEATURES in "
                         f"sync with the feats list")
    return out


# ---------------------------------------------------------------- dataset


@dataclass
class CellRecord:
    """One campaign cell: everything the guide trainer (and any later
    analysis) needs, independent of the process that mapped it."""
    key: bytes                     # canonical cell key (dfg+fabric+config)
    dfg_key: bytes                 # canonical DFG key (corpus identity)
    name: str
    kind: str                      # corpus item kind
    fabric: str                    # fabric grammar name
    n_nodes: int
    features: np.ndarray           # float32[N_FEATURES]
    mii: int
    ii: Optional[int]              # final II (None when no mapping found)
    success: bool
    infeasible: bool
    attempts: Tuple[Tuple[int, str, str, float], ...]  # (ii, status, via, s)
    total_time: float
    sweep_width: int = 1
    witness: Optional[bytes] = None   # ClauseArena.to_bytes of the MII
    #                                   projection for refuted cells

    def to_payload(self) -> bytes:
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_payload(payload: bytes) -> "CellRecord":
        return pickle.loads(payload)

    @property
    def offset(self) -> Optional[int]:
        """The guide's training label: final II - MII (None = unmapped)."""
        return None if self.ii is None else self.ii - self.mii


class CampaignDataset:
    """Sharded campaign logs under ``path``: ``cells-<k>.log`` files of
    store-framed :data:`RT_CELL` records, routed by the cell key hash.
    Appends are flock-serialised per shard, so several campaign drivers
    may share one dataset directory; reads tolerate torn tails (truncated
    away implicitly) and stop at — but survive — corrupt shards."""

    def __init__(self, path: str, n_shards: int = 4):
        self.path = os.path.abspath(path)
        self.n_shards = max(1, n_shards)
        os.makedirs(self.path, exist_ok=True)
        self.corrupt_shards = 0

    def shard_path(self, shard: int) -> str:
        return os.path.join(self.path, f"cells-{shard:02d}.log")

    def shard_of(self, key: bytes) -> int:
        return struct.unpack("<Q", key[:8])[0] % self.n_shards

    def append(self, rec: CellRecord) -> None:
        shard = self.shard_of(rec.key)
        path = self.shard_path(shard)
        with _FileLock(path + ".lock", exclusive=True):
            with open(path, "ab") as f:
                write_framed(f, RT_CELL, rec.key, rec.to_payload())
                f.flush()

    def iter_cells(self) -> Iterator[CellRecord]:
        for shard in range(self.n_shards):
            path = self.shard_path(shard)
            if not os.path.exists(path):
                continue
            try:
                for rtype, _key, payload, _off, _end in iter_framed(path):
                    if rtype == RT_CELL:
                        yield CellRecord.from_payload(payload)
            except StoreCorruption:
                self.corrupt_shards += 1

    def __iter__(self) -> Iterator[CellRecord]:
        return self.iter_cells()

    def count(self) -> int:
        return sum(1 for _ in self.iter_cells())

    def describe(self) -> Dict[str, int]:
        sizes = [os.path.getsize(self.shard_path(s))
                 for s in range(self.n_shards)
                 if os.path.exists(self.shard_path(s))]
        return {"shards": self.n_shards, "bytes": sum(sizes),
                "cells": self.count(),
                "corrupt_shards": self.corrupt_shards}


# --------------------------------------------------------------- campaign


@dataclass
class CampaignStats:
    cells: int = 0
    mapped: int = 0
    failed: int = 0                # swept every II, no mapping
    infeasible: int = 0            # structurally impossible cells
    witnesses: int = 0
    wall_s: float = 0.0
    cells_per_sec: float = 0.0
    errors: int = 0

    def snapshot(self) -> Dict[str, float]:
        return dict(self.__dict__)


def cell_key(dfg_key: bytes, fabric, cfg, sweep_width: int) -> bytes:
    """Canonical key of one campaign cell (mirrors the service cache key
    but swaps the raw DFG signature for the isomorphism-invariant corpus
    key)."""
    from dataclasses import astuple
    return key_hash(("campaign-cell", dfg_key, topology_signature(fabric),
                     astuple(cfg), sweep_width))


def _mii_witness(dfg: DFG, fabric, amo: str,
                 max_clauses: int = 50_000) -> Optional[bytes]:
    """The MII projection's clause arena for a refuted cell — a compact,
    self-contained formula any process can re-solve to re-check the
    verdict (the same pattern as ``MappingStore.verify_core``)."""
    try:
        from .encode import EncoderSession
        from .schedule import min_ii
        mii = min_ii(dfg, fabric)
        enc = EncoderSession(dfg, fabric, amo).encode(mii)
        if enc.cnf.n_clauses > max_clauses:
            return None
        return enc.cnf.arena.to_bytes()
    except Exception:
        return None


def run_campaign(items: Sequence[CorpusItem], fabrics: Sequence,
                 pool, dataset: Optional[CampaignDataset] = None,
                 cfg=None, sweep_width: int = 1,
                 max_in_flight: int = 128,
                 witness_unsat: bool = True,
                 progress=None) -> Tuple[CampaignStats, List[CellRecord]]:
    """Map every (corpus item × fabric) cell through ``pool`` (a
    :class:`~repro.core.workers.WorkerPool` — or any object with the same
    ``submit``) and stream one :class:`CellRecord` per cell into
    ``dataset``. Returns (stats, records).

    Submission is bounded (``max_in_flight``) so a million-cell campaign
    never balloons the driver; records are appended as futures land.
    Structurally infeasible cells are recorded (they are real data — the
    guide must not be trained to predict IIs for them) and refuted cells
    get an MII-projection arena witness when ``witness_unsat``."""
    from collections import deque
    from .mapper import MapperConfig
    cfg = cfg or MapperConfig(timeout_s=30.0)
    stats = CampaignStats()
    records: List[CellRecord] = []
    t0 = time.time()

    grid = [(item, fabric) for item in items for fabric in fabrics]
    pending = deque()

    def harvest(block_one: bool) -> None:
        while pending and (block_one or pending[0][0].done()):
            fut, item, fabric, fname, feats = pending.popleft()
            block_one = False
            try:
                res = fut.result(timeout=max(60.0, 4 * cfg.timeout_s))
            except Exception:
                stats.errors += 1
                continue
            rec = _record_of(item, fabric, fname, feats, res, cfg,
                             sweep_width, witness_unsat)
            stats.cells += 1
            if rec.infeasible:
                stats.infeasible += 1
            elif rec.success:
                stats.mapped += 1
            else:
                stats.failed += 1
            if rec.witness is not None:
                stats.witnesses += 1
            if dataset is not None:
                dataset.append(rec)
            records.append(rec)
            if progress is not None:
                progress(stats)

    for item, fabric in grid:
        fname = str(fabric)
        try:
            feats = cell_features(item.dfg, fabric)
        except Infeasible:
            feats = None
        if feats is None:
            # res_mii-infeasible: record without ever touching the pool
            rec = CellRecord(
                key=cell_key(item.key, fabric, cfg, sweep_width),
                dfg_key=item.key, name=item.name, kind=item.kind,
                fabric=fname, n_nodes=item.dfg.n,
                features=np.zeros(N_FEATURES, dtype=np.float32),
                mii=0, ii=None, success=False, infeasible=True,
                attempts=(), total_time=0.0, sweep_width=sweep_width)
            stats.cells += 1
            stats.infeasible += 1
            if dataset is not None:
                dataset.append(rec)
            records.append(rec)
            continue
        fut = pool.submit(item.dfg, fabric, cfg, sweep_width=sweep_width)
        pending.append((fut, item, fabric, fname, feats))
        if len(pending) >= max_in_flight:
            harvest(block_one=True)
    while pending:
        harvest(block_one=True)

    stats.wall_s = time.time() - t0
    stats.cells_per_sec = stats.cells / max(stats.wall_s, 1e-9)
    return stats, records


def _record_of(item: CorpusItem, fabric, fname: str, feats: np.ndarray,
               res, cfg, sweep_width: int,
               witness_unsat: bool) -> CellRecord:
    attempts = tuple(
        (int(a.ii), str(a.status), str(a.via), float(a.solve_time))
        for a in res.attempts)
    infeasible = bool(res.infeasible)
    success = bool(res.success)
    witness = None
    if witness_unsat and not success and not infeasible:
        witness = _mii_witness(item.dfg, fabric, cfg.amo)
    return CellRecord(
        key=cell_key(item.key, fabric, cfg, sweep_width),
        dfg_key=item.key, name=item.name, kind=item.kind, fabric=fname,
        n_nodes=item.dfg.n, features=feats, mii=int(res.mii),
        ii=None if res.ii is None else int(res.ii), success=success,
        infeasible=infeasible, attempts=attempts,
        total_time=float(res.total_time), sweep_width=sweep_width,
        witness=witness)


__all__ = [
    "RT_CELL", "N_FEATURES", "MUTATION_KINDS",
    "CorpusSpec", "CorpusItem", "CellRecord", "CampaignDataset",
    "CampaignStats",
    "canonical_dfg", "canonical_key", "random_dfg", "mutate_dfg",
    "build_corpus", "corpus_digest", "cell_features", "cell_key",
    "run_campaign",
]

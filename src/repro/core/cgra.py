"""CGRA architecture model.

The target architecture (paper Fig. 1): a 2-D mesh of processing elements
(PEs). Each PE has a single-cycle ALU, ``n_regs`` local registers, and an
output register readable by its 4-neighbours in later cycles. Memory lines
give (by default all) PEs load/store access.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import FrozenSet, Tuple


@dataclass(frozen=True)
class CGRA:
    rows: int
    cols: int
    n_regs: int = 4
    topology: str = "mesh"  # "mesh" (paper) | "torus" | "diag"
    # PE ids with memory access; None -> all PEs can load/store (paper default)
    mem_pes: Tuple[int, ...] | None = None

    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    def coords(self, p: int) -> Tuple[int, int]:
        return divmod(p, self.cols)

    def pe(self, r: int, c: int) -> int:
        return r * self.cols + c

    @cached_property
    def _neighbors(self) -> Tuple[FrozenSet[int], ...]:
        out = []
        for p in range(self.n_pes):
            r, c = self.coords(p)
            deltas = [(-1, 0), (1, 0), (0, -1), (0, 1)]
            if self.topology == "diag":
                deltas += [(-1, -1), (-1, 1), (1, -1), (1, 1)]
            acc = set()
            for dr, dc in deltas:
                nr, nc = r + dr, c + dc
                if self.topology == "torus":
                    acc.add(self.pe(nr % self.rows, nc % self.cols))
                elif 0 <= nr < self.rows and 0 <= nc < self.cols:
                    acc.add(self.pe(nr, nc))
            out.append(frozenset(acc))
        return tuple(out)

    def neighbors(self, p: int) -> FrozenSet[int]:
        """PEs whose output register PE ``p``'s operands can read (excl. self)."""
        return self._neighbors[p]

    def reachable(self, src: int, dst: int) -> bool:
        """True if a value produced on ``src`` is directly consumable on ``dst``."""
        return src == dst or dst in self._neighbors[src]

    def can_mem(self, p: int) -> bool:
        return self.mem_pes is None or p in self.mem_pes

    def __str__(self) -> str:  # pragma: no cover
        return f"CGRA({self.rows}x{self.cols}, {self.topology}, {self.n_regs} regs)"


def cgra_from_name(name: str, **kw) -> CGRA:
    """'4x4' -> CGRA(4, 4)."""
    r, c = name.lower().split("x")
    return CGRA(int(r), int(c), **kw)

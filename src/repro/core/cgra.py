"""CGRA architecture model (legacy front end).

The target architecture (paper Fig. 1): a 2-D mesh of processing elements
(PEs). Each PE has a single-cycle ALU, ``n_regs`` local registers, and an
output register readable by its 4-neighbours in later cycles. Memory lines
give (by default all) PEs load/store access.

:class:`CGRA` is kept as a thin adapter over the declarative
:class:`repro.core.arch.ArchSpec`: the homogeneous ``spec`` it constructs
is the single source of truth for neighbour tables, capability checks, and
the service-keying ``signature()``, so a ``CGRA(4, 4)`` and an
``arch("4x4")`` describe — and pool as — the identical fabric. New code
(and every heterogeneous fabric) should use ``ArchSpec`` /
:func:`repro.core.arch.arch` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import FrozenSet, Tuple

from .arch import OP_CLASSES, ArchSpec, parse_fabric


@dataclass(frozen=True)
class CGRA:
    rows: int
    cols: int
    n_regs: int = 4
    # "mesh" (paper) | "torus" | "diag" | "onehop" (HyCUBE-style bypass)
    topology: str = "mesh"
    # PE ids with memory access; None -> all PEs can load/store (paper default)
    mem_pes: Tuple[int, ...] | None = None
    # per-op-class latency table as sorted (cls, cycles) items; None -> the
    # paper's all-unit-latency model
    latencies: Tuple[Tuple[str, int], ...] | None = None

    @cached_property
    def spec(self) -> ArchSpec:
        """The equivalent homogeneous :class:`ArchSpec` (ground truth for
        neighbours, capabilities, latencies, and the service signature)."""
        caps = None
        if self.mem_pes is not None:
            with_mem = frozenset(OP_CLASSES)
            without = with_mem - {"mem"}
            mem = set(self.mem_pes)
            caps = tuple(with_mem if p in mem else without
                         for p in range(self.rows * self.cols))
        return ArchSpec(self.rows, self.cols, self.topology,
                        pe_caps=caps, pe_regs=self.n_regs,
                        op_lat=self.latencies)

    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    def coords(self, p: int) -> Tuple[int, int]:
        return divmod(p, self.cols)

    def pe(self, r: int, c: int) -> int:
        return r * self.cols + c

    def neighbors(self, p: int) -> FrozenSet[int]:
        """PEs whose output register PE ``p``'s operands can read (excl. self)."""
        return self.spec._neighbors[p]

    def reachable(self, src: int, dst: int) -> bool:
        """True if a value produced on ``src`` is directly consumable on ``dst``."""
        return self.spec.reachable(src, dst)

    def can_mem(self, p: int) -> bool:
        return self.spec.can_mem(p)

    def can_execute(self, p: int, op: str) -> bool:
        return self.spec.can_execute(p, op)

    def pes_for(self, op: str) -> Tuple[int, ...]:
        return self.spec.pes_for(op)

    def pes_for_class(self, cls: str) -> Tuple[int, ...]:
        return self.spec.pes_for_class(cls)

    def regs(self, p: int) -> int:
        return self.n_regs

    def lat(self, cls: str) -> int:
        """Latency (cycles) of op class ``cls`` (1 unless ``latencies``
        says otherwise)."""
        return self.spec.lat(cls)

    def lat_of(self, op: str) -> int:
        return self.spec.lat_of(op)

    def signature(self) -> Tuple:
        return self.spec.signature()

    def __str__(self) -> str:  # pragma: no cover
        return f"CGRA({self.rows}x{self.cols}, {self.topology}, {self.n_regs} regs)"


def cgra_from_name(name: str, **kw) -> CGRA:
    """'4x4' -> CGRA(4, 4); the grammar also carries the interconnect,
    register count, and op-class latencies: '4x4-torus' ->
    CGRA(4, 4, topology="torus"), '8x8:r8' -> CGRA(8, 8, n_regs=8),
    '4x4:mul2:mem2' -> 2-cycle multipliers and memory ports,
    '4x4-onehop:r2' combines suffixes. Explicit keyword arguments win
    over name suffixes."""
    rows, cols, interconnect, regs, lats = parse_fabric(name)
    if interconnect == "custom":
        raise ValueError("custom adjacency needs repro.core.arch.arch(), "
                         "not cgra_from_name()")
    kw.setdefault("topology", interconnect)
    if regs is not None:
        kw.setdefault("n_regs", regs)
    if lats:
        kw.setdefault("latencies", tuple(sorted(lats.items())))
    return CGRA(rows, cols, **kw)

"""Declarative CGRA architecture specification.

The paper targets one fixed fabric (Fig. 1: a 4-neighbour mesh of identical
PEs), but the SAT formulation only ever reads two things off the hardware:
a *reachability* relation (which PE's output register can each PE consume)
and per-PE *capabilities* (which operations may execute where, how many
local registers back them). :class:`ArchSpec` makes exactly those two
things declarative data, so real CGRA variants — HyCUBE-style one-hop
bypass links, memory-restricted PE columns, heterogeneous multiplier
placement — are a spec change, not a code change.

Operations are grouped into *op classes*; a PE's capability set says which
classes it executes:

  * ``"mem"`` — ``load`` / ``store`` (the paper's memory-line access),
  * ``"mul"`` — ``mul`` / ``div`` / ``rem`` (the expensive functional unit
    real fabrics place sparsely),
  * ``"alu"`` — everything else (single-cycle ALU ops).

Op classes also carry a *latency* (``ArchSpec.lat(cls)``, cycles from
issue to result availability). The paper's fabric is fully unit-latency;
HyCUBE/ADRES-class fabrics pipeline multipliers and memory ports over
2+ cycles. Latencies default to 1 everywhere — and with every latency 1
the whole mapping pipeline (KMS windows, CNF, register allocation,
simulator) is bit-identical to the unit-latency model, so unit fabrics
keep their exact pre-latency signatures and pooled solver sessions.

Interconnects: ``"mesh"`` (paper Fig. 1), ``"torus"`` (wrap-around),
``"diag"`` (8-neighbour), ``"onehop"`` (mesh plus one-hop bypass links two
steps along each row/column, HyCUBE-flavoured), and ``"custom"`` (an
explicit adjacency list).

The :func:`arch` builder parses compact fabric names —

    arch("4x4")                          # the paper's homogeneous mesh
    arch("4x4-torus", regs=8)            # wrap-around links, 8 regs per PE
    arch("8x8:r8")                       # ':rN' register-count suffix
    arch("4x4-onehop", mem="col0")       # loads/stores only on column 0
    arch("4x4", mul="corners", mem="row0")
    arch("4x4-torus:r8:mul2:mem2")       # 2-cycle multipliers + memory
    arch("4x4", lat={"mul": 3})          # explicit latency table

— where ``mem=`` / ``mul=`` / ``alu=`` restrict an op class to a *region*
(``"all"``, ``"none"``, ``"colK"``, ``"rowK"``, ``"corners"``,
``"border"``, ``"even"``/``"odd"`` checkerboards, or an explicit iterable
of PE ids). ``ArchSpec.signature()`` is the stable key the mapping service
pools solver sessions by; the legacy :class:`repro.core.cgra.CGRA` adapter
delegates here, so equivalent homogeneous fabrics share one signature (and
one pooled session) regardless of which front-end class described them.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Dict, FrozenSet, Iterable, Optional, Sequence, Tuple, Union

# ------------------------------------------------------------- op classes

OP_CLASS_OF: Dict[str, str] = {
    "load": "mem", "store": "mem",
    "mul": "mul", "div": "mul", "rem": "mul",
}
OP_CLASSES: Tuple[str, ...] = ("alu", "mem", "mul")

INTERCONNECTS: Tuple[str, ...] = ("mesh", "torus", "diag", "onehop",
                                  "custom")
_TOPO_ALIASES = {"": "mesh", "mesh": "mesh", "torus": "torus",
                 "diag": "diag", "diagonal": "diag",
                 "onehop": "onehop", "one-hop": "onehop", "1hop": "onehop",
                 "hycube": "onehop", "custom": "custom"}


def op_class(op: str) -> str:
    """The resource class a DFG op occupies ("alu" | "mem" | "mul")."""
    return OP_CLASS_OF.get(op, "alu")


# ---------------------------------------------------------------- regions


def region(spec, rows: int, cols: int) -> FrozenSet[int]:
    """Resolve a region spec to a set of PE ids on a rows x cols grid.

    ``None``/``"all"`` -> every PE; ``"none"`` -> no PE; ``"colK"`` /
    ``"rowK"`` (K may be negative, python-style) -> one column/row;
    ``"corners"`` / ``"border"`` / ``"even"`` / ``"odd"`` -> the obvious
    geometric subsets; any iterable of ints -> those PE ids.
    """
    n = rows * cols
    if spec is None:
        return frozenset(range(n))
    if isinstance(spec, str):
        s = spec.strip().lower()
        if s == "all":
            return frozenset(range(n))
        if s == "none":
            return frozenset()
        if s.startswith("col") or s.startswith("row"):
            try:
                k = int(s[3:])
            except ValueError:
                raise ValueError(f"bad region {spec!r}: expected "
                                 f"'{s[:3]}<int>'") from None
            if s.startswith("col"):
                k %= cols
                return frozenset(r * cols + k for r in range(rows))
            k %= rows
            return frozenset(k * cols + c for c in range(cols))
        if s == "corners":
            return frozenset({0, cols - 1, (rows - 1) * cols, n - 1})
        if s == "border":
            return frozenset(r * cols + c for r in range(rows)
                             for c in range(cols)
                             if r in (0, rows - 1) or c in (0, cols - 1))
        if s in ("even", "odd"):
            want = 0 if s == "even" else 1
            return frozenset(r * cols + c for r in range(rows)
                             for c in range(cols) if (r + c) % 2 == want)
        raise ValueError(f"unknown region {spec!r}")
    try:
        pes = frozenset(int(p) for p in spec)
    except TypeError:
        raise ValueError(f"bad region {spec!r}: expected a region name or "
                         f"an iterable of PE ids") from None
    for p in pes:
        if not 0 <= p < n:
            raise ValueError(f"region PE id {p} outside [0, {n})")
    return pes


# ----------------------------------------------------------- fabric names


def parse_fabric(name: str) -> Tuple[int, int, str, Optional[int],
                                     Dict[str, int]]:
    """Parse ``"RxC[-topology][:rN][:clsK...]"`` ->
    (rows, cols, interconnect, regs, latencies).

    ``regs`` is None when the name carries no ``:rN`` suffix. Any number
    of ``:aluK`` / ``:memK`` / ``:mulK`` suffixes set that op class's
    latency to K cycles (``latencies`` is {} when none appear). Examples:
    ``"4x4"``, ``"4x4-torus"``, ``"8x8:r8"``, ``"4x4-one-hop:r2"``,
    ``"4x4-torus:r8:mul2:mem2"``.
    """
    parts = name.strip().split(":")
    base, regs, lats = parts[0], None, {}
    for suf in parts[1:]:
        s = suf.strip().lower()
        if s.startswith("r") and s[1:].isdigit():
            regs = int(s[1:])
        elif s[:3] in OP_CLASSES and s[3:].isdigit():
            lats[s[:3]] = int(s[3:])
        else:
            raise ValueError(f"bad fabric suffix {s!r} in {name!r} "
                             f"(expected ':rN' or ':aluK'/':memK'/':mulK', "
                             f"e.g. '4x4:r8:mul2')")
    geom, _, topo = base.partition("-")
    interconnect = _TOPO_ALIASES.get(topo.strip().lower())
    if interconnect is None:
        raise ValueError(f"unknown interconnect {topo!r} in {name!r} "
                         f"(know: {', '.join(sorted(set(_TOPO_ALIASES) - {''}))})")
    r, x, c = geom.strip().lower().partition("x")
    if x != "x" or not (r.isdigit() and c.isdigit()):
        raise ValueError(f"bad fabric geometry {geom!r} in {name!r} "
                         f"(expected 'RxC', e.g. '4x4')")
    return int(r), int(c), interconnect, regs, lats


# ----------------------------------------------------------------- spec


@dataclass(frozen=True)
class ArchSpec:
    """Declarative CGRA fabric: geometry + interconnect + per-PE
    capability sets + per-PE register counts.

    ``pe_caps[p]`` is the frozenset of op classes PE ``p`` executes
    (``None`` normalises to "every class everywhere" — the paper's
    homogeneous fabric). ``pe_regs`` is per-PE local register counts (an
    int normalises to a uniform tuple). ``adjacency`` (required iff
    ``interconnect == "custom"``) lists, per PE, the PEs whose operands
    may read *its* output register. ``op_lat`` is the per-op-class
    latency table (mapping or item tuple, cycles from issue to result);
    absent classes — and ``None`` — mean unit latency, and an all-unit
    table normalises to ``None`` so unit-latency fabrics compare and
    ``signature()`` exactly as before latencies existed.
    """
    rows: int
    cols: int
    interconnect: str = "mesh"
    pe_caps: Optional[Tuple[FrozenSet[str], ...]] = None
    pe_regs: Union[int, Tuple[int, ...]] = 4
    adjacency: Optional[Tuple[Tuple[int, ...], ...]] = None
    op_lat: Optional[Tuple[Tuple[str, int], ...]] = None
    name: str = ""

    def __post_init__(self):
        if self.rows < 1 or self.cols < 1:
            raise ValueError(f"bad geometry {self.rows}x{self.cols}")
        inter = _TOPO_ALIASES.get(str(self.interconnect).strip().lower())
        if inter is None:
            raise ValueError(f"unknown interconnect {self.interconnect!r}")
        object.__setattr__(self, "interconnect", inter)
        n = self.rows * self.cols
        # capabilities: None -> homogeneous (all classes on every PE)
        if self.pe_caps is None:
            caps = tuple(frozenset(OP_CLASSES) for _ in range(n))
        else:
            caps = tuple(frozenset(c) for c in self.pe_caps)
            if len(caps) != n:
                raise ValueError(f"pe_caps has {len(caps)} entries for "
                                 f"{n} PEs")
            for p, cs in enumerate(caps):
                bad = cs - set(OP_CLASSES)
                if bad:
                    raise ValueError(f"PE {p}: unknown op classes {bad}")
        object.__setattr__(self, "pe_caps", caps)
        # registers: int -> uniform per-PE tuple
        regs = self.pe_regs
        if isinstance(regs, int):
            regs = (regs,) * n
        else:
            regs = tuple(int(r) for r in regs)
        if len(regs) != n:
            raise ValueError(f"pe_regs has {len(regs)} entries for {n} PEs")
        if any(r < 0 for r in regs):
            raise ValueError("negative register count")
        object.__setattr__(self, "pe_regs", regs)
        # latencies: mapping/items -> canonical sorted tuple; all-unit -> None
        if self.op_lat is not None:
            lat = dict(self.op_lat)
            bad = set(lat) - set(OP_CLASSES)
            if bad:
                raise ValueError(f"unknown op classes in op_lat: {bad}")
            lat = {c: int(v) for c, v in lat.items()}
            if any(v < 1 for v in lat.values()):
                raise ValueError("op latencies must be >= 1 cycle")
            lat = {c: v for c, v in lat.items() if v != 1}
            object.__setattr__(self, "op_lat",
                               tuple(sorted(lat.items())) or None)
        # adjacency: custom interconnect only; normalised (sorted, no self)
        if (self.adjacency is None) != (inter != "custom"):
            raise ValueError("adjacency is required iff "
                             "interconnect == 'custom'")
        if self.adjacency is not None:
            adj = tuple(tuple(sorted({int(q) for q in row} - {p}))
                        for p, row in enumerate(self.adjacency))
            if len(adj) != n:
                raise ValueError(f"adjacency has {len(adj)} rows for "
                                 f"{n} PEs")
            for p, row in enumerate(adj):
                for q in row:
                    if not 0 <= q < n:
                        raise ValueError(f"adjacency[{p}]: PE id {q} "
                                         f"outside [0, {n})")
            object.__setattr__(self, "adjacency", adj)

    # ----------------------------------------------------------- geometry
    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    def coords(self, p: int) -> Tuple[int, int]:
        return divmod(p, self.cols)

    def pe(self, r: int, c: int) -> int:
        return r * self.cols + c

    # ------------------------------------------------------- interconnect
    @cached_property
    def _neighbors(self) -> Tuple[FrozenSet[int], ...]:
        if self.interconnect == "custom":
            return tuple(frozenset(row) for row in self.adjacency)
        deltas = [(-1, 0), (1, 0), (0, -1), (0, 1)]
        if self.interconnect == "diag":
            deltas += [(-1, -1), (-1, 1), (1, -1), (1, 1)]
        elif self.interconnect == "onehop":
            # HyCUBE-style one-hop bypass: a value also crosses *two* PEs
            # along a straight row/column in a single cycle
            deltas += [(-2, 0), (2, 0), (0, -2), (0, 2)]
        out = []
        for p in range(self.n_pes):
            r, c = self.coords(p)
            acc = set()
            for dr, dc in deltas:
                nr, nc = r + dr, c + dc
                if self.interconnect == "torus":
                    q = self.pe(nr % self.rows, nc % self.cols)
                    # degenerate grids (1 row/col, 2-wide wrap) can fold a
                    # delta back onto p itself; neighbours exclude self by
                    # contract, so drop those wraparounds here
                    if q != p:
                        acc.add(q)
                elif 0 <= nr < self.rows and 0 <= nc < self.cols:
                    acc.add(self.pe(nr, nc))
            out.append(frozenset(acc))
        return tuple(out)

    def neighbors(self, p: int) -> FrozenSet[int]:
        """PEs whose operands can read PE ``p``'s output register
        (excl. self)."""
        return self._neighbors[p]

    def reachable(self, src: int, dst: int) -> bool:
        """True if a value produced on ``src`` is directly consumable on
        ``dst``."""
        return src == dst or dst in self._neighbors[src]

    # ------------------------------------------------------- capabilities
    @cached_property
    def _pes_by_class(self) -> Dict[str, Tuple[int, ...]]:
        return {cls: tuple(p for p in range(self.n_pes)
                           if cls in self.pe_caps[p])
                for cls in OP_CLASSES}

    def can_execute(self, p: int, op: str) -> bool:
        """True if PE ``p`` supports the op class of DFG op ``op``."""
        return op_class(op) in self.pe_caps[p]

    def pes_for(self, op: str) -> Tuple[int, ...]:
        """Ascending PE ids able to execute ``op`` (the encoder's
        allowed-PE set for a node with that op)."""
        return self._pes_by_class[op_class(op)]

    def pes_for_class(self, cls: str) -> Tuple[int, ...]:
        return self._pes_by_class[cls]

    def can_mem(self, p: int) -> bool:
        return "mem" in self.pe_caps[p]

    def regs(self, p: int) -> int:
        """Local register count of PE ``p``."""
        return self.pe_regs[p]

    # ----------------------------------------------------------- latencies
    @cached_property
    def _lat_map(self) -> Dict[str, int]:
        return dict(self.op_lat or ())

    def lat(self, cls: str) -> int:
        """Latency (cycles, >= 1) of op class ``cls``; classes absent
        from the table are single-cycle."""
        return self._lat_map.get(cls, 1)

    def lat_of(self, op: str) -> int:
        """Latency of the DFG op ``op`` (via its op class)."""
        return self._lat_map.get(op_class(op), 1)

    @property
    def unit_latency(self) -> bool:
        """True when every op class is single-cycle (the paper's model)."""
        return self.op_lat is None

    # ----------------------------------------------------------- identity
    def signature(self) -> Tuple:
        """Stable hashable identity of everything the encoding, register
        allocation, and simulator read off the fabric — the mapping
        service's solver-pool / result-cache key component. The latency
        table is appended only when some class is multi-cycle, so
        unit-latency fabrics keep their exact pre-latency signatures
        (existing caches, pooled sessions, and proven-UNSAT registries
        stay valid)."""
        sig = ("arch", self.rows, self.cols, self.interconnect,
               self.adjacency,
               tuple(tuple(sorted(c)) for c in self.pe_caps),
               self.pe_regs)
        if self.op_lat is not None:
            sig = sig + (("lat",) + self.op_lat,)
        return sig

    def __str__(self) -> str:  # pragma: no cover
        n = self.n_pes
        regs = (str(self.pe_regs[0]) if len(set(self.pe_regs)) == 1
                else f"{min(self.pe_regs)}-{max(self.pe_regs)}")
        parts = [f"{self.rows}x{self.cols}-{self.interconnect}",
                 f"regs={regs}"]
        for cls in ("mem", "mul"):
            k = len(self._pes_by_class[cls])
            if k != n:
                parts.append(f"{cls}={k}/{n}")
        if self.op_lat is not None:
            parts.append("lat=" + ",".join(f"{c}:{v}" for c, v in self.op_lat))
        label = f" {self.name!r}" if self.name else ""
        return f"Arch({', '.join(parts)}{label})"


# ---------------------------------------------------------------- builder


def arch(name: str = "4x4", *, regs=None, mem=None, mul=None, alu=None,
         lat: Optional[Dict[str, int]] = None,
         adjacency: Optional[Sequence[Iterable[int]]] = None) -> ArchSpec:
    """Build an :class:`ArchSpec` from a compact fabric name plus optional
    heterogeneity knobs.

    ``name`` follows ``"RxC[-topology][:rN][:clsK...]"`` (see
    :func:`parse_fabric`). ``regs`` overrides the register count (int, or
    a per-PE sequence). ``mem`` / ``mul`` / ``alu`` restrict that op
    class to a *region* (see :func:`region`); unset classes stay
    available on every PE. ``lat`` is a per-op-class latency table
    ({"mul": 2, ...}; entries win over the name's ``:mulK``-style
    suffixes, unset classes are single-cycle). ``adjacency`` switches the
    interconnect to ``"custom"`` with the given per-PE consumer lists.
    """
    rows, cols, interconnect, suffix_regs, suffix_lat = parse_fabric(name)
    if adjacency is not None:
        interconnect = "custom"
        adjacency = tuple(tuple(row) for row in adjacency)
    if regs is None:
        regs = suffix_regs if suffix_regs is not None else 4
    lat_map = dict(suffix_lat)
    if lat:
        lat_map.update(lat)
    n = rows * cols
    caps = [set(OP_CLASSES) for _ in range(n)]
    for cls, spec in (("mem", mem), ("mul", mul), ("alu", alu)):
        if spec is None:
            continue
        allowed = region(spec, rows, cols)
        for p in range(n):
            if p not in allowed:
                caps[p].discard(cls)
    return ArchSpec(rows, cols, interconnect,
                    tuple(frozenset(c) for c in caps),
                    regs if isinstance(regs, int) else tuple(regs),
                    adjacency=adjacency,
                    op_lat=tuple(sorted(lat_map.items())) or None,
                    name=name)

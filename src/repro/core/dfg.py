"""Data Flow Graph: the mapper's input IR.

Nodes are single-output operations; edges carry a loop-carried *distance*
(0 = intra-iteration dependency, d>=1 = value produced d iterations earlier,
i.e. a back-edge). The DFG is executable (``execute``) — that execution is
the ground-truth oracle against which every CGRA mapping is validated by the
simulator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

import numpy as np

# Ops with value semantics used by the executable oracle. All 1-cycle on the
# CGRA ALU (paper model).
_BINOPS: Dict[str, Callable[[int, int], int]] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "shl": lambda a, b: a << (b & 63),
    "shr": lambda a, b: (a % (1 << 64)) >> (b & 63),
    "min": min,
    "max": max,
    "lt": lambda a, b: int(a < b),
    "le": lambda a, b: int(a <= b),
    "eq": lambda a, b: int(a == b),
    "ne": lambda a, b: int(a != b),
    "div": lambda a, b: a // b if b else 0,
    "rem": lambda a, b: a % b if b else 0,
}
_MASK64 = (1 << 64) - 1


def _wrap(v: int) -> int:
    """Two's-complement wrap to signed 64-bit (keeps python ints bounded)."""
    v &= _MASK64
    return v - (1 << 64) if v >= (1 << 63) else v


@dataclass
class Node:
    id: int
    op: str
    # dataflow inputs: (src node id, loop-carried distance)
    ins: Tuple[Tuple[int, int], ...] = ()
    imm: int = 0          # payload for 'const'; base address for load/store
    name: str = ""

    @property
    def is_mem(self) -> bool:
        return self.op in ("load", "store")


class DFG:
    def __init__(self, name: str = "dfg"):
        self.name = name
        self.nodes: Dict[int, Node] = {}
        # memoized canonical signatures (see repro.core.service): computed
        # from scratch they walk every node and edge, which dominates the
        # cache-lookup path of a hot mapping service. Any structural
        # mutation must invalidate — add() does so itself; direct edits of
        # ``node.ins`` (back-edge patching, route splicing) must call
        # ``touch()``.
        self._sig_cache: Dict[Tuple, Tuple] = {}

    # ---------------------------------------------------------------- build
    def add(self, op: str, ins: Sequence[Tuple[int, int]] = (), imm: int = 0,
            name: str = "") -> int:
        nid = len(self.nodes)
        for src, dist in ins:
            if src not in self.nodes:
                raise ValueError(f"unknown source node {src}")
            if dist < 0:
                raise ValueError("negative edge distance")
        self.nodes[nid] = Node(nid, op, tuple(tuple(e) for e in ins), imm, name)
        self._sig_cache.clear()
        return nid

    def touch(self) -> None:
        """Invalidate memoized signatures after in-place node mutation."""
        self._sig_cache.clear()

    def __deepcopy__(self, memo):
        import copy as _copy
        g = DFG(self.name)
        memo[id(self)] = g
        g.nodes = _copy.deepcopy(self.nodes, memo)
        return g   # fresh empty _sig_cache: copies are usually mutated next

    # --------------------------------------------------------------- views
    @property
    def n(self) -> int:
        return len(self.nodes)

    def edges(self) -> List[Tuple[int, int, int]]:
        """(src, dst, distance) triples."""
        out = []
        for node in self.nodes.values():
            for src, dist in node.ins:
                out.append((src, node.id, dist))
        return out

    def forward_edges(self) -> List[Tuple[int, int]]:
        return [(s, d) for s, d, dist in self.edges() if dist == 0]

    def succs(self, nid: int, *, forward_only: bool = True) -> List[int]:
        return [d for s, d, dist in self.edges()
                if s == nid and (dist == 0 or not forward_only)]

    def preds(self, nid: int, *, forward_only: bool = True) -> List[int]:
        return [s for s, dist in self.nodes[nid].ins
                if dist == 0 or not forward_only]

    def topo_order(self) -> List[int]:
        """Topological order over forward (distance-0) edges."""
        indeg = {i: 0 for i in self.nodes}
        for s, d in self.forward_edges():
            indeg[d] += 1
        ready = sorted(i for i, k in indeg.items() if k == 0)
        order: List[int] = []
        while ready:
            nid = ready.pop(0)
            order.append(nid)
            for s, d in self.forward_edges():
                if s == nid:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        ready.append(d)
        if len(order) != self.n:
            raise ValueError(f"{self.name}: forward edges contain a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()  # raises on forward cycles
        for node in self.nodes.values():
            if node.op in _BINOPS and len(node.ins) != 2:
                raise ValueError(f"{node.op} node {node.id} needs 2 inputs")
            if node.op == "select" and len(node.ins) != 3:
                raise ValueError(f"select node {node.id} needs 3 inputs")
            if node.op in ("route", "not", "neg") and len(node.ins) != 1:
                raise ValueError(f"{node.op} node {node.id} needs 1 input")

    # ------------------------------------------------------------- execute
    def execute(self, n_iters: int, mem: Dict[int, int] | None = None,
                init: Dict[int, int] | None = None,
                ) -> Tuple[List[Dict[int, int]], Dict[int, int]]:
        """Reference loop execution: ``n_iters`` iterations of the body.

        Returns (per-iteration node values, final memory). ``init[nid]`` seeds
        loop-carried reads that reach before iteration 0 (defaults 0).
        """
        mem = dict(mem or {})
        init = init or {}
        order = self.topo_order()
        hist: List[Dict[int, int]] = []
        for it in range(n_iters):
            vals: Dict[int, int] = {}
            for nid in order:
                node = self.nodes[nid]
                args = []
                for src, dist in node.ins:
                    if dist == 0:
                        args.append(vals[src])
                    elif it - dist >= 0:
                        args.append(hist[it - dist][src])
                    else:
                        args.append(init.get(src, 0))
                vals[nid] = _wrap(self._eval(node, args, it, mem))
            hist.append(vals)
        return hist, mem

    def _eval(self, node: Node, args: List[int], it: int,
              mem: Dict[int, int]) -> int:
        op = node.op
        if op in _BINOPS:
            return _BINOPS[op](args[0], args[1])
        if op == "const":
            return node.imm
        if op == "iv":
            return it
        if op in ("route", "phi"):
            return args[0]
        if op == "not":
            return ~args[0]
        if op == "neg":
            return -args[0]
        if op == "select":
            return args[1] if args[0] else args[2]
        if op == "load":
            return mem.get(node.imm + (args[0] if args else 0), 0)
        if op == "store":
            mem[node.imm + args[0]] = args[1]
            return args[1]
        raise ValueError(f"unknown op {op!r}")

    def __repr__(self) -> str:  # pragma: no cover
        return f"DFG({self.name}, n={self.n}, edges={len(self.edges())})"


def running_example() -> DFG:
    """The paper's running example (Fig. 2a), reconstructed so that the
    ASAP/ALAP/MS tables of Fig. 4 are reproduced exactly (11 nodes, critical
    path 5, ResMII 3 on a 2x2 CGRA -> II=3 as in Fig. 2b/2c). A distance-1
    back-edge (11 -> 10) gives it a loop-carried dependency as in Fig. 2a.
    Node ids here are 0-based (paper's are 1-based)."""
    g = DFG("running_example")
    n1 = g.add("iv", name="n1")                      # paper node 1
    n2 = g.add("const", imm=3, name="n2")            # paper node 2
    n3 = g.add("const", imm=7, name="n3")            # paper node 3
    n4 = g.add("const", imm=11, name="n4")           # paper node 4
    n5 = g.add("add", [(n3, 0), (n3, 0)], name="n5")   # paper node 5
    n7 = g.add("mul", [(n4, 0), (n4, 0)], name="n7")   # paper node 7
    n10 = g.add("add", [(n1, 0), (n1, 0)], name="n10")  # paper node 10
    n6 = g.add("xor", [(n5, 0), (n5, 0)], name="n6")   # paper node 6
    n11_in = n10
    n11 = g.add("add", [(n2, 0), (n11_in, 0)], name="n11")  # paper node 11
    n8 = g.add("add", [(n6, 0), (n7, 0)], name="n8")   # paper node 8
    n9 = g.add("mul", [(n8, 0), (n8, 0)], name="n9")   # paper node 9
    # loop-carried: node 10 also accumulates node 11 from previous iteration
    g.nodes[n10].ins = ((n1, 0), (n11, 1))
    g.touch()
    g.validate()
    return g

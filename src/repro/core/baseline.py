"""Heuristic modulo-scheduling baseline (the paper's SoA comparators).

RAMP [13] and PathSeeker [15] are heuristic mappers: priority-ordered
iterative placement with local adjustment (ejection) and randomized restarts
(PathSeeker is explicitly randomized; the paper reruns it 10x). This module
implements that family faithfully enough to serve as the comparison line in
our Fig. 6 / Tables I-IV reproduction:

  * node priority: height (longest path to a sink), critical nodes first;
  * placement scans the node's mobility window x PEs for a slot compatible
    with already-placed neighbours (same C3 timing window as the SAT
    encoding, so both mappers search the same space);
  * on conflict: bounded ejection of blocking nodes (PathSeeker-style local
    adjustment), then randomized restart (CRIMSON-style), then II+1.

It is complete in the limit of infinite restarts but — like the SoA tools —
greedy per step, so it misses solutions in tightly constrained instances
(2x2 CGRAs) where SAT-MapIt succeeds. That asymmetry is the paper's headline
result and is reproduced in benchmarks/fig6_ii.py.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .cgra import CGRA
from .dfg import DFG
from .mapper import IIAttempt, MappingResult
from .regalloc import allocate
from .schedule import Infeasible, asap_alap, min_ii, node_latencies
from .simulator import verify_mapping


@dataclass
class BaselineConfig:
    n_restarts: int = 50
    max_ejects: int = 200
    max_ii: Optional[int] = None
    timeout_s: float = 4000.0
    seed: int = 0
    verify_iters: int = 6


def _heights(dfg: DFG) -> Dict[int, int]:
    order = dfg.topo_order()
    h = {n: 0 for n in order}
    for n in reversed(order):
        for d in dfg.succs(n):
            h[n] = max(h[n], h[d] + 1)
    return h


def _attempt(dfg: DFG, cgra: CGRA, ii: int, rng: random.Random,
             max_ejects: int) -> Optional[Dict[int, Tuple[int, int, int]]]:
    lat = node_latencies(dfg, cgra)
    # uniform latencies make a completion clash imply an issue clash (the
    # slot dict already forbids those), so the write-port scan below is
    # needed only on mixed-latency fabrics
    mixed_lat = len(set(lat.values())) > 1
    asap, alap, _ = asap_alap(dfg, lat)
    heights = _heights(dfg)
    prio = sorted(dfg.nodes, key=lambda n: (-heights[n], rng.random()))
    place: Dict[int, Tuple[int, int]] = {}       # n -> (pe, flat t)
    slot: Dict[Tuple[int, int], int] = {}        # (pe, t mod II) -> n
    queue = list(prio)
    ejects = 0

    in_edges = {n: [(s, dd) for s, d, dd in dfg.edges() if d == n]
                for n in dfg.nodes}
    out_edges = {n: [(d, dd) for s, d, dd in dfg.edges() if s == n]
                 for n in dfg.nodes}

    def compatible(n: int, p: int, t: int) -> bool:
        # the same latency-shifted C3 window the SAT encoding uses:
        # lat(producer) <= span <= II + lat(producer) - 1
        node = dfg.nodes[n]
        if not cgra.can_execute(p, node.op):
            return False
        # output-register write-port conflict: a mixed-latency neighbour
        # on this PE completing in our completion cycle (same-issue-slot
        # clashes are handled by the slot dict / ejection path instead)
        if mixed_lat:
            for m, (pm, tm) in place.items():
                if pm == p and tm % ii != t % ii \
                        and (tm + lat[m]) % ii == (t + lat[n]) % ii:
                    return False
        for s, dd in in_edges[n]:
            if s in place:
                ps, ts = place[s]
                if not cgra.reachable(ps, p):
                    return False
                if not (lat[s] <= t - ts + dd * ii <= ii + lat[s] - 1):
                    return False
        for d, dd in out_edges[n]:
            if d in place:
                pd, td = place[d]
                if not cgra.reachable(p, pd):
                    return False
                if not (lat[n] <= td - t + dd * ii <= ii + lat[n] - 1):
                    return False
        return True

    while queue:
        n = queue.pop(0)
        window = list(range(asap[n], alap[n] + 1))
        rng.shuffle(window)
        pes = list(range(cgra.n_pes))
        rng.shuffle(pes)
        placed = False
        blocked: List[Tuple[int, int, int]] = []   # (occupant, p, t)
        for t in window:
            for p in pes:
                if not compatible(n, p, t):
                    continue
                occ = slot.get((p, t % ii))
                if occ is None:
                    place[n] = (p, t)
                    slot[(p, t % ii)] = n
                    placed = True
                    break
                blocked.append((occ, p, t))
            if placed:
                break
        if placed:
            continue
        # local adjustment: eject one blocking occupant and take its slot
        if blocked and ejects < max_ejects:
            ejects += 1
            occ, p, t = blocked[rng.randrange(len(blocked))]
            del place[occ]
            del slot[(p, t % ii)]
            if compatible(n, p, t):
                place[n] = (p, t)
                slot[(p, t % ii)] = n
                queue.append(occ)
                continue
            queue.insert(0, n)
            queue.append(occ)
            continue
        return None
    return {n: (p, t % ii, t // ii) for n, (p, t) in place.items()}


def map_heuristic(dfg: DFG, cgra: CGRA, cfg: BaselineConfig | None = None,
                  ) -> MappingResult:
    cfg = cfg or BaselineConfig()
    dfg.validate()
    rng = random.Random(cfg.seed)
    t_start = time.time()
    deadline = t_start + cfg.timeout_s
    try:
        mii = min_ii(dfg, cgra)
    except Infeasible as e:
        return MappingResult(success=False, cgra=cgra, infeasible=str(e),
                             total_time=time.time() - t_start)
    max_ii = cfg.max_ii if cfg.max_ii is not None else mii + 16
    res = MappingResult(success=False, mii=mii, cgra=cgra)

    for ii in range(mii, max_ii + 1):
        if time.time() > deadline:
            res.timed_out = True
            break
        t_ii = time.time()
        status = "FAIL"
        for r in range(cfg.n_restarts):
            if time.time() > deadline:
                res.timed_out = True
                break
            placement = _attempt(dfg, cgra, ii, rng, cfg.max_ejects)
            if placement is None:
                continue
            ra = allocate(dfg, cgra, placement, ii)
            if not ra.ok:
                continue
            chk = verify_mapping(dfg, cgra, placement, ii,
                                 n_iters=cfg.verify_iters)
            if not chk.ok:      # pragma: no cover - guards the heuristic
                continue
            res.success = True
            res.ii = ii
            res.placement = placement
            res.regalloc = ra
            res.dfg = dfg
            status = "SAT"
            break
        res.attempts.append(IIAttempt(
            ii=ii, n_vars=0, n_clauses=0, status=status,
            solve_time=time.time() - t_ii, encode_time=0.0))
        if res.success:
            break

    res.total_time = time.time() - t_start
    return res

"""Model layers: norms, RoPE, blockwise attention, SwiGLU, MoE, SSD.

Everything is written against plain parameter pytrees (no framework) and is
shape-polymorphic over the mesh: weights carry PartitionSpecs assigned in
model.py, activations get with_sharding_constraint at block boundaries, and
XLA's SPMD partitioner inserts the Megatron-style collectives.

Attention is *blockwise* (online-softmax over KV blocks, lax.scan) — the
same algorithm as the Pallas flash kernel in repro.kernels.flash_attention,
which replaces it on real TPU hardware; this jnp version is the portable
path and the kernel's numerical oracle. Naive O(S^2)-memory attention is
kept for cross-checking (tests) and perf ablation.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import AttnPlan

Params = Dict[str, Any]
_NEG = -2.0 ** 30  # large-negative for masking (safe in bf16/f32)


# ----------------------------------------------------------------- basics
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm with a custom VJP that keeps the *cotangent boundary* in the
    residual dtype (bf16): without it, the f32 upcast inside the norm makes
    XLA all-reduce residual-stream gradients in f32 — measured 2x collective
    wire on dense train steps."""
    return _rmsnorm_fwd(x, w, eps)[0]


def _rmsnorm_fwd(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    y = (xf * r).astype(x.dtype) * w
    return y, (x, w, r)


def _rmsnorm_bwd(eps, res, dy):
    x, w, r = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    xhat = xf * r
    g = dyf * w.astype(jnp.float32)
    dw = jnp.sum(dyf * xhat, axis=tuple(range(x.ndim - 1)))
    dx = r * (g - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dw.astype(w.dtype)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (int)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------- KV quantization
def quantize_kv(x: jnp.ndarray):
    """Symmetric int8 per-(pos, head) quantization over the head_dim axis.
    x: [..., hd] -> (int8 [..., hd], scale f32 [...])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ------------------------------------------------------------- attention
def naive_attention(q, k, v, q_pos, k_pos, window: int = 0):
    """O(S_q*S_k) reference. q: [B,Sq,H,D], k/v: [B,Sk,KV,D]."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qf = q.astype(jnp.float32) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, sq, kvh, group, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf)
    mask = k_pos[:, None, :] <= q_pos[:, :, None]            # causal
    if window:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(mask[:, None, None, :, :], scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def blockwise_attention(q, k, v, q_pos, k_pos, window: int = 0,
                        block: int = 512):
    """Flash-style online-softmax attention over KV blocks (jnp/lax.scan).

    Peak memory O(Sq * block) instead of O(Sq * Sk). Same signature/semantics
    as naive_attention; this is the oracle mirrored by the Pallas kernel.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    group = h // kvh
    nblk = -(-sk // block)
    pad = nblk * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    qf = (q.astype(jnp.float32) / math.sqrt(d)).reshape(b, sq, kvh, group, d)
    kb = k.reshape(b, nblk, block, kvh, d).swapaxes(0, 1)    # [n,B,blk,KV,D]
    vb = v.reshape(b, nblk, block, kvh, d).swapaxes(0, 1)
    pb = k_pos.reshape(b, nblk, block).swapaxes(0, 1)        # [n,B,blk]

    def step(carry, blk):
        m, l, acc = carry                                    # running max/sum
        kc, vc, pc = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32))
        mask = pc[:, None, :] <= q_pos[:, :, None]
        if window:
            mask &= pc[:, None, :] > q_pos[:, :, None] - window
        s = jnp.where(mask[:, None, None, :, :], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, group, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kvh, group, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, group, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def attention_layer(cfg: ModelConfig, plan: AttnPlan, p: Params,
                    x: jnp.ndarray, positions: jnp.ndarray,
                    cache: Optional[Dict[str, jnp.ndarray]] = None,
                    window: int = 0, impl: str = "blockwise",
                    ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """x: [B,S,D]. cache: {"k","v": [B,Skv,KV,hd], "pos": [B,Skv]} or ring
    buffer (see decode path in model.py). Returns (out [B,S,D], new kv)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk",
                   x, p["wq"].reshape(cfg.d_model, plan.h_pad, hd))
    k = jnp.einsum("bsd,dhk->bshk",
                   x, p["wk"].reshape(cfg.d_model, plan.kv_virtual, hd))
    v = jnp.einsum("bsd,dhk->bshk",
                   x, p["wv"].reshape(cfg.d_model, plan.kv_virtual, hd))
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(plan.h_pad, hd)
        k = k + p["bk"].reshape(plan.kv_virtual, hd)
        v = v + p["bv"].reshape(plan.kv_virtual, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        kk, vv, kpos = k, v, positions
    else:
        # decode: attend over the ring buffer PLUS the current token(s);
        # stale/unwritten ring slots are excluded by the position mask
        kk = jnp.concatenate([cache["k"], k], axis=1)
        vv = jnp.concatenate([cache["v"], v], axis=1)
        kpos = jnp.concatenate([cache["pos"], positions], axis=1)

    if impl == "flash" and cache is None:
        # Pallas kernel path: [B,S,H,D] -> [B,H,S,D] kernel layout. Prefill/
        # train only (contiguous positions); decode keeps the jnp path for
        # ring-buffer position masks.
        from ..kernels.flash_attention import flash_attention
        out = flash_attention(
            q.swapaxes(1, 2), kk.swapaxes(1, 2), vv.swapaxes(1, 2),
            causal=True, window=window).swapaxes(1, 2)
    else:
        fn = blockwise_attention if impl == "blockwise" else naive_attention
        out = fn(q, kk, vv, positions, kpos, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out,
                     p["wo"].reshape(plan.h_pad, hd, cfg.d_model))
    return out, {"k": k, "v": v}


# ------------------------------------------------------------------- MLP
def swiglu(p: Params, x: jnp.ndarray, bias: bool = False) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if bias:
        g = g + p["b_gate"]
        u = u + p["b_up"]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    if bias:
        out = out + p["b_down"]
    return out


def _moe_groups(cfg: ModelConfig, t: int) -> int:
    """Number of dispatch groups: capacity is enforced per group so the
    dispatch structures stay O(group) — groups align with data shards."""
    g = max(1, t // cfg.moe_group)
    while t % g:
        g -= 1
    return g


def moe_sort(cfg: ModelConfig, p: Params, x: jnp.ndarray,
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort/scatter MoE dispatch (MaxText-style 'ragged' dropping impl).

    Zero dispatch matmul FLOPs and O(t*k*d) dispatch memory: tokens are
    argsorted by expert within a group, placed into per-expert capacity
    buffers with scatter (overflow dropped), and combined back with a
    scatter-add. The sort/scatter are group-local, so sharding groups over
    the data axes keeps dispatch communication-free; the only collectives
    are the ones the partitioner inserts around the e-sharded expert matmul.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    ng = _moe_groups(cfg, t)
    sg = t // ng
    cap = max(1, int(cfg.capacity_factor * sg * k / e))
    xg = x.reshape(ng, sg, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # [g,sg,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(ng, sg * k)
    order = jnp.argsort(flat_e, axis=1)                      # stable
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)    # [g, sg*k]
    # position within expert = rank - first occurrence of that expert
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
    pos = jnp.arange(sg * k)[None, :] - first
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)    # OOB -> dropped
    token = order // k                                       # [g, sg*k]
    src = jnp.take_along_axis(xg, token[..., None], axis=1)  # [g, sg*k, d]
    gidx = jnp.arange(ng)[:, None]
    xin = jnp.zeros((ng, e * cap, d), x.dtype)
    xin = xin.at[gidx, dest].set(src, mode="drop")
    xin = xin.reshape(ng, e, cap, d)

    gg = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    hh = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * uu
    eout = jnp.einsum("gecf,efd->gecd", hh, p["w_down"])
    eout = eout.reshape(ng, e * cap, d)

    back = jnp.take_along_axis(
        eout, jnp.where(keep, dest, 0)[..., None], axis=1)   # [g, sg*k, d]
    gflat = jnp.take_along_axis(gate.reshape(ng, sg * k), order, axis=1)
    w = jnp.where(keep, gflat, 0.0).astype(jnp.float32)
    contrib = back.astype(jnp.float32) * w[..., None]
    out = jnp.zeros((ng, sg, d), jnp.float32)
    out = out.at[gidx, token].add(contrib)
    out = out.astype(x.dtype).reshape(b, s, d)

    me = probs.reshape(t, e).mean(axis=0)
    ce = jax.nn.one_hot(idx.reshape(t, k), e,
                        dtype=jnp.float32).sum(1).mean(0)
    aux = e * jnp.sum(me * ce)
    if cfg.n_shared_experts:
        out = out + swiglu(p["shared"], x)
    return out, aux


def moe_einsum(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style one-hot dispatch einsums with per-group capacity.

    Kept as the reference/ablation implementation: dispatch costs
    O(t * group * k * cf) one-hot einsum FLOPs, which the sort impl avoids
    (see EXPERIMENTS.md §Perf iteration on deepseek_moe_16b).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    ng = _moe_groups(cfg, t)
    sg = t // ng
    cap = max(1, int(cfg.capacity_factor * sg * k / e))
    xg = x.reshape(ng, sg, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # [g,sg,k,e]
    pos = jnp.cumsum(onehot.reshape(ng, sg * k, e), axis=1) - 1.0
    pos = pos.reshape(ng, sg, k, e)
    keep = (pos < cap) & (onehot > 0)
    pos_cap = jax.nn.one_hot(
        jnp.where(keep, pos, cap).astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = jnp.einsum("gske,gskec->gsec", onehot, pos_cap)
    combine = jnp.einsum("gske,gsk,gskec->gsec", onehot, gate, pos_cap)
    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    gg = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    hh = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * uu
    eout = jnp.einsum("gecf,efd->gecd", hh, p["w_down"])
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), eout)
    out = out.reshape(b, s, d)
    me = probs.reshape(t, e).mean(axis=0)
    ce = onehot.reshape(t, k, e).sum(1).mean(0)
    aux = e * jnp.sum(me * ce)
    if cfg.n_shared_experts:
        out = out + swiglu(p["shared"], x)
    return out, aux


def moe_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mixture-of-experts block; impl selected by cfg.moe_impl."""
    if cfg.moe_impl == "sort":
        return moe_sort(cfg, p, x)
    return moe_einsum(cfg, p, x)


# ------------------------------------------------------------------- SSD
def ssd_chunked(x, dt, A_log, B, C, D, chunk: int, return_state: bool = False):
    """Mamba2 SSD, chunked dual form (arXiv:2405.21060 listing 1).

    x:  [b, s, h, p]   (heads h, head dim p)
    dt: [b, s, h]      (softplus-ed outside)
    A_log: [h]         B, C: [b, s, n]  (single group), D: [h]
    Returns y: [b, s, h, p], or (y, final_state [b,h,p,n]) when
    ``return_state`` (the prefill -> decode handoff).
    """
    b, s, h, hp = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad to a chunk multiple; dt=0 makes padding a no-op for the state
        pad = chunk - s % chunk
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        out = ssd_chunked(xp, dtp, A_log, Bp, Cp, D, chunk, return_state)
        if return_state:
            return out[0][:, :s], out[1]
        return out[:, :s]
    nc = s // chunk
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    A = -jnp.exp(A_log.astype(jnp.float32))                  # [h], negative
    dA = dtf * A                                             # [b,s,h]
    xc = xf.reshape(b, nc, chunk, h, hp)
    dtc = dtf.reshape(b, nc, chunk, h)
    dAc = dA.reshape(b, nc, chunk, h)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, n)
    seg = jnp.cumsum(dAc, axis=2)                            # [b,nc,l,h]
    # intra-chunk (diagonal block): attention-like with decay matrix L
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # [b,nc,l,l,h]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)               # [b,nc,l,l]
    y_diag = jnp.einsum("bclm,bclmh,bcmh,bcmhp->bclhp",
                        cb, L, dtc, xc)
    # chunk-level states: decayed sum of inputs
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)          # [b,nc,l,h]
    states = jnp.einsum("bcln,bclh,bclh,bclhp->bchpn",
                        Bc, decay_to_end, dtc, xc)           # [b,nc,h,p,n]
    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(seg[:, :, -1, :])                  # [b,nc,h]

    def scan_fn(prev, inp):
        st, dec = inp
        new = st + dec[..., None, None] * prev
        return new, prev                                     # emit state *before* chunk

    init = jnp.zeros((b, h, hp, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                 # [b,nc,h,p,n]
    # contribution of carried state to each position
    state_decay = jnp.exp(seg)                               # decay from chunk start
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp",
                       Cc, state_decay, prev_states)
    y = (y_diag + y_off).reshape(b, s, h, hp)
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(state, x, dt, A_log, B, C, D):
    """Single-token SSD recurrence. state: [b,h,p,n]; x: [b,h,p];
    dt: [b,h]; B,C: [b,n]. Returns (y [b,h,p], new state)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * A)                 # [b,h]
    xf = x.astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(jnp.float32), xf,
                     B.astype(jnp.float32))
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 prev: Optional[jnp.ndarray]):
    """Depthwise causal conv. x: [B,S,F], w: [K,F], prev: [B,K-1,F] or None.
    Implemented as a sum of K shifted slices (no gather blowup).
    Returns (silu(conv(x)), new_prev [B,K-1,F])."""
    b, s, f = x.shape
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((b, k - 1, f), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i:i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
            for i in range(k))
    y = jax.nn.silu(y).astype(x.dtype)
    return y, xp[:, -(k - 1):, :]


def ssm_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              want_cache: bool = False):
    """Mamba2 mixer. x: [B,S,D]. If ``cache`` is given (decode), S must be 1.

    Projections are separate (w_z/w_x head-sharded over the model axis,
    small w_B/w_C/w_dt replicated) so all SSD math is shard-local and the
    only collective is the all-reduce after w_out — the Megatron pattern.

    Returns (out [B,S,D], new_cache)."""
    b, s, d = x.shape
    h, hp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = h * hp
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xin = jnp.einsum("bsd,de->bse", x, p["w_x"])
    Bc = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    Cc = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    cv = cache or {}
    xin, conv_x = _causal_conv(xin, p["conv_x"], cv.get("conv_x"))
    Bc, conv_B = _causal_conv(Bc, p["conv_B"], cv.get("conv_B"))
    Cc, conv_C = _causal_conv(Cc, p["conv_C"], cv.get("conv_C"))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xin.reshape(b, s, h, hp)
    if cache is None:
        if want_cache:  # prefill: also hand the final state to decode
            y, new_state = ssd_chunked(xh, dt, p["A_log"], Bc, Cc, p["D"],
                                       cfg.ssm_chunk, return_state=True)
        else:
            y = ssd_chunked(xh, dt, p["A_log"], Bc, Cc, p["D"],
                            cfg.ssm_chunk)
            new_state = None
    else:
        y1, new_state = ssd_decode_step(
            cache["state"], xh[:, 0], dt[:, 0], p["A_log"], Bc[:, 0],
            Cc[:, 0], p["D"])
        y = y1[:, None]
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_cache = ({"state": new_state, "conv_x": conv_x, "conv_B": conv_B,
                  "conv_C": conv_C}
                 if (cache is not None or want_cache) else None)
    return out, new_cache

"""Model configuration for the LM substrate.

One frozen dataclass covers every assigned architecture family:
dense GQA, MoE (shared + routed experts), SSM (Mamba2/SSD), hybrid
(parallel attention+SSM heads), and modality-stub backbones (audio/VLM).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                   # per-expert hidden for MoE
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    mlp_bias: bool = False
    # --- MoE ---
    n_experts: int = 0          # routed experts
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    d_conv: int = 4
    # --- hybrid ---
    attn_window: int = 0        # sliding-window attention (0 = full)
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: str = "none"      # none | audio_frames | vision_patches
    frontend_len: int = 0       # stub modality tokens prepended (vlm)
    dtype: str = "bfloat16"
    remat: bool = True
    # --- distribution knobs ---
    scan_layers: bool = True
    zero1: bool = True          # shard optimizer state over the data axis
    # sequence parallelism: measured -42% temp memory / -28% wire (yi_34b)
    seq_shard: bool = True
    # grouped one-hot dispatch; "sort" kept as the (refuted-under-jit)
    # scatter ablation — see EXPERIMENTS.md §Perf
    moe_impl: str = "einsum"
    # dispatch group size: 512 measured better than 2048 on deepseek
    # (coll 6.8->5.7s, mem 6.5->3.9s, useful 0.45->0.59) — §Perf
    moe_group: int = 512
    # FSDP-shard expert weights over the data axes too (needed when
    # E*3*d*f exceeds per-chip HBM under pure EP, e.g. llama4's 770B)
    fsdp_experts: bool = False
    # prevent XLA from hoisting f32 converts above the DP grad all-reduce
    grad_barrier: bool = False
    # microbatch gradient accumulation: divides activation temps by
    # accum_steps at the cost of accum extra weight passes (§Perf It. 10)
    accum_steps: int = 1
    # int8 KV cache with per-(pos, head) scales: halves decode cache HBM
    kv_quant: bool = False
    # attention implementation: "blockwise" (portable jnp online-softmax),
    # "flash" (Pallas TPU kernel; interpret-mode on CPU), "naive" (testing)
    attn_impl: str = "blockwise"
    pp_stages: int = 1          # reserved for >1k-chip pipeline meshes

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid-with-window)."""
        return self.is_attention_free or (self.has_ssm and self.attn_window > 0)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return self.replace(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=0 if self.is_attention_free else 4,
            n_kv_heads=0 if self.is_attention_free else max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_heads=4 if self.has_ssm else 0,
            ssm_head_dim=16,
            ssm_chunk=8,
            attn_window=min(self.attn_window, 16) if self.attn_window else 0,
            frontend_len=min(self.frontend_len, 8),
            remat=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell runs (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch"
    return True, ""

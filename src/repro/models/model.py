"""The LM: parameter init/specs, forward, train/prefill/decode steps.

One decoder block definition per family, lax.scan over stacked layer
parameters (compile time O(1) in depth), optional jax.checkpoint (remat)
around the block. All tensors carry PartitionSpecs derived from
models.sharding; steps are jit-able with explicit in/out shardings by
launch/dryrun.py and launch/train.py.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import layers
from .config import ModelConfig, ShapeConfig
from .sharding import AttnPlan, batch_axes, pad_to, plan_attention, spec, tp_size

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class LM:
    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = tp_size(mesh)
        self.plan: Optional[AttnPlan] = None
        if not cfg.is_attention_free:
            self.plan = plan_attention(cfg.n_heads, cfg.n_kv_heads, self.tp)
        self.vocab_pad = pad_to(cfg.vocab, self.tp)
        if cfg.has_ssm:
            assert (cfg.ssm_heads * cfg.ssm_head_dim) % self.tp == 0, \
                "ssm heads*dim must divide TP"
        assert cfg.d_ff == 0 or cfg.d_ff % self.tp == 0, "d_ff must divide TP"

    # ------------------------------------------------------------- params
    def _block_shapes(self) -> Dict[str, Tuple[Tuple[int, ...], P]]:
        """Leaf name -> (shape, partition spec) for ONE block (unstacked)."""
        cfg, plan = self.cfg, self.plan
        d, hd = cfg.d_model, cfg.head_dim
        out: Dict[str, Tuple[Tuple[int, ...], P]] = {}
        m = self.mesh

        def add(name, shape, *axes):
            out[name] = (shape, spec(m, *axes))

        add("ln1", (d,), None)
        if not cfg.is_attention_free:
            add("attn.wq", (d, plan.h_pad * hd), None, "model")
            add("attn.wk", (d, plan.kv_virtual * hd), None, "model")
            add("attn.wv", (d, plan.kv_virtual * hd), None, "model")
            add("attn.wo", (plan.h_pad * hd, d), "model", None)
            if cfg.qkv_bias:
                add("attn.bq", (plan.h_pad * hd,), "model")
                add("attn.bk", (plan.kv_virtual * hd,), "model")
                add("attn.bv", (plan.kv_virtual * hd,), "model")
        if cfg.has_ssm:
            h, hp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            di = h * hp
            add("ssm.w_z", (d, di), None, "model")
            add("ssm.w_x", (d, di), None, "model")
            add("ssm.w_B", (d, n), None, None)
            add("ssm.w_C", (d, n), None, None)
            add("ssm.w_dt", (d, h), None, None)
            add("ssm.conv_x", (cfg.d_conv, di), None, "model")
            add("ssm.conv_B", (cfg.d_conv, n), None, None)
            add("ssm.conv_C", (cfg.d_conv, n), None, None)
            add("ssm.dt_bias", (h,), None)
            add("ssm.A_log", (h,), None)
            add("ssm.D", (h,), None)
            add("ssm.norm", (di,), "model")
            add("ssm.w_out", (di, d), "model", None)
        if cfg.family == "hybrid":
            add("mix", (2,), None)
        if cfg.n_experts:
            f = cfg.d_ff
            dax = "fsdp" if cfg.fsdp_experts else None
            add("ln2", (d,), None)
            add("moe.router", (d, cfg.n_experts), None, None)
            add("moe.w_gate", (cfg.n_experts, d, f), "expert", dax, None)
            add("moe.w_up", (cfg.n_experts, d, f), "expert", dax, None)
            add("moe.w_down", (cfg.n_experts, f, d), "expert", dax, None)
            if cfg.n_shared_experts:
                fs = cfg.n_shared_experts * f
                add("moe.shared.w_gate", (d, fs), None, "model")
                add("moe.shared.w_up", (d, fs), None, "model")
                add("moe.shared.w_down", (fs, d), "model", None)
        elif cfg.d_ff:
            add("ln2", (d,), None)
            add("mlp.w_gate", (d, cfg.d_ff), None, "model")
            add("mlp.w_up", (d, cfg.d_ff), None, "model")
            add("mlp.w_down", (cfg.d_ff, d), "model", None)
            if cfg.mlp_bias:
                add("mlp.b_gate", (cfg.d_ff,), "model")
                add("mlp.b_up", (cfg.d_ff,), "model")
                add("mlp.b_down", (d,), None)
        return out

    def _top_shapes(self) -> Dict[str, Tuple[Tuple[int, ...], P]]:
        cfg = self.cfg
        # embed is d-sharded (local gather); lm_head is vocab-sharded
        out = {
            "embed": ((self.vocab_pad, cfg.d_model), spec(self.mesh, None, "model")),
            "final_norm": ((cfg.d_model,), spec(self.mesh, None)),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = ((cfg.d_model, self.vocab_pad),
                              spec(self.mesh, None, "vocab"))
        return out

    def param_specs(self) -> Params:
        # layer params are ALWAYS stacked on a leading L axis;
        # cfg.scan_layers only selects lax.scan vs an unrolled Python loop
        blocks = {}
        for name, (shape, sp) in self._block_shapes().items():
            _set(blocks, name, P(None, *sp))
        tops = {k: sp for k, (s, sp) in self._top_shapes().items()}
        return {"blocks": blocks, **tops}

    def param_shapes(self) -> Params:
        """ShapeDtypeStructs (for dry-run lowering without allocation)."""
        dt = _dtype(self.cfg)
        L = self.cfg.n_layers
        blocks = {}
        for name, (shape, sp) in self._block_shapes().items():
            _set(blocks, name, jax.ShapeDtypeStruct((L, *shape), dt))
        out = {"blocks": blocks}
        for k, (shape, sp) in self._top_shapes().items():
            out[k] = jax.ShapeDtypeStruct(shape, dt)
        return out

    def init(self, key: jax.Array) -> Params:
        """Real initialization (smoke tests / examples; NOT used by dry-run)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        L = cfg.n_layers
        shapes = self._block_shapes()
        keys = jax.random.split(key, len(shapes) + 2)
        blocks = {}
        for i, (name, (shape, sp)) in enumerate(shapes.items()):
            leaf = self._init_leaf(keys[i], name, (L, *shape), dt)
            _set(blocks, name, leaf)
        out = {"blocks": blocks}
        for j, (k, (shape, sp)) in enumerate(self._top_shapes().items()):
            kk = jax.random.fold_in(keys[-1], j)
            out[k] = (jax.random.normal(kk, shape, jnp.float32) * 0.02
                      ).astype(dt)
        if not cfg.is_attention_free:
            out["blocks"] = self._mask_dead_heads(out["blocks"])
        return out

    def _init_leaf(self, key, name, shape, dt):
        base = name.split(".")[-1]
        if base in ("ln1", "ln2", "norm"):
            return jnp.ones(shape, dt)
        if base == "mix":
            return jnp.ones(shape, dt)
        if base in ("dt_bias",):
            return jnp.zeros(shape, jnp.float32)
        if base == "A_log":
            return jnp.log(jnp.ones(shape, jnp.float32))
        if base == "D":
            return jnp.ones(shape, jnp.float32)
        if base.startswith("b"):
            return jnp.zeros(shape, dt)
        scale = 0.02
        if base in ("wo", "w_down", "w_out"):
            scale = 0.02 / math.sqrt(2 * self.cfg.n_layers)
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)

    def _dead_head_mask(self) -> jnp.ndarray:
        """[h_pad] 1.0 for real q-head slots, 0.0 for padding slots."""
        plan, cfg = self.plan, self.cfg
        gs = cfg.n_heads // cfg.n_kv_heads
        gs_p = plan.h_pad // (plan.kv_virtual // plan.repl)
        slot = jnp.arange(plan.h_pad)
        grp, r = slot // gs_p, slot % gs_p
        return ((grp < cfg.n_kv_heads) & (r < gs)).astype(jnp.float32)

    def _mask_dead_heads(self, blocks: Params) -> Params:
        """Zero wo rows of padded q-head slots => padding never affects
        the function (heads compute garbage that is multiplied by zero)."""
        mask = self._dead_head_mask()
        hd, d = self.cfg.head_dim, self.cfg.d_model
        wo = _get(blocks, "attn.wo")
        shape = wo.shape
        wom = wo.reshape(shape[0], -1, hd, d) * mask[None, :, None, None]
        _set(blocks, "attn.wo", wom.reshape(shape).astype(wo.dtype))
        return blocks

    # ------------------------------------------------------------ forward
    def _block(self, p: Params, x: jnp.ndarray, positions: jnp.ndarray,
               cache: Optional[Params], window: int,
               want_cache: bool = False,
               ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        """Returns (x, new_cache, aux_loss)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
        # NOTE: an explicit Megatron-SP all-gather boundary here was tried
        # and REFUTED — XLA pins full f32 activation all-reduces to it
        # (51.5s vs 24.6s collective term on yi_34b; EXPERIMENTS.md §Perf).
        # Leaving the mixers unconstrained lets the partitioner pick the
        # cheaper schedule from the seq-sharded residual constraint alone.
        new_cache: Dict[str, Any] = {}
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            a, kv = layers.attention_layer(
                cfg, self.plan, p["attn"], h, positions,
                cache=cache.get("attn") if cache else None, window=window,
                impl=cfg.attn_impl if cache is None else "blockwise")
            x = x + a
            new_cache["attn_kv"] = kv
        elif cfg.family == "ssm":
            a, sc = layers.ssm_layer(cfg, p["ssm"], h,
                                     cache=cache.get("ssm") if cache else None,
                                     want_cache=want_cache)
            x = x + a
            new_cache["ssm"] = sc
        elif cfg.family == "hybrid":
            a, kv = layers.attention_layer(
                cfg, self.plan, p["attn"], h, positions,
                cache=cache.get("attn") if cache else None, window=window,
                impl=cfg.attn_impl if cache is None else "blockwise")
            s_out, sc = layers.ssm_layer(
                cfg, p["ssm"], h, cache=cache.get("ssm") if cache else None,
                want_cache=want_cache)
            mix = p["mix"].astype(jnp.float32)
            x = x + (a * mix[0] + s_out * mix[1]).astype(x.dtype) * 0.5
            new_cache["attn_kv"] = kv
            new_cache["ssm"] = sc
        else:
            raise ValueError(cfg.family)
        if cfg.n_experts:
            h2 = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
            mo, aux = layers.moe_layer(cfg, p["moe"], h2)
            x = x + mo
        elif cfg.d_ff:
            h2 = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
            x = x + layers.swiglu(p["mlp"], h2, bias=cfg.mlp_bias)
        x = jax.lax.with_sharding_constraint(x, self._act_spec(x))
        return x, new_cache, aux

    def _act_spec(self, x: jnp.ndarray) -> P:
        """Residual-stream sharding: batch over data axes (when divisible);
        sequence over the model axis when seq_shard (sequence parallelism —
        activations and their grads live reduce-scattered between blocks)."""
        b, s, _ = x.shape
        seq_ax = "model" if (self.cfg.seq_shard and s > 1
                             and s % self.tp == 0) else None
        return spec(self.mesh, "batch", seq_ax, None, batch_size=b)

    def embed_tokens(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        return jnp.take(params["embed"], tokens, axis=0)

    def logits(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        x = layers.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        # keep the contraction (d) axis UNsharded: resharding the small tied
        # head here costs ~MBs; contracting over a sharded d would all-reduce
        # the full [B,S,V] f32 logits (measured 24.7GB wire on mamba2)
        head = jax.lax.with_sharding_constraint(
            head, spec(self.mesh, None, "vocab"))
        lg = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
        # mask padded vocab slots
        valid = jnp.arange(self.vocab_pad) < self.cfg.vocab
        return jnp.where(valid, lg, -1e30)

    def forward(self, params: Params, tokens: Optional[jnp.ndarray],
                embeds: Optional[jnp.ndarray] = None, window: int = 0,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence forward. Returns (hidden [B,S,D], aux_loss)."""
        cfg = self.cfg
        parts = []
        if embeds is not None:
            parts.append(embeds.astype(_dtype(cfg)))
        if tokens is not None:
            parts.append(self.embed_tokens(params, tokens))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = jax.lax.with_sharding_constraint(x, self._act_spec(x))

        blk = functools.partial(self._fwd_block, positions=positions,
                                window=window)
        if cfg.remat:
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(
                lambda carry, lp: (blk(carry, lp), None),
                (x, jnp.zeros((), jnp.float32)), params["blocks"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                x, aux = blk((x, aux), lp)
        return x, aux

    def _fwd_block(self, carry, lp, *, positions, window):
        x, aux = carry
        x, _, a = self._block(lp, x, positions, cache=None, window=window)
        return x, aux + a

    # -------------------------------------------------------------- steps
    def loss_fn(self, params: Params, batch: Dict[str, jnp.ndarray],
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        window = cfg.attn_window
        x, aux = self.forward(params, batch.get("tokens"),
                              batch.get("embeds"), window=window)
        labels = batch["labels"]
        # frontend tokens (prepended embeds) carry no loss
        x_text = x[:, -labels.shape[1]:, :]
        lg = self.logits(params, x_text)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
        loss = ce + cfg.router_aux_weight * aux / max(cfg.n_layers, 1)
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- decode
    def cache_shapes(self, batch: int, window: int) -> Params:
        """ShapeDtypeStructs of the decode cache (ring buffer of ``window``)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        L = cfg.n_layers
        out: Dict[str, Any] = {}
        if not cfg.is_attention_free:
            kvh, hd = self.plan.kv_virtual, cfg.head_dim
            kv_dt = jnp.int8 if cfg.kv_quant else dt
            out["k"] = jax.ShapeDtypeStruct((L, batch, window, kvh, hd), kv_dt)
            out["v"] = jax.ShapeDtypeStruct((L, batch, window, kvh, hd), kv_dt)
            if cfg.kv_quant:
                out["k_scale"] = jax.ShapeDtypeStruct(
                    (L, batch, window, kvh), jnp.float32)
                out["v_scale"] = jax.ShapeDtypeStruct(
                    (L, batch, window, kvh), jnp.float32)
            out["pos"] = jax.ShapeDtypeStruct((L, batch, window), jnp.int32)
        if cfg.has_ssm:
            h, hp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            di, k = h * hp, cfg.d_conv
            out["state"] = jax.ShapeDtypeStruct((L, batch, h, hp, n),
                                                jnp.float32)
            out["conv_x"] = jax.ShapeDtypeStruct((L, batch, k - 1, di), dt)
            out["conv_B"] = jax.ShapeDtypeStruct((L, batch, k - 1, n), dt)
            out["conv_C"] = jax.ShapeDtypeStruct((L, batch, k - 1, n), dt)
        return out

    def cache_specs(self, batch: Optional[int] = None) -> Params:
        m = self.mesh
        cfg = self.cfg
        bs = batch  # batch=1 (long_500k) falls back to replicated
        out: Dict[str, Any] = {}
        if not cfg.is_attention_free:
            out["k"] = spec(m, None, "batch", None, "model", None, batch_size=bs)
            out["v"] = spec(m, None, "batch", None, "model", None, batch_size=bs)
            if cfg.kv_quant:
                out["k_scale"] = spec(m, None, "batch", None, "model",
                                      batch_size=bs)
                out["v_scale"] = spec(m, None, "batch", None, "model",
                                      batch_size=bs)
            out["pos"] = spec(m, None, "batch", None, batch_size=bs)
        if cfg.has_ssm:
            out["state"] = spec(m, None, "batch", "model", None, None,
                                batch_size=bs)
            out["conv_x"] = spec(m, None, "batch", None, "model", batch_size=bs)
            out["conv_B"] = spec(m, None, "batch", None, None, batch_size=bs)
            out["conv_C"] = spec(m, None, "batch", None, None, batch_size=bs)
        return out

    def init_cache(self, batch: int, window: int) -> Params:
        shapes = self.cache_shapes(batch, window)
        out = {}
        for k, sd in shapes.items():
            if k == "pos":
                out[k] = jnp.full(sd.shape, 2 ** 30, sd.dtype)
            else:
                out[k] = jnp.zeros(sd.shape, sd.dtype)
        return out

    def decode_step(self, params: Params, cache: Params,
                    tokens: jnp.ndarray, t: jnp.ndarray,
                    ) -> Tuple[jnp.ndarray, Params]:
        """One token for the whole batch. tokens: [B,1]; t: scalar int32
        (current absolute position). Ring-buffer insert at t % window."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        b = x.shape[0]
        positions = jnp.broadcast_to(t[None, None], (b, 1)).astype(jnp.int32)
        window = 0
        if not cfg.is_attention_free:
            window = cache["k"].shape[2]
            slot = (t % window).astype(jnp.int32)

        def blk(x, inp):
            lp, lc = inp
            layer_cache: Dict[str, Any] = {}
            if not cfg.is_attention_free:
                if cfg.kv_quant:
                    dt = x.dtype
                    layer_cache["attn"] = {
                        "k": layers.dequantize_kv(lc["k"], lc["k_scale"], dt),
                        "v": layers.dequantize_kv(lc["v"], lc["v_scale"], dt),
                        "pos": lc["pos"]}
                else:
                    layer_cache["attn"] = {"k": lc["k"], "v": lc["v"],
                                           "pos": lc["pos"]}
            if cfg.has_ssm:
                layer_cache["ssm"] = {
                    "state": lc["state"], "conv_x": lc["conv_x"],
                    "conv_B": lc["conv_B"], "conv_C": lc["conv_C"]}
            aw = cfg.attn_window if cfg.attn_window else 0
            x, nc, _ = self._block(lp, x, positions, layer_cache, window=aw)
            new_lc = dict(lc)
            if not cfg.is_attention_free:
                kv = nc["attn_kv"]
                k_new, v_new = kv["k"][:, 0], kv["v"][:, 0]
                if cfg.kv_quant:
                    k_new, ks = layers.quantize_kv(k_new)
                    v_new, vs = layers.quantize_kv(v_new)
                    new_lc["k_scale"] = jax.lax.dynamic_update_index_in_dim(
                        lc["k_scale"], ks, slot, axis=1)
                    new_lc["v_scale"] = jax.lax.dynamic_update_index_in_dim(
                        lc["v_scale"], vs, slot, axis=1)
                new_lc["k"] = jax.lax.dynamic_update_index_in_dim(
                    lc["k"], k_new, slot, axis=1)
                new_lc["v"] = jax.lax.dynamic_update_index_in_dim(
                    lc["v"], v_new, slot, axis=1)
                new_lc["pos"] = jax.lax.dynamic_update_index_in_dim(
                    lc["pos"], positions[:, 0], slot, axis=1)
            if cfg.has_ssm:
                sc = nc["ssm"]
                new_lc["state"] = sc["state"]
                new_lc["conv_x"] = sc["conv_x"]
                new_lc["conv_B"] = sc["conv_B"]
                new_lc["conv_C"] = sc["conv_C"]
            return x, new_lc

        if cfg.scan_layers:
            x, new_cache = jax.lax.scan(blk, x, (params["blocks"], cache))
        else:
            outs = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                lc = jax.tree.map(lambda a, i=i: a[i], cache)
                x, nlc = blk(x, (lp, lc))
                outs.append(nlc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        lg = self.logits(params, x)
        return lg, new_cache

    def prefill(self, params: Params, tokens: jnp.ndarray,
                embeds: Optional[jnp.ndarray] = None,
                ) -> jnp.ndarray:
        """Prefill forward; returns last-position logits [B,1,V]."""
        window = self.cfg.attn_window
        x, _ = self.forward(params, tokens, embeds, window=window)
        return self.logits(params, x[:, -1:, :])

    def prefill_with_cache(self, params: Params, tokens: Optional[jnp.ndarray],
                           embeds: Optional[jnp.ndarray] = None,
                           window: Optional[int] = None,
                           ) -> Tuple[jnp.ndarray, Params]:
        """Prefill that also materializes the decode cache (ring buffer of
        ``window`` slots; decode continues at t = prompt length).
        Returns (last logits [B,1,Vp], cache)."""
        cfg = self.cfg
        parts = []
        if embeds is not None:
            parts.append(embeds.astype(_dtype(cfg)))
        if tokens is not None:
            parts.append(self.embed_tokens(params, tokens))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        b, s, _ = x.shape
        if window is None:
            window = min(s, cfg.attn_window) if cfg.attn_window else s
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = jax.lax.with_sharding_constraint(x, self._act_spec(x))

        def blk(carry, lp):
            xx, aux = carry
            xx, nc, a = self._block(lp, xx, positions, cache=None,
                                    window=cfg.attn_window, want_cache=True)
            ys: Dict[str, Any] = {}
            if not cfg.is_attention_free:
                ys["k"] = nc["attn_kv"]["k"]
                ys["v"] = nc["attn_kv"]["v"]
            if cfg.has_ssm:
                ys.update(nc["ssm"])
            return (xx, aux + a), ys

        if cfg.scan_layers:
            (x, _), per_layer = jax.lax.scan(
                blk, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        else:
            outs = []
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                (x, aux), ys = blk((x, aux), lp)
                outs.append(ys)
            per_layer = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        cache = self.init_cache(b, window)
        take = min(window, s)
        src = jnp.arange(s - take, s)
        slots = src % window
        if not cfg.is_attention_free:
            k_all, v_all = per_layer["k"], per_layer["v"]  # [L,B,S,KV,hd]
            k_new = k_all[:, :, s - take:s]
            v_new = v_all[:, :, s - take:s]
            if cfg.kv_quant:
                k_new, ks = layers.quantize_kv(k_new)
                v_new, vs = layers.quantize_kv(v_new)
                cache["k_scale"] = cache["k_scale"].at[:, :, slots].set(ks)
                cache["v_scale"] = cache["v_scale"].at[:, :, slots].set(vs)
            cache["k"] = cache["k"].at[:, :, slots].set(
                k_new.astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, slots].set(
                v_new.astype(cache["v"].dtype))
            cache["pos"] = cache["pos"].at[:, :, slots].set(
                jnp.broadcast_to(src[None, None, :], (cfg.n_layers, b, take)))
        if cfg.has_ssm:
            cache["state"] = per_layer["state"]
            cache["conv_x"] = per_layer["conv_x"]
            cache["conv_B"] = per_layer["conv_B"]
            cache["conv_C"] = per_layer["conv_C"]
        lg = self.logits(params, x[:, -1:, :])
        return lg, cache


def _set(d: Dict[str, Any], dotted: str, val) -> None:
    ks = dotted.split(".")
    for k in ks[:-1]:
        d = d.setdefault(k, {})
    d[ks[-1]] = val


def _get(d: Dict[str, Any], dotted: str):
    for k in dotted.split("."):
        d = d[k]
    return d

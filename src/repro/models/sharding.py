"""Sharding rules: logical axes -> mesh axes, and the attention head plan.

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single-pod.
Logical tensor axes used by the model code:

  batch   -> ("pod", "data")         data parallelism (+ pod axis)
  model   -> "model"                 tensor parallelism
  vocab   -> "model"
  expert  -> "model"                 expert parallelism
  None    -> replicated

Indivisible head counts are handled by the *attention plan*: q-heads are
padded (zero o_proj rows keep the function exact) and kv heads are expanded
to "virtual" heads (vLLM-style replication) so that every sharded axis is
divisible by the TP degree and all attention math stays shard-local.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def spec(mesh: Mesh, *axes, batch_size: Optional[int] = None) -> P:
    """Translate logical axes to a PartitionSpec for this mesh.

    ``batch_size``: when given, the "batch" logical axis falls back to
    replicated if the size does not divide the data-parallel degree
    (e.g. the global_batch=1 long-context decode shape)."""
    out = []
    for a in axes:
        if a == "batch":
            ba = batch_axes(mesh)
            if batch_size is not None and batch_size % dp_size(mesh):
                ba = None
            out.append(ba)
        elif a in ("model", "vocab", "expert"):
            out.append("model")
        elif a == "fsdp":
            # weight sharding over the data axes (ZeRO-3 style); shares the
            # batch axes — all-gathered at use, partitioner-inserted
            out.append(batch_axes(mesh))
        elif a is None:
            out.append(None)
        else:
            raise ValueError(f"unknown logical axis {a!r}")
    return P(*out)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


@dataclass(frozen=True)
class AttnPlan:
    """Padded/virtualized head layout for a given TP degree.

    h_pad      padded q heads (multiple of tp; extra heads functionally dead)
    kv_virtual virtual kv heads materialized in weights & KV cache
               (multiple of tp or == true kv heads when replicated=1)
    group      q heads per virtual kv head (h_pad / kv_virtual)
    repl       how many times each true kv head is duplicated
    """
    n_heads: int
    n_kv: int
    h_pad: int
    kv_virtual: int
    group: int
    repl: int

    @property
    def pad_overhead(self) -> float:
        return self.h_pad / self.n_heads


def plan_attention(n_heads: int, n_kv: int, tp: int) -> AttnPlan:
    if n_heads % n_kv:
        raise ValueError("n_heads must be a multiple of n_kv_heads")
    gs = n_heads // n_kv
    # Search padded (groups g_p, group size gs_p). Original q head i lands in
    # padded slot (i//gs)*gs_p + (i%gs), so pairing with its kv head is
    # preserved; added slots/groups carry zero weights (function unchanged).
    best: Optional[Tuple[int, int, int]] = None  # (total, g_p, gs_p)
    for g_p in range(n_kv, 4 * n_kv + 1):
        for gs_p in range(gs, 4 * gs + 1):
            total = g_p * gs_p
            if total % tp:
                continue
            hps = total // tp  # q heads per shard
            # a shard must hold whole groups, or a group must span shards evenly
            if hps % gs_p and gs_p % hps:
                continue
            if best is None or total < best[0]:
                best = (total, g_p, gs_p)
    if best is None:
        raise ValueError(f"no attention plan for H={n_heads} kv={n_kv} tp={tp}")
    total, g_p, gs_p = best
    hps = total // tp
    if hps % gs_p == 0:
        # whole groups per shard: kv heads sharded directly, no replication
        kv_virtual, repl = g_p, 1
    else:
        # each group spans k shards -> replicate kv k times
        k = gs_p // hps
        kv_virtual, repl = g_p * k, k
    return AttnPlan(n_heads=n_heads, n_kv=n_kv, h_pad=total,
                    kv_virtual=kv_virtual, group=total // kv_virtual, repl=repl)


def pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m

import os

# Smoke tests and benches must see the real (single) CPU device; only
# launch/dryrun.py forces 512 placeholder devices — never set that here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)

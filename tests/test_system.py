"""End-to-end behaviour: the full SAT-MapIt pipeline and the launch stack."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cgra import CGRA, cgra_from_name
from repro.core.frontend import trace_loop_body
from repro.core.mapper import MapperConfig, map_loop
from repro.core.simulator import emit_code, verify_mapping


def test_full_pipeline_jax_to_cgra_code():
    """JAX loop body -> DFG -> SAT mapping -> regalloc -> verified code."""
    def body(i, acc):
        x = (acc + i) * 3
        return (x ^ (x >> 1),)

    g, cm = trace_loop_body(body, n_carry=1, name="pipeline")
    cgra = cgra_from_name("3x3")
    r = map_loop(g, cgra, MapperConfig(solver="auto", timeout_s=60))
    assert r.success
    assert r.regalloc is not None and r.regalloc.ok
    chk = verify_mapping(g, cgra, r.placement, r.ii, n_iters=10)
    assert chk.ok, chk.errors
    code = emit_code(g, cgra, r.placement, r.ii)
    assert len(code.kernel) == r.ii
    assert "II=" in code.render(g)


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives, terms
    hlo = """
  %ar = f32[16,4096,7168]{2,1,0} all-reduce(f32[16,4096,7168] %x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag.1 = bf16[64,1024]{1,0} all-gather(bf16[4,1024] %y), replica_groups=[2,16]<=[32], dimensions={0}
  %rs = f32[8]{0} reduce-scatter(f32[128] %z), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}
  %cp = u32[2]{0} collective-permute(u32[2] %w), source_target_pairs={{0,1}}
  %dead = f32[2]{0} add(f32[2] %a, f32[2] %b)
"""
    st = parse_collectives(hlo)
    assert st.count == 4
    ar = 2 * (3 / 4) * 16 * 4096 * 7168 * 4
    ag = (15 / 16) * 64 * 1024 * 2
    rs = 15 * 8 * 4
    cp = 2 * 4
    assert abs(st.wire_bytes - (ar + ag + rs + cp)) < 1.0
    t = terms(1e15, 1e12, st.wire_bytes)
    assert t["bottleneck"] == "compute_s"


def test_param_counts_match_init():
    """Analytic parameter count equals the actual initialized tree."""
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.roofline import param_counts
    from repro.models.model import LM
    cfg = get_config("mamba2_370m").smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
    actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    pc = param_counts(cfg)
    # analytic excludes small norms/scalars and padding; within 10%
    assert abs(actual - pc["total"]) / actual < 0.10


def test_mesh_helpers():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    assert set(mesh.axis_names) == {"data", "model"}


def test_serve_batched_requests():
    """Batched serving smoke: prefill-free decode of a token stream."""
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import LM
    cfg = get_config("musicgen_large").smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        B = 4
        cache = lm.init_cache(B, 8)
        dec = jax.jit(lm.decode_step)
        tok = jnp.zeros((B, 1), jnp.int32)
        for t in range(6):
            lg, cache = dec(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(lg[:, :, :cfg.vocab], axis=-1).astype(jnp.int32)
        assert tok.shape == (B, 1)

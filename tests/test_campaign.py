"""Mapping-campaign engine: corpus determinism, isomorphism dedup,
feature contract, sharded dataset durability, and the campaign driver."""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:
    from _propshim import HealthCheck, given, settings, strategies as st

from repro.core import suite
from repro.core.arch import arch
from repro.core.campaign import (CampaignDataset, CellRecord, CorpusSpec,
                                 N_FEATURES, build_corpus, canonical_dfg,
                                 canonical_key, cell_features, corpus_digest,
                                 mutate_dfg, random_dfg, run_campaign)
from repro.core.dfg import running_example
from repro.core.mapper import MapperConfig
from repro.core.service import dfg_signature
from repro.core.workers import WorkerPool

SMALL = CorpusSpec(seed=3, n_random=6, n_mutants=4, include_suite=False,
                   min_nodes=5, max_nodes=9)


# ----------------------------------------------------------- determinism

def test_corpus_same_seed_same_digest_across_hash_seeds():
    """The corpus (and its canonical keys) must be byte-identical in any
    process — no ``hash()``/set-order dependence — so two campaign drivers
    with the same spec always agree on cell identity."""
    prog = ("import sys; sys.path.insert(0, 'src')\n"
            "from repro.core.campaign import (CorpusSpec, build_corpus, "
            "corpus_digest)\n"
            "spec = CorpusSpec(seed=3, n_random=6, n_mutants=4, "
            "include_suite=False, min_nodes=5, max_nodes=9)\n"
            "items, _ = build_corpus(spec)\n"
            "print(corpus_digest(items))\n")
    digests = set()
    for hashseed in ("0", "12345"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   JAX_PLATFORMS="cpu")
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, env=env,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))))
        assert out.returncode == 0, out.stderr
        digests.add(out.stdout.strip())
    assert len(digests) == 1
    items, _ = build_corpus(SMALL)
    assert corpus_digest(items) == digests.pop()


def test_random_dfg_validates_and_executes():
    import random
    rng = random.Random(11)
    for i in range(10):
        g = random_dfg(rng, SMALL, f"g{i}")
        g.validate()
        hist, _mem = g.execute(3)
        assert len(hist) == 3


# ----------------------------------------------------------------- dedup

@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.sampled_from(suite.names()), st.integers(0, 10_000))
def test_relabel_mutants_collapse_to_one_canonical_key(name, seed):
    """Any node-id permutation of a DFG is the *same* corpus entry: its
    canonical key (and the canonical form itself) is permutation-
    invariant."""
    import random
    g = suite.get(name)
    mut, kind = mutate_dfg(g, random.Random(seed), kind="relabel")
    assert kind == "relabel"
    assert canonical_key(mut) == canonical_key(g)
    assert dfg_signature(canonical_dfg(mut)) == \
        dfg_signature(canonical_dfg(g))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 10_000), st.integers(0, 10_000))
def test_relabel_collapse_on_random_dfgs(gen_seed, perm_seed):
    import random
    g = random_dfg(random.Random(gen_seed), SMALL)
    mut, _ = mutate_dfg(g, random.Random(perm_seed), kind="relabel")
    assert canonical_key(mut) == canonical_key(g)


def test_semantic_mutations_change_the_key():
    """Non-relabel mutations are meant to produce *new* corpus entries
    (an op swap / imm perturbation is a different kernel)."""
    import random
    g = suite.get("sha")
    for kind, seed in (("op", 1), ("imm", 2), ("grow", 3)):
        mut, _ = mutate_dfg(g, random.Random(seed), kind=kind)
        assert canonical_key(mut) != canonical_key(g), kind


def test_build_corpus_reports_dedup():
    spec = CorpusSpec(seed=0, n_random=12, n_mutants=24,
                      include_suite=True, min_nodes=5, max_nodes=9)
    items, stats = build_corpus(spec)
    assert stats["unique"] == len(items)
    assert stats["generated"] == stats["unique"] + stats["duplicates"]
    # relabel mutants collapse onto parents, so dedup fires in practice
    assert stats["duplicates"] > 0
    assert len({it.key for it in items}) == len(items)


# -------------------------------------------------------------- features

def test_cell_features_shape_and_finiteness():
    for fabric in (arch("2x2"), arch("4x4-torus:r8"), arch("3x3-onehop")):
        f = cell_features(running_example(), fabric)
        assert f.shape == (N_FEATURES,)
        assert f.dtype == np.float32
        assert np.all(np.isfinite(f))


def test_cell_features_see_the_fabric():
    g = suite.get("gsm")
    a = cell_features(g, arch("2x2"))
    b = cell_features(g, arch("4x4"))
    assert not np.array_equal(a, b)


# --------------------------------------------------------------- dataset

def _mk_cell(key_byte: int, ii=4, witness=None) -> CellRecord:
    key = bytes([key_byte]) + bytes(31)
    return CellRecord(
        key=key, dfg_key=bytes(32), name=f"c{key_byte}", kind="random",
        fabric="2x2", n_nodes=7,
        features=np.full(N_FEATURES, float(key_byte), dtype=np.float32),
        mii=2, ii=ii, success=ii is not None, infeasible=False,
        attempts=((2, "UNSAT", "cdcl", 0.01), (ii or 9, "SAT", "walksat",
                                               0.02)),
        total_time=0.05, witness=witness)


def test_dataset_roundtrip_and_sharding(tmp_path):
    ds = CampaignDataset(str(tmp_path / "cells"), n_shards=3)
    recs = [_mk_cell(b, witness=b"\x01\x02" if b % 2 else None)
            for b in range(17)]
    for r in recs:
        ds.append(r)
    got = {r.key: r for r in ds}
    assert len(got) == len(recs)
    for r in recs:
        back = got[r.key]
        assert back.offset == r.ii - r.mii
        assert back.attempts == r.attempts
        assert back.witness == r.witness
        assert np.array_equal(back.features, r.features)
    d = ds.describe()
    assert d["cells"] == len(recs) and d["corrupt_shards"] == 0
    # keys really spread over shards
    used = [s for s in range(3) if os.path.exists(ds.shard_path(s))]
    assert len(used) > 1


def test_dataset_tolerates_torn_tail_and_corrupt_shard(tmp_path):
    ds = CampaignDataset(str(tmp_path / "cells"), n_shards=2)
    for b in range(8):
        ds.append(_mk_cell(b))
    n = ds.count()
    # torn tail on shard 0: a half-written frame is invisible
    with open(ds.shard_path(0), "ab") as f:
        f.write(b"\x00" * 11)
    assert ds.count() == n
    # flipped byte inside shard 1: that shard stops early but the reader
    # survives and reports it
    with open(ds.shard_path(1), "r+b") as f:
        f.seek(60)
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0xFF]))
    survivors = list(ds)
    assert ds.corrupt_shards >= 1
    assert 0 < len(survivors) < n


# -------------------------------------------------------------- campaign

def test_run_campaign_inline_pool_smoke(tmp_path):
    items, _ = build_corpus(CorpusSpec(seed=5, n_random=4, n_mutants=0,
                                       include_suite=False,
                                       min_nodes=5, max_nodes=7))
    fabrics = [arch("2x2"), arch("3x3")]
    ds = CampaignDataset(str(tmp_path / "cells"), n_shards=2)
    with WorkerPool(workers=0, store_path=str(tmp_path / "store")) as pool:
        stats, recs = run_campaign(items, fabrics, pool, dataset=ds,
                                   cfg=MapperConfig(timeout_s=30.0))
    assert stats.cells == len(items) * len(fabrics)
    assert stats.errors == 0
    assert stats.mapped + stats.failed + stats.infeasible == stats.cells
    assert stats.mapped > 0
    assert ds.count() == stats.cells
    for rec in recs:
        if rec.success:
            assert rec.ii is not None and rec.ii >= rec.mii
            assert any(st_ == "SAT" for _ii, st_, _via, _s in rec.attempts)
        if rec.witness is not None:
            # the witness re-solves to the recorded UNSAT-at-MII verdict
            from repro.core.sat import UNSAT, solve_cnf
            from repro.core.sat.cnf import CNF
            from repro.core.arena import ClauseArena
            cnf = CNF.__new__(CNF)
            cnf.arena = ClauseArena.from_bytes(rec.witness)
            assert solve_cnf(cnf, method="cdcl").status == UNSAT


def test_run_campaign_records_structural_infeasibility(tmp_path):
    """A cell whose fabric lacks an op class entirely never reaches the
    pool but still lands in the dataset (labelled infeasible)."""
    from repro.core.campaign import CorpusItem
    from repro.core.dfg import DFG
    g = DFG("dot")                       # needs a multiplier somewhere
    iv = g.add("iv", name="i")
    c = g.add("const", imm=3)
    m = g.add("mul", [(iv, 0), (c, 0)])
    g.add("add", [(m, 0), (c, 0)])
    items = [CorpusItem(name="dot", dfg=g, key=canonical_key(g),
                        kind="suite")]
    fabric = arch("2x2", mul="none")
    ds = CampaignDataset(str(tmp_path / "cells"))

    class NoPool:                        # submit() must never be called
        def submit(self, *a, **kw):
            raise AssertionError("infeasible cell hit the pool")

    stats, recs = run_campaign(items, [fabric], NoPool(), dataset=ds)
    assert stats.cells == stats.infeasible == 1
    assert recs[0].infeasible and not recs[0].success
    assert recs[0].ii is None
    assert ds.count() == 1

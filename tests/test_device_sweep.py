"""Device-resident probSAT sweep engine: host/device bit-compatibility,
warm-start padding regressions, near-miss semantics, chunk scheduling,
and the non-model structured-error guard."""
import numpy as np
import pytest

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.cnf import CNF
from repro.core.dfg import running_example
from repro.core.encode import EncoderSession
from repro.core.sat import SAT, UNKNOWN
from repro.core.sat.walksat_jax import (NonModelError, _chunk_plan,
                                        _next_chunk, solve_walksat,
                                        solve_walksat_window)
from repro.core.schedule import min_ii


def _window_cnfs(name: str, cgra: CGRA, width: int = 3):
    g = suite.get(name)
    mii = max(min_ii(g, cgra), 1)
    sess = EncoderSession(g, cgra)
    iis = list(range(mii, mii + width))
    return iis, [sess.encode(ii).cnf for ii in iis]


def _tiny_cnf(n_vars: int = 6, seed: int = 0) -> CNF:
    """A small random satisfiable-ish 3-CNF for unit-level checks."""
    rng = np.random.RandomState(seed)
    cnf = CNF()
    for _ in range(n_vars):
        cnf.new_var()
    model = rng.rand(n_vars) > 0.5
    for _ in range(3 * n_vars):
        vs = rng.choice(n_vars, 3, replace=False) + 1
        lits = [int(v) if rng.rand() > 0.5 else -int(v) for v in vs]
        # force at least one literal to agree with `model` => SAT
        v0 = int(vs[0])
        lits[0] = v0 if model[v0 - 1] else -v0
        cnf.add_clause(lits)
    return cnf


# -------------------------------------------------- engine bit-compatibility
@pytest.mark.parametrize("name", suite.names())
def test_device_engine_matches_host_engine_3x3(name):
    """Fixed-seed determinism across drive styles: the device-resident
    while_loop engine must return the same statuses AND the same models as
    the per-chunk host reference loop on every suite kernel's II window."""
    _, cnfs = _window_cnfs(name, CGRA(3, 3))
    nm_h, nm_d = {}, {}
    rh = solve_walksat_window(cnfs, seed=11, steps=1200, batch=6,
                              engine="host", near_miss=nm_h)
    rd = solve_walksat_window(cnfs, seed=11, steps=1200, batch=6,
                              engine="device", near_miss=nm_d)
    assert rh == rd
    assert nm_h == nm_d


@pytest.mark.slow
@pytest.mark.parametrize("size", ["2x2", "4x4"])
@pytest.mark.parametrize("name", suite.names())
def test_device_engine_matches_host_engine_all_sizes(name, size):
    """The remaining cells of the 11-kernel x {2x2, 3x3, 4x4} suite grid
    (3x3 runs in tier-1 above)."""
    r, c = int(size[0]), int(size[2])
    _, cnfs = _window_cnfs(name, CGRA(r, c))
    rh = solve_walksat_window(cnfs, seed=11, steps=800, batch=4,
                              engine="host")
    rd = solve_walksat_window(cnfs, seed=11, steps=800, batch=4,
                              engine="device")
    assert rh == rd


def test_device_engine_is_deterministic():
    _, cnfs = _window_cnfs("sha", CGRA(3, 3))
    r1 = solve_walksat_window(cnfs, seed=4, steps=900, batch=6,
                              engine="device")
    r2 = solve_walksat_window(cnfs, seed=4, steps=900, batch=6,
                              engine="device")
    assert r1 == r2


def test_engine_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_WALKSAT_ENGINE", "host")
    _, cnfs = _window_cnfs("srand", CGRA(3, 3))
    assert solve_walksat_window(cnfs, seed=1, steps=400, batch=4) == \
        solve_walksat_window(cnfs, seed=1, steps=400, batch=4,
                             engine="host")


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        solve_walksat_window([_tiny_cnf()], engine="gpu-magic")


def test_solve_walksat_is_the_k1_window():
    """The single-CNF entry point must be byte-equivalent to a K=1 window
    (shared pack, chunk schedule, and PRNG stream)."""
    cnf = _window_cnfs("gsm", CGRA(3, 3))[1][0]
    assert solve_walksat(cnf, seed=9, steps=700, batch=4) == \
        solve_walksat_window([cnf], seed=9, steps=700, batch=4)[0]


# ------------------------------------------------------ warm-start regression
def test_warm_start_longer_than_window_does_not_crash():
    """Regression: a warm-start hint from a previous, *larger* window
    (more padded vars) used to crash _init_assign with a NumPy shape
    mismatch. The hint must be truncated defensively."""
    cnf = _tiny_cnf(8)
    init = [True] * 100000          # way beyond any padded var count
    status, model = solve_walksat(cnf, seed=0, steps=400, batch=4,
                                  init=init)
    assert status == SAT and cnf.check(model)


def test_warm_start_shrinking_window_across_iis():
    """End-to-end shrinking-window shape change: warm-start assignments
    recorded against a big kernel's padded var space must be usable as
    inits for a much smaller formula's window."""
    _, big = _window_cnfs("sha", CGRA(3, 3))          # thousands of vars
    nm: dict = {}
    solve_walksat_window([big[0]], seed=0, steps=300, batch=4,
                         near_miss=nm)
    assert 0 in nm                                     # II=MII is hard
    carried = nm[0][1]
    small = _tiny_cnf(5)
    assert len(carried) > small.n_vars
    res = solve_walksat_window([small], seed=0, steps=400, batch=4,
                               inits=[carried])
    assert res[0][0] == SAT and small.check(res[0][1])


def test_warm_start_shorter_init_is_padded():
    cnf = _window_cnfs("nw", CGRA(3, 3))[1][0]
    status, model = solve_walksat(cnf, seed=2, steps=800, batch=6,
                                  init=[True, False, True])
    if status == SAT:
        assert cnf.check(model)


# ------------------------------------------------------- near-miss semantics
@pytest.mark.parametrize("engine", ["host", "device"])
def test_near_miss_excludes_solved_and_skipped(engine):
    """Only still-pending candidates may emit near-misses: solved ones
    have a model (a near-miss would be stale), skipped ones are no longer
    interesting (their assignment would pollute the warm-start dict)."""
    iis, cnfs = _window_cnfs("sha", CGRA(3, 3))
    near: dict = {}
    res = solve_walksat_window(
        cnfs, seed=3, steps=1500, batch=8, engine=engine,
        should_skip=lambda i: i == 2,      # candidate 2 abandoned
        near_miss=near)
    assert 2 not in near
    for i, (status, _) in enumerate(res):
        if status == SAT:
            assert i not in near
    for i, (nu, assign) in near.items():
        assert nu > 0
        assert res[i][0] == UNKNOWN
        # consistency: the reported quality matches a recount
        n_unsat = sum(
            1 for cl in cnfs[i].clauses
            if not any((lit > 0) == assign[abs(lit) - 1] for lit in cl))
        assert n_unsat == nu


@pytest.mark.parametrize("engine", ["host", "device"])
def test_near_miss_streams_improvements(engine):
    """on_near_miss must fire while the walk runs, monotonically
    improving per candidate, and agree with the final near_miss dict."""
    _, cnfs = _window_cnfs("sha", CGRA(3, 3))
    seen: dict = {}
    final: dict = {}

    def on_nm(i, nu, assign):
        assert i not in seen or nu < seen[i]
        seen[i] = nu

    res = solve_walksat_window(cnfs[:1], seed=3, steps=1500, batch=6,
                               engine=engine, near_miss=final,
                               on_near_miss=on_nm)
    if res[0][0] == UNKNOWN:
        assert 0 in seen and 0 in final
        assert seen[0] == final[0][0]


# --------------------------------------------------------- chunk scheduling
def test_chunk_plan_honours_small_budgets():
    """Regression: solve_walksat used to run at least 256 steps even for
    steps=64. The shared plan must never exceed the caller's budget on
    the first chunk."""
    cap, chunk0 = _chunk_plan(64, 100)
    assert cap == 64 and chunk0 == 64
    cap, chunk0 = _chunk_plan(8192, 100)
    assert cap == 2048 and chunk0 == 256


def test_chunk_plan_bounds_by_formula_size():
    """Big formulas get smaller chunks so stop()/skip polling stays
    responsive (both entry points now share this bound)."""
    cap_small, _ = _chunk_plan(20000, 1000)
    cap_big, _ = _chunk_plan(20000, 20000)
    assert cap_big < cap_small
    assert cap_big == max(64, 2_000_000 // 20000)


def test_chunk_schedule_lands_on_budget():
    for steps in (64, 100, 256, 1000, 4096, 20000):
        cap, chunk = _chunk_plan(steps, 500)
        done = 0
        while done < steps:
            done += chunk
            chunk = _next_chunk(chunk, cap, steps - done)
        # the shrink-to-land schedule overshoots by less than the minimal
        # chunk (the halving floor), never by a whole max-size chunk
        assert done >= steps
        assert done - steps < 256


def test_small_step_budget_is_respected_end_to_end():
    """steps=1 on a hard instance must return fast as UNKNOWN — the old
    max(256, ...) floor walked 256x the requested budget."""
    _, cnfs = _window_cnfs("sha", CGRA(3, 3))
    status, _ = solve_walksat(cnfs[0], seed=0, steps=1, batch=2)
    assert status == UNKNOWN


# ------------------------------------------------------- non-model guard
class _LyingCNF(CNF):
    """A CNF whose check() always fails — stands in for a miscompiled
    kernel / packer bug making the device claim SAT on a non-model."""

    def check(self, assignment):
        return False


@pytest.mark.parametrize("engine", ["host", "device"])
def test_non_model_raises_structured_error(engine):
    lying = _LyingCNF()
    src = _tiny_cnf(6)
    for _ in range(src.n_vars):
        lying.new_var()
    for cl in src.clauses:
        lying.add_clause(list(cl))
    with pytest.raises(NonModelError):
        solve_walksat_window([lying], seed=0, steps=2000, batch=8,
                             engine=engine)


def test_non_model_guard_is_not_an_assert():
    """The guard must survive `python -O` (it used to be a bare assert):
    NonModelError is a real exception type raised by _validate_model."""
    from repro.core.sat.walksat_jax import _validate_model
    assert issubclass(NonModelError, RuntimeError)
    with pytest.raises(NonModelError):
        _validate_model(_LyingCNF(), [], "unit")


# --------------------------------------------------- phase-hint feedback
def test_session_phase_hint_roundtrip():
    from repro.core.sat.portfolio import SolverSession
    g = running_example()
    sess = SolverSession(EncoderSession(g, CGRA(2, 2)), method="cdcl")
    assert sess.phase_hint() is None
    sess.update_best([True] * 10, 3)
    hint = sess.phase_hint()
    assert hint is not None and sess.phase_hints_served == 1
    assert sess.near_miss_updates == 1
    # a worse near-miss must not replace the banked one
    sess.update_best([False] * 10, 7)
    assert sess.near_miss_updates == 1
    # a full model always wins and is not a near-miss
    sess.update_best([False] * 10, 0)
    assert sess.near_miss_updates == 1 and sess.best_quality == 0


def test_sweep_with_phase_hints_still_equals_sequential():
    """The async near-miss -> phase-hint feedback must not change the
    sweep's II verdict (hinted models that fail regalloc are provisional
    and retried unhinted)."""
    from repro.core.mapper import MapperConfig, map_loop
    g = suite.get("sha")
    cgra = CGRA(3, 3)
    cfg = MapperConfig(solver="auto", timeout_s=90)
    seq = map_loop(g, cgra, cfg)
    swp = map_loop(suite.get("sha"), cgra, cfg, sweep_width=3)
    assert swp.ii == seq.ii
    assert any(a.phase_hinted is not None for a in swp.attempts)

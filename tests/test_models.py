"""LM substrate: per-arch smoke tests + numerical consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.models import layers
from repro.models.model import LM
from repro.optim import adamw


def _batch(cfg, key, B=2, S=32):
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    elif cfg.frontend == "vision_patches":
        fl = cfg.frontend_len
        batch["embeds"] = jax.random.normal(key, (B, fl, cfg.d_model),
                                            jnp.bfloat16)
        batch["tokens"] = jax.random.randint(key, (B, S - fl), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(key, (B, S - fl), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one train step, finite loss, shapes."""
    cfg = get_config(arch).smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    key = jax.random.PRNGKey(0)
    with mesh:
        params = lm.init(key)
        opt = adamw.init(params)
        batch = _batch(cfg, key)
        p2, o2, m = jax.jit(steps.make_train_step(lm))(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        assert int(o2["step"]) == 1
        # one step changed the params
        leaves1 = jax.tree.leaves(params)
        leaves2 = jax.tree.leaves(p2)
        assert any(not np.array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
                   for a, b in zip(leaves1, leaves2))


@pytest.mark.parametrize("arch", [
    pytest.param("yi_34b", marks=pytest.mark.slow),
    "hymba_1_5b", "mamba2_370m",
    pytest.param("deepseek_moe_16b", marks=pytest.mark.slow)])
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        cache = lm.init_cache(2, 16)
        dec = jax.jit(lm.decode_step)
        lg, cache = dec(params, cache, jnp.zeros((2, 1), jnp.int32),
                        jnp.int32(0))
        assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen1_5_32b", "mamba2_370m", "hymba_1_5b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward logits —
    validates the ring-buffer KV cache and the SSM state recurrence."""
    cfg = get_config(arch).smoke().replace(dtype="float32")
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    B, S = 2, 12
    with mesh:
        params = lm.init(jax.random.PRNGKey(1))
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
        x, _ = lm.forward(params, toks, window=cfg.attn_window)
        full_logits = lm.logits(params, x)          # [B, S, Vp]
        cache = lm.init_cache(B, 16)
        dec = jax.jit(lm.decode_step)
        for t in range(S):
            lg, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(lg[:, 0, :cfg.vocab]),
                np.asarray(full_logits[:, t, :cfg.vocab]),
                atol=2e-3, rtol=2e-3)


def test_blockwise_attention_matches_naive():
    rng = np.random.RandomState(0)
    b, s, h, kv, d = 2, 96, 4, 2, 16
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for window in (0, 17):
        got = layers.blockwise_attention(q, k, v, pos, pos, window=window,
                                         block=32)
        want = layers.naive_attention(q, k, v, pos, pos, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.slow
def test_moe_sort_equals_einsum():
    cfg = get_config("deepseek_moe_16b").smoke().replace(
        capacity_factor=8.0, moe_group=64, dtype="float32")
    key = jax.random.PRNGKey(1)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sc = 0.05
    p = {"router": jax.random.normal(key, (d, e)) * 0.1,
         "w_gate": jax.random.normal(key, (e, d, f)) * sc,
         "w_up": jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * sc,
         "w_down": jax.random.normal(jax.random.fold_in(key, 2), (e, f, d)) * sc,
         "shared": {
             "w_gate": jax.random.normal(key, (d, cfg.n_shared_experts * f)) * sc,
             "w_up": jax.random.normal(key, (d, cfg.n_shared_experts * f)) * sc,
             "w_down": jax.random.normal(key, (cfg.n_shared_experts * f, d)) * sc}}
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 32, d))
    o1, a1 = layers.moe_sort(cfg, p, x)
    o2, a2 = layers.moe_einsum(cfg, p, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_dead_head_padding_preserves_function():
    """Padded q-head slots must not change logits (zeroed wo rows)."""
    cfg = get_config("yi_34b").smoke()
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        toks = jnp.zeros((1, 8), jnp.int32)
        x, _ = lm.forward(params, toks)
        lg1 = lm.logits(params, x)
        # corrupt the dead q slots' wq columns: function must be unchanged
        mask = lm._dead_head_mask()
        wq = params["blocks"]["attn"]["wq"]
        L = wq.shape[0]
        wq4 = wq.reshape(L, cfg.d_model, -1, cfg.head_dim)
        noise = 7.0 * (1.0 - mask)[None, None, :, None]
        params["blocks"]["attn"]["wq"] = (
            wq4 + noise.astype(wq.dtype)).reshape(wq.shape)
        x2, _ = lm.forward(params, toks)
        lg2 = lm.logits(params, x2)
        np.testing.assert_allclose(np.asarray(lg1, np.float32),
                                   np.asarray(lg2, np.float32),
                                   atol=1e-2, rtol=1e-2)


def test_rmsnorm_custom_vjp_matches_autodiff():
    def plain(x, w, eps=1e-5):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 16), jnp.float32)
    w = jnp.asarray(rng.rand(16), jnp.float32)
    g1 = jax.grad(lambda x, w: jnp.sum(jnp.sin(layers.rmsnorm(x, w, 1e-5))),
                  argnums=(0, 1))(x, w)
    g2 = jax.grad(lambda x, w: jnp.sum(jnp.sin(plain(x, w))),
                  argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_loss_decreases_on_learnable_data():
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.train import train_loop
    cfg = get_config("minitron_8b").smoke()
    out = train_loop(cfg, steps=80, global_batch=8, seq_len=32, log_every=0)
    assert out["loss"] < np.log(cfg.vocab)   # better than uniform

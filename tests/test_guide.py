"""Learned II guidance: persistence, sanitisation, registry resolution,
training, and — above all — the soundness contract: guidance may only
change how the sweep spends wall-clock, never the final II."""
import numpy as np
import pytest

from repro.core import suite
from repro.core.arch import arch
from repro.core.campaign import N_FEATURES, cell_features
from repro.core.cgra import CGRA
from repro.core.dfg import running_example
from repro.core.guide import (GuideSuggestion, IIGuide, MAX_GUIDED_SPAN,
                              N_OFFSETS, clear_guides, init_guide,
                              register_guide, resolve_guide)
from repro.core.mapper import MapperConfig, map_loop

CFG = MapperConfig(solver="auto", timeout_s=90)


@pytest.fixture(autouse=True)
def _clean_registry():
    clear_guides()
    yield
    clear_guides()


# ------------------------------------------------------------- unit layer

def test_guide_save_load_roundtrip(tmp_path):
    g = init_guide(seed=7)
    x = np.arange(N_FEATURES, dtype=np.float32)
    path = str(tmp_path / "g.npz")
    g.save(path)
    g2 = IIGuide.load(path)
    p1, h1 = g.predict(x)
    p2, h2 = g2.predict(x)
    assert np.allclose(p1, p2) and h1 == h2
    s1, s2 = g.suggest(x), g2.suggest(x)
    assert s1.order == s2.order and s1.offset == s2.offset


def test_guide_rejects_wrong_feature_width():
    g = init_guide()
    params = dict(g.params)
    params["w1"] = params["w1"][: N_FEATURES - 1]
    params["mean"] = params["mean"][: N_FEATURES - 1]
    params["std"] = params["std"][: N_FEATURES - 1]
    with pytest.raises(ValueError):
        IIGuide(params)


def test_suggest_sanitises_degenerate_forward_pass():
    """NaN parameters must degrade to the uniform 'no opinion' suggestion
    — the mapping path never sees an exception or a NaN probability."""
    g = init_guide()
    g.params["w1"] = np.full_like(g.params["w1"], np.nan)
    s = g.suggest(np.ones(N_FEATURES, dtype=np.float32))
    assert len(s.order) == N_OFFSETS
    assert all(np.isfinite(p) for p in s.probs)
    assert abs(sum(s.probs) - 1.0) < 1e-5
    assert 0.0 <= s.hopeless <= 1.0
    assert s.offset == 0          # uniform ties resolve lowest-first


def test_span_from_semantics():
    s = GuideSuggestion(offset=3, order=(3, 0, 6, 1, 2, 4, 5, 7),
                        probs=(0.0,) * N_OFFSETS, hopeless=0.0)
    assert s.span_from(0) == 4    # stretch to cover the predicted offset
    assert s.span_from(3) == 1    # already there: race exactly one II
    assert s.span_from(4) == 3    # best not-yet-passed candidate is 6
    assert s.span_from(99) == 1   # past every prediction: minimal windows
    hopeless = GuideSuggestion(offset=0, order=tuple(range(N_OFFSETS)),
                               probs=(0.0,) * N_OFFSETS, hopeless=0.9)
    assert hopeless.span_from(0) == MAX_GUIDED_SPAN


def test_registry_resolution(tmp_path):
    assert resolve_guide(None) is None
    assert resolve_guide("nope-not-registered") is None
    g = init_guide(seed=1)
    register_guide("mine", g)
    assert resolve_guide("mine") is g
    register_guide("mine", None)
    assert resolve_guide("mine") is None
    path = str(tmp_path / "ckpt.npz")
    g.save(path)
    loaded = resolve_guide(path)
    assert isinstance(loaded, IIGuide)
    assert resolve_guide(path) is loaded     # cached after first load
    bad = str(tmp_path / "garbage.npz")
    with open(bad, "wb") as f:
        f.write(b"not an npz")
    assert resolve_guide(bad) is None


# -------------------------------------------------------------- soundness

class _AdversarialGuide:
    """Worst-case guidance: always claims the II lives far above MII and
    that the cell is probably hopeless. May only waste wall-clock."""

    def suggest(self, features):
        order = tuple(range(N_OFFSETS - 1, -1, -1))
        return GuideSuggestion(offset=N_OFFSETS - 1, order=order,
                               probs=(1.0 / N_OFFSETS,) * N_OFFSETS,
                               hopeless=0.49)


SOUNDNESS_CELLS = [("sha", CGRA(3, 3)), ("gsm", CGRA(3, 3)),
                   ("bitcount", CGRA(4, 4))]


@pytest.mark.parametrize("name,cgra", SOUNDNESS_CELLS,
                         ids=[n for n, _ in SOUNDNESS_CELLS])
def test_guided_sweep_ii_equals_unguided(name, cgra):
    """An untrained (random) guide and an adversarial one both leave the
    final II bit-identical to the unguided sweep — guidance is window
    extents only."""
    register_guide("random", init_guide(seed=9))
    register_guide("adversarial", _AdversarialGuide())
    g = suite.get(name)
    base = map_loop(suite.get(name), cgra, CFG, sweep_width=4)
    for spec in ("random", "adversarial"):
        cfg = MapperConfig(solver="auto", timeout_s=90, guide=spec)
        r = map_loop(suite.get(name), cgra, cfg, sweep_width=4)
        assert r.success == base.success
        assert r.ii == base.ii, (name, spec)
        assert r.guidance and r.guidance["used"]
        assert r.guidance["spans"]
        # every II from MII up to the winner was attempted — no II is
        # ever skipped, whatever the guide said (higher same-window
        # candidates may appear too; that is wall-clock, not soundness)
        tried = {a.ii for a in r.attempts}
        assert set(range(r.mii, r.ii + 1)) <= tried


def test_unresolvable_guide_name_runs_unguided():
    g = running_example()
    cfg = MapperConfig(solver="auto", timeout_s=90,
                       guide="no-such-guide-anywhere")
    r = map_loop(g, CGRA(2, 2), cfg, sweep_width=4)
    base = map_loop(running_example(), CGRA(2, 2), CFG, sweep_width=4)
    assert r.success and r.ii == base.ii == 3
    assert r.guidance == {"guide": "no-such-guide-anywhere", "used": False}


def test_guide_ignored_at_sweep_width_one():
    register_guide("random", init_guide(seed=2))
    cfg = MapperConfig(solver="auto", timeout_s=90, guide="random")
    r = map_loop(running_example(), CGRA(2, 2), cfg, sweep_width=1)
    assert r.success and r.ii == 3
    assert r.guidance is None


@pytest.mark.slow
@pytest.mark.parametrize("width", [1, 4])
def test_full_suite_soundness_gate(width):
    """The CI-grade gate: guided == unguided final II on *every* suite
    cell at both sweep widths (33 cells x 2 widths)."""
    register_guide("random", init_guide(seed=3))
    for name in suite.names():
        for size in ("2x2", "3x3", "4x4"):
            fabric = arch(size)
            base = map_loop(suite.get(name), fabric, CFG,
                            sweep_width=width)
            cfg = MapperConfig(solver="auto", timeout_s=90, guide="random")
            r = map_loop(suite.get(name), fabric, cfg, sweep_width=width)
            assert (r.success, r.ii) == (base.success, base.ii), \
                (name, size, width)


# --------------------------------------------------------------- training

def _synthetic_records(n=160, seed=0):
    """Records whose offset is a simple function of one feature — enough
    signal for a tiny MLP to beat the offset-0 baseline."""
    from repro.core.campaign import CellRecord
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        feats = rng.normal(0, 1, N_FEATURES).astype(np.float32)
        off = int(feats[0] > 0) + int(feats[1] > 0)   # offsets 0..2
        key = bytes([int(rng.integers(0, 256))]) + bytes(31)
        recs.append(CellRecord(
            key=key, dfg_key=bytes(32), name=f"s{i}", kind="random",
            fabric="2x2", n_nodes=8, features=feats, mii=3, ii=3 + off,
            success=True, infeasible=False, attempts=(),
            total_time=0.01))
    return recs


def test_train_guide_learns_synthetic_signal():
    from repro.core.guide import train_guide
    guide, metrics = train_guide(_synthetic_records(), seed=0, hidden=16,
                                 epochs=60, batch=64)
    assert metrics["n_train"] > 0 and metrics["n_heldout"] > 0
    assert metrics["hit1"] > metrics["baseline_hit1"]
    # the trained artifact round-trips through suggest()
    s = guide.suggest(np.zeros(N_FEATURES, dtype=np.float32))
    assert 0 <= s.offset < N_OFFSETS


def test_train_guide_drops_infeasible_cells():
    from repro.core.guide import _dataset_arrays
    recs = _synthetic_records(n=20)
    recs[0].infeasible = True
    recs[1].ii = None
    recs[1].success = False
    X, yo, yh, held = _dataset_arrays(recs)
    assert len(X) == 19                       # infeasible dropped
    assert yo.max() <= N_OFFSETS - 1
    assert yh.sum() == 1.0                    # the refuted cell labels hop

"""Serving tier: shared disk store behind the service, near-shape warm
admission, the multi-process worker pool (inline mode in-process), the
async batched front door, signature memoization, and thread-safety of the
shared :class:`MappingService` under concurrent hammering."""
import asyncio
import copy
import threading

import pytest

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.encode import EncoderSession
from repro.core.mapper import MapperConfig
from repro.core.sat.portfolio import SolverSession
from repro.core.service import (MappingService, dfg_signature,
                                near_shape_key, shape_signature)
from repro.core.simulator import verify_mapping
from repro.core.store import MappingStore
from repro.core.workers import WorkerPool
from repro.launch.serve import CompileFrontDoor, DeadlineExceeded

CFG = MapperConfig(solver="auto", timeout_s=90)


def _near_variant(g):
    """One rewired edge: same node/edge counts, kinds, and distance set
    (same lattice bucket), different exact wiring (different shape)."""
    g2 = copy.deepcopy(g)
    for nid in sorted(g2.nodes):
        ins = g2.nodes[nid].ins
        if (len(ins) == 2 and ins[0][1] == 0 and ins[1][1] == 0
                and ins[0][0] != ins[1][0]):
            g2.nodes[nid].ins = ((ins[0][0], 0), (ins[0][0], 0))
            g2.touch()
            g2.validate()
            return g2
    raise AssertionError("kernel has no rewireable two-input node")


# ------------------------------------------------- signature memoization

def test_signature_memoized_on_instance_and_invalidated_on_mutation():
    g = suite.get("sha")
    assert g._sig_cache == {}
    s1 = dfg_signature(g)
    assert g._sig_cache                       # populated by the first call
    # memo hit: the cached object itself is returned
    assert dfg_signature(g) is s1
    sh1 = shape_signature(g)
    cgra = CGRA(3, 3)
    sh_arch = shape_signature(g, cgra)
    assert shape_signature(g) is sh1          # arch=None and arch=cgra are
    assert shape_signature(g, cgra) is sh_arch   # separate memo keys
    # structural mutation clears the memo and changes the signature
    g.add("add", [(0, 0), (0, 0)])
    assert g._sig_cache == {}
    assert dfg_signature(g) != s1
    # in-place edits go through touch()
    g2 = suite.get("sha")
    dfg_signature(g2)
    g2.touch()
    assert g2._sig_cache == {}


def test_deepcopy_does_not_share_memo():
    g = suite.get("gsm")
    dfg_signature(g)
    g2 = copy.deepcopy(g)
    assert g2._sig_cache == {}
    assert dfg_signature(g2) == dfg_signature(g)


# --------------------------------------------------- near-shape lattice

def test_near_shape_key_buckets_variants_together():
    g = suite.get("sha")
    gv = _near_variant(g)
    assert shape_signature(g) != shape_signature(gv)
    assert near_shape_key(shape_signature(g), 1) \
        == near_shape_key(shape_signature(gv), 1)
    other = suite.get("gsm")
    assert near_shape_key(shape_signature(g), 1) \
        != near_shape_key(shape_signature(other), 1)


def test_service_near_shape_admission_seeds_fresh_session():
    svc = MappingService(near_delta=1)
    cgra = CGRA(3, 3)
    g = suite.get("sha")
    r1 = svc.map(g, cgra, CFG)
    assert r1.success and not r1.service.near_seeded
    gv = _near_variant(g)
    r2 = svc.map(gv, cgra, CFG)
    assert r2.success
    assert r2.service.near_seeded
    assert svc.stats.near_hits == 1
    # admission is heuristic only — the mapping must still verify
    assert verify_mapping(r2.dfg, cgra, r2.placement, r2.ii, n_iters=5).ok
    # near_delta=0 disables the lattice entirely
    svc0 = MappingService(near_delta=0)
    svc0.map(g, cgra, CFG)
    svc0.map(gv, cgra, CFG)
    assert svc0.stats.near_hits == 0


# ------------------------------------------------------- disk-tier service

def test_service_disk_tier_restart_hits_and_core_preload(tmp_path):
    path = str(tmp_path / "store")
    cgra = CGRA(3, 3)
    g = suite.get("sha")
    svc1 = MappingService(store=MappingStore(path))
    r_cold = svc1.map(g, cgra, CFG)
    assert r_cold.success and r_cold.service.via == "cold"
    assert svc1.stats.disk_writes == 1
    had_unsat = any(a.status == "UNSAT" for a in r_cold.attempts)

    # a fresh service (≈ restarted process) over the same store directory
    svc2 = MappingService(store=MappingStore(path))
    r_disk = svc2.map(g, cgra, CFG)
    assert r_disk.service.via == "disk"
    assert svc2.stats.disk_hits == 1
    assert (r_disk.ii, r_disk.placement) == (r_cold.ii, r_cold.placement)

    # forcing a re-solve builds a session that preloads the persisted
    # cores and prunes the proven-UNSAT IIs without solving them
    r_resolve = svc2.map(g, cgra, CFG, use_cache=False)
    assert r_resolve.success and r_resolve.ii == r_cold.ii
    if had_unsat:
        assert svc2.stats.cores_preloaded > 0
        assert r_resolve.service.iis_pruned > 0
        assert all(a.via == "core" for a in r_resolve.attempts
                   if a.status == "UNSAT")


# ------------------------------------------------ concurrent service hammer

def test_service_concurrent_hammer_is_consistent():
    """Many threads, few kernels: the RLock'd pool/cache/stats must stay
    consistent and every thread must see the same verified results."""
    svc = MappingService()
    cgra = CGRA(3, 3)
    kernels = [suite.get("sha"), suite.get("gsm")]
    n_threads, per_thread = 8, 4
    results, errors = [], []

    def worker(t):
        try:
            for i in range(per_thread):
                g = kernels[(t + i) % len(kernels)]
                results.append((g.name, svc.map(g, cgra, CFG)))
        except Exception as e:          # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    assert len(results) == n_threads * per_thread
    assert svc.stats.requests == n_threads * per_thread
    # every request for one kernel agrees on the result
    by_kernel = {}
    for name, r in results:
        assert r.success
        by_kernel.setdefault(name, set()).add(
            (r.ii, tuple(sorted(r.placement.items()))))
    assert all(len(v) == 1 for v in by_kernel.values())
    # concurrent first requests may each miss the cache before the first
    # solve lands (they serialise on the session lock and agree on the
    # result), but at most one miss per (thread, kernel) is possible and
    # the pool must hold exactly one session per shape
    assert svc.stats.cache_hits >= n_threads * per_thread \
        - n_threads * len(kernels)
    assert svc.stats.sessions_created == len(kernels)


# ------------------------------------------------------ pack-cache bounding

def test_session_pack_cache_lru_bounded_and_counted():
    g = suite.get("sha")
    sess = SolverSession(EncoderSession(g, CGRA(3, 3), CFG.amo),
                         method="cdcl", seed=7)
    sess.max_cached_packs = 2
    for ii in range(3, 8):
        sess.ensure_ii(ii)
        sess.host_pack(ii)
    assert len(sess._pack_np) == 2
    assert sess.pack_evictions == 3
    # the LRU survivor is a hit, the evicted II repacks
    _, reused = sess.host_pack(7)
    assert reused and sess.pack_reuses >= 1
    _, reused = sess.host_pack(3)
    assert not reused
    # the counter is surfaced through the service stats snapshot
    snap = MappingService().stats.snapshot()
    assert "pack_evictions" in snap and "pack_reuses" in snap


# ------------------------------------------------------------- worker pool

def test_worker_pool_inline_routes_and_aggregates(tmp_path):
    cgra = CGRA(3, 3)
    kernels = [suite.get(n) for n in ("sha", "gsm", "srand")]
    with WorkerPool(workers=2, store_path=str(tmp_path / "store"),
                    inline=True) as pool:
        shards = {pool.shard_of(g, cgra, CFG) for g in kernels}
        assert shards <= {0, 1}
        futs = [pool.submit(g, cgra, CFG) for g in kernels]
        res = [f.result(timeout=120) for f in futs]
        assert all(r.success for r in res)
        # affinity is stable: the same request routes to the same shard
        assert pool.shard_of(kernels[0], cgra, CFG) \
            == pool.shard_of(kernels[0], cgra, CFG)
        again = pool.map(kernels[0], cgra, CFG)
        assert again.service.via == "cache"
        st = pool.stats()
        assert st["requests"] == 4 and st["inline"]
        assert st["n_workers"] == 2 and len(st["shards"]) == 1


# ------------------------------------------------------------- front door

def test_front_door_coalesces_and_matches_direct(tmp_path):
    cgra = CGRA(3, 3)
    g = suite.get("srand")
    gother = suite.get("bitcount")

    async def drive():
        with WorkerPool(workers=2, store_path=str(tmp_path / "store"),
                        inline=True) as pool:
            async with CompileFrontDoor(pool, window_ms=20,
                                        max_batch=64) as door:
                res = await asyncio.gather(*(
                    [door.compile(g, cgra, CFG) for _ in range(12)]
                    + [door.compile(gother, cgra, CFG)]))
                stats = door.stats.snapshot()
        return res, stats

    res, stats = asyncio.run(drive())
    assert all(r.success for r in res)
    assert len({(r.ii, tuple(sorted(r.placement.items())))
                for r in res[:12]}) == 1
    assert stats["submitted"] == stats["served"] == 13
    assert stats["coalesced"] >= 1 and stats["failed"] == 0
    # the served result equals the direct in-process reference
    from repro.core.mapper import map_loop
    ref = map_loop(g, cgra, CFG)
    assert (res[0].ii, res[0].placement) == (ref.ii, ref.placement)


def test_front_door_enforces_deadlines():
    cgra = CGRA(3, 3)
    g = suite.get("nw")

    async def drive():
        with WorkerPool(workers=1, inline=True) as pool:
            async with CompileFrontDoor(pool) as door:
                with pytest.raises(DeadlineExceeded):
                    await door.compile(g, cgra, CFG, deadline_s=1e-4)
                # a sane deadline still serves (the in-flight solve from
                # the expired request keeps warming the shard)
                r = await door.compile(g, cgra, CFG, deadline_s=120)
                return r, door.stats.snapshot()

    r, stats = asyncio.run(drive())
    assert r.success
    assert stats["deadline_violations"] == 1
    assert stats["served"] == 1

"""Property tests for the flat clause arena.

The arena (``repro.core.cnf.ClauseArena``) replaced the list-of-tuples
clause store; everything downstream — session signatures, the UNSAT
registry, WalkSAT packing — keys on the exact clause stream, so the
arena-backed ``CNF``/``IncrementalCNF`` must round-trip *bit for bit*
to the legacy view: same clause order, same literals, same selector
guards, same ``project()`` output. These tests pin that on random
formulas and on real encoder output, and pin ``pack_cnf_np`` against a
per-clause reference pack.
"""
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import HealthCheck, given, settings, strategies as st

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.cnf import (ClauseArena, CNF, EmptyClauseError,
                            IncrementalCNF)
from repro.core.encode import EncoderSession, IncrementalEncoding
from repro.core.sat.walksat_jax import pack_cnf_np


# --------------------------------------------------------------- strategies

@st.composite
def random_formula(draw):
    """(n_vars, clauses) with clauses as lists of nonzero lits.

    Allows duplicate literals within a clause and duplicate clauses —
    the arena must preserve the stream verbatim, not normalise it.
    """
    n_vars = draw(st.integers(1, 12))
    n_clauses = draw(st.integers(0, 25))
    clauses = []
    for _ in range(n_clauses):
        k = draw(st.integers(1, 5))
        cl = []
        for _ in range(k):
            v = draw(st.integers(1, n_vars))
            cl.append(v if draw(st.booleans()) else -v)
        clauses.append(cl)
    return n_vars, clauses


def build_cnf(n_vars, clauses, data):
    """Build a CNF from ``clauses`` choosing randomly, per clause, among
    the three entry points (``add``, ``add_clause``, ``extend_flat``) —
    all must yield the same stream."""
    cnf = CNF()
    for _ in range(n_vars):
        cnf.new_var()
    i = 0
    while i < len(clauses):
        how = data.draw(st.integers(0, 2))
        if how == 0:
            cnf.add(*clauses[i])
            i += 1
        elif how == 1:
            cnf.add_clause(clauses[i])
            i += 1
        else:   # bulk: a run of 1..4 clauses in one extend_flat
            run = clauses[i:i + data.draw(st.integers(1, 4))]
            flat = np.asarray([l for c in run for l in c], dtype=np.int32)
            lens = np.asarray([len(c) for c in run], dtype=np.int64)
            cnf.extend_flat(flat, lens)
            i += len(run)
    return cnf


# ------------------------------------------------------- CNF round-tripping

@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_formula(), st.data())
def test_cnf_roundtrips_to_legacy_view(formula, data):
    n_vars, clauses = formula
    ref = [tuple(c) for c in clauses]
    cnf = build_cnf(n_vars, clauses, data)

    # the view IS the legacy list-of-tuples, in order
    assert list(cnf.clauses) == ref
    assert len(cnf.clauses) == len(ref)
    assert cnf.n_clauses == len(ref)
    if ref:
        idx = data.draw(st.integers(0, len(ref) - 1))
        assert cnf.clauses[idx] == ref[idx]
        assert cnf.clauses[-1] == ref[-1]
        assert list(cnf.clauses[idx:]) == ref[idx:]
        assert ref[idx] in cnf.clauses
    assert (0, 0) not in cnf.clauses
    assert cnf.clauses == ref

    # CSR invariants: offs monotone, lits[offs[i]:offs[i+1]] == clause i
    offs = cnf.arena.offs_view()
    lits = cnf.arena.lits_view()
    assert offs[0] == 0 and offs[-1] == lits.size
    assert (np.diff(offs) >= 0).all()
    for i, c in enumerate(ref):
        assert tuple(lits[offs[i]:offs[i + 1]]) == c

    # round-trip through from_arrays and copy()
    rt = ClauseArena.from_arrays(lits, offs)
    assert list(rt.iter_tuples()) == ref
    cp = cnf.arena.copy()
    cp.add((1,))
    assert list(cnf.clauses) == ref     # copy is independent

    # check() agrees with a naive Python evaluator
    assign = [data.draw(st.booleans()) for _ in range(n_vars)]
    naive = all(any(assign[abs(l) - 1] == (l > 0) for l in c) for c in ref)
    assert cnf.check(assign) == naive


def test_empty_clause_semantics():
    with pytest.raises(EmptyClauseError):
        CNF().add()
    with pytest.raises(EmptyClauseError):
        IncrementalCNF().add()
    cnf = CNF()
    cnf.add_clause([])
    assert cnf.trivially_unsat and list(cnf.clauses) == [()]
    cnf2 = CNF()
    cnf2.extend_flat(np.asarray([3], np.int32), np.asarray([1, 0], np.int64))
    assert cnf2.trivially_unsat and list(cnf2.clauses) == [(3,), ()]


def test_at_most_one_pairwise_limit():
    def pairwise_ref(lits):
        return [(-lits[i], -lits[j]) for i in range(len(lits))
                for j in range(i + 1, len(lits))]

    # sequential falls back to pairwise at/below the limit: no fresh vars
    for k, limit, expect_pairwise in [(4, 4, True), (5, 4, False),
                                      (5, 8, True), (3, 1, False)]:
        cnf = CNF()
        lits = cnf.new_vars(k)
        cnf.at_most_one(lits, "sequential", pairwise_limit=limit)
        if expect_pairwise:
            assert cnf.n_vars == k
            assert list(cnf.clauses) == pairwise_ref(lits)
        else:
            assert cnf.n_vars == k + (k - 1)    # Sinz registers
            assert cnf.n_clauses == 3 * k - 4

    # large pairwise groups take the vectorised bulk path — same stream
    cnf = CNF()
    lits = cnf.new_vars(11)
    cnf.at_most_one(lits, "pairwise")
    assert list(cnf.clauses) == pairwise_ref(lits)


# ------------------------------------------------- IncrementalCNF layering

@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_formula(), st.integers(1, 3), st.data())
def test_incremental_guards_and_project(formula, n_layers, data):
    n_vars, base = formula
    inc = IncrementalCNF()
    for _ in range(n_vars):
        inc.new_var()
    for c in base:
        inc.add_clause(c)

    layers = {}
    for key in range(n_layers):
        sel = inc.begin_layer(key)
        n_cl = data.draw(st.integers(0, 6))
        rows = []
        for _ in range(n_cl):
            k = data.draw(st.integers(1, 4))
            rows.append([data.draw(st.integers(1, n_vars))
                         * (1 if data.draw(st.booleans()) else -1)
                         for _ in range(k)])
        # split randomly between per-clause and bulk entry
        cut = data.draw(st.integers(0, n_cl))
        for c in rows[:cut]:
            inc.add_clause(c)
        tail = rows[cut:]
        if tail:
            inc.extend_flat(
                np.asarray([l for c in tail for l in c], np.int32),
                np.asarray([len(c) for c in tail], np.int64))
        inc.end_layer()
        layers[key] = (sel, rows)

    ref_base = [tuple(c) for c in base]
    view = list(inc.clauses)
    assert view[:len(ref_base)] == ref_base
    pos = len(ref_base)
    for key in range(n_layers):
        sel, rows = layers[key]
        assert inc.selector(key) == sel
        s, e = inc.layer_slice(key)
        assert (s, e) == (pos, pos + len(rows))
        for c in rows:   # every layer clause carries the ¬selector guard
            assert view[pos] == tuple(c) + (-sel,)
            pos += 1
    assert pos == len(view)

    for key in range(n_layers):
        sel, rows = layers[key]
        proj = inc.project(key)
        assert proj.n_vars == inc.n_vars
        assert list(proj.clauses) == ref_base + [tuple(c) for c in rows]
        # activating the layer via assumptions names exactly its selector
        assums = inc.assumptions_for(key)
        assert assums[0] == sel
        assert sorted(assums[1:]) == sorted(
            -layers[k][0] for k in layers if k != key)


# -------------------------------------------- real encoder output parity

@pytest.mark.parametrize("name,size,iis", [("srand", (3, 3), (4, 5)),
                                           ("nw", (4, 4), (3, 4))])
def test_encoder_streams_match_legacy(name, size, iis):
    g = suite.get(name)
    cgra = CGRA(*size)
    legacy = EncoderSession(g, cgra, emitters="legacy")
    vector = EncoderSession(g, cgra, emitters="vector")
    for ii in iis:
        el, ev = legacy.encode(ii), vector.encode(ii)
        assert list(el.cnf.clauses) == list(ev.cnf.clauses)
        assert el.cnf.n_vars == ev.cnf.n_vars
        assert el.cnf.stats() == ev.cnf.stats()

    il = IncrementalEncoding(legacy)
    iv = IncrementalEncoding(vector)
    for ii in iis:
        il.ensure_ii(ii)
        iv.ensure_ii(ii)
        assert list(il.inc.clauses) == list(iv.inc.clauses)
        pl, pv = il.project(ii), iv.project(ii)
        assert list(pl.clauses) == list(pv.clauses)
        # projection matches the cold encode of the same II as a clause
        # multiset (the incremental build splits C2 fold pairs between
        # base and layer, so the order differs from the cold stream)
        cold = vector.encode(ii).cnf
        assert sorted(pv.clauses) == sorted(cold.clauses)
        assert pv.n_vars >= cold.n_vars   # selectors on top of the layout


# ------------------------------------------------------- pack parity

def _legacy_pack(cnf):
    """Pre-arena per-clause pack (PR 6), pinned as the oracle."""
    C, V = cnf.n_clauses, cnf.n_vars
    lmax = max((len(c) for c in cnf.clauses), default=1) if C else 1
    lmax = max(lmax, 1)
    cvars = np.zeros((C, lmax), np.int32)
    csign = np.zeros((C, lmax), bool)
    occ = {v: [] for v in range(V + 1)}
    for i, cl in enumerate(cnf.clauses):
        for j, lit in enumerate(cl):
            v = abs(lit)
            cvars[i, j] = v
            csign[i, j] = lit > 0
            occ[v].append((i, lit > 0))
    omax = max((len(o) for o in occ.values()), default=0)
    ovars = np.full((V + 1, omax), -1, np.int32)
    osign = np.zeros((V + 1, omax), bool)
    for v, entries in occ.items():
        for j, (ci, sg) in enumerate(entries):
            ovars[v, j] = ci
            osign[v, j] = sg
    return cvars, csign, ovars, osign


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_formula(), st.data())
def test_pack_matches_legacy(formula, data):
    n_vars, clauses = formula
    cnf = build_cnf(n_vars, clauses, data)
    p = pack_cnf_np(cnf)
    cv, cs, ov, os_ = _legacy_pack(cnf)
    np.testing.assert_array_equal(p.cvars, cv)
    np.testing.assert_array_equal(p.csign, cs)
    np.testing.assert_array_equal(p.ovars, ov)
    np.testing.assert_array_equal(p.osign, os_)
    assert (p.n_vars, p.n_clauses) == (n_vars, len(clauses))

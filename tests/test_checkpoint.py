"""Fault tolerance: atomic checkpoints, exact resume, elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.launch.train import train_loop


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x, np.float32),
                              np.asarray(y, np.float32))
               for x, y in zip(la, lb))


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                  "b16": jnp.ones((4, 2), jnp.bfloat16) * 1.5},
            "step": jnp.int32(7)}
    ckpt.save(str(tmp_path), 3, tree, extra={"data_cursor": 3}, chunks=2)
    got, manifest = ckpt.restore(str(tmp_path))
    assert manifest["step"] == 3
    assert manifest["extra"]["data_cursor"] == 3
    assert _leaves_equal(tree, got)
    assert str(np.asarray(got["a"]["b16"]).dtype) == "bfloat16"


def test_latest_and_retention(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"x": jnp.ones(2)}, keep_last=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]


def test_interrupted_write_is_invisible(tmp_path):
    """A .tmp dir (killed writer) must never be picked up."""
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones(2)})
    os.makedirs(os.path.join(str(tmp_path), "ckpt_00000002.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


@pytest.mark.slow
def test_crash_resume_is_bitwise_exact(tmp_path):
    """Train 12 steps with a crash at 8 + resume == train 12 uninterrupted.
    This is the end-to-end fault-tolerance contract."""
    cfg = get_config("minitron_8b").smoke()
    d1 = str(tmp_path / "a")
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(cfg, steps=12, global_batch=4, seq_len=16, ckpt_dir=d1,
                   ckpt_every=4, fail_at=8, log_every=0)
    out_resumed = train_loop(cfg, steps=12, global_batch=4, seq_len=16,
                             ckpt_dir=d1, ckpt_every=4, resume=True,
                             log_every=0)
    d2 = str(tmp_path / "b")
    out_straight = train_loop(cfg, steps=12, global_batch=4, seq_len=16,
                              ckpt_dir=d2, ckpt_every=4, log_every=0)
    p1 = out_resumed.pop("params")
    p2 = out_straight.pop("params")
    assert _leaves_equal(p1, p2)
    assert out_resumed["loss"] == out_straight["loss"]


def test_elastic_restore_to_different_mesh(tmp_path):
    """A checkpoint written under one mesh restores onto another (the
    elastic-rescale path); values identical, shardings re-derived."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = make_host_mesh()   # 1 device — "different" pod count
    specs = {"w": P(None, None)}
    got, _ = ckpt.restore(str(tmp_path), mesh=mesh, specs=specs)
    assert _leaves_equal(tree, got)
    assert got["w"].sharding.mesh.devices.size == mesh.devices.size


def test_data_pipeline_stateless_and_sharded():
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = get_config("minitron_8b").smoke()
    data = SyntheticLM(DataConfig(seed=1, global_batch=8, seq_len=16), cfg)
    a = data.batch_at(5)
    b = data.batch_at(5)
    assert np.array_equal(a["tokens"], b["tokens"])          # deterministic
    c = data.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the global batch: shard recompute == global slice
    s0 = data.batch_at(5, shard=0, n_shards=2)
    s1 = data.batch_at(5, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 4 and s1["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])

"""Incremental assumption-based SAT core: layered IncrementalCNF semantics,
CDCL assumption handling + learned-clause retention, incremental-vs-cold
equivalence for every backend, and the AMO encoding property tests."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import given, settings, strategies as st

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.cnf import CNF, EmptyClauseError, IncrementalCNF
from repro.core.dfg import DFG, running_example
from repro.core.encode import EncoderSession, IncrementalEncoding, encode
from repro.core.mapper import MapperConfig, map_loop
from repro.core.sat import SAT, UNKNOWN, UNSAT, solve
from repro.core.sat.cdcl import CDCLSolver
from repro.core.sat.portfolio import SolverSession, solve_window
from repro.core.simulator import verify_mapping


# ------------------------------------------------------------ CNF marker
def test_add_clause_empty_records_trivially_unsat_marker():
    cnf = CNF()
    cnf.n_vars = 2
    cnf.add_clause([1, 2])
    assert not cnf.trivially_unsat
    cnf.add_clause([])
    assert cnf.trivially_unsat
    assert not cnf.check([True, True])


@pytest.mark.parametrize("method", ["cdcl", "walksat", "auto"])
def test_backends_fail_fast_on_trivially_unsat(method):
    cnf = CNF()
    cnf.n_vars = 3
    cnf.add_clause([1, 2])
    cnf.add_clause([])
    assert solve(cnf, method)[0] == UNSAT


def test_add_raises_on_empty():
    # a real exception, not a bare assert: must survive python -O
    with pytest.raises(EmptyClauseError):
        CNF().add()
    with pytest.raises(EmptyClauseError):
        IncrementalCNF().add()


# ------------------------------------------------------ IncrementalCNF
def _inc_two_layers():
    """Base: (x1). Layer 'a': (x2). Layer 'b': (¬x2)."""
    inc = IncrementalCNF()
    x1, x2 = inc.new_vars(2)
    inc.add(x1)
    inc.begin_layer("a")
    inc.add(x2)
    inc.end_layer()
    inc.begin_layer("b")
    inc.add(-x2)
    inc.end_layer()
    return inc, x1, x2


def test_layer_clauses_carry_selector_guard():
    inc, x1, x2 = _inc_two_layers()
    sa, sb = inc.selector("a"), inc.selector("b")
    assert (x1,) in inc.clauses                 # base unguarded
    assert (x2, -sa) in inc.clauses             # guarded by ¬selector
    assert (-x2, -sb) in inc.clauses
    assert set(inc.assumptions_for("a")) == {sa, -sb}


def test_projection_strips_guards():
    inc, x1, x2 = _inc_two_layers()
    pa = inc.project("a")
    assert (x1,) in pa.clauses and (x2,) in pa.clauses
    assert all(len(c) <= 2 for c in pa.clauses)
    pb = inc.project("b")
    assert (-x2,) in pb.clauses and (x2,) not in pb.clauses


def test_assumption_solve_activates_exactly_one_layer():
    inc, x1, x2 = _inc_two_layers()
    solver = CDCLSolver(inc)
    sta, ma = solver.solve(assumptions=inc.assumptions_for("a"))
    assert sta == SAT and ma[x1 - 1] and ma[x2 - 1]
    stb, mb = solver.solve(assumptions=inc.assumptions_for("b"))
    assert stb == SAT and mb[x1 - 1] and not mb[x2 - 1]


def test_empty_clause_inside_layer_is_layer_local():
    inc = IncrementalCNF()
    x1 = inc.new_var()
    inc.add(x1)
    inc.begin_layer("dead")
    inc.add_clause([])          # forbids activating this layer only
    inc.end_layer()
    inc.begin_layer("live")
    inc.add(-x1, x1)
    inc.end_layer()
    assert not inc.trivially_unsat
    assert inc.project("dead").trivially_unsat
    solver = CDCLSolver(inc)
    assert solver.solve(assumptions=inc.assumptions_for("dead"))[0] == UNSAT
    assert solver.solve(assumptions=inc.assumptions_for("live"))[0] == SAT


# ----------------------------------------------------- CDCL assumptions
def test_cdcl_assumptions_basic_semantics():
    cnf = CNF()
    cnf.n_vars = 2
    cnf.add(1, 2)
    s = CDCLSolver(cnf)
    st_, m = s.solve(assumptions=[-1])
    assert st_ == SAT and not m[0] and m[1]
    assert s.solve(assumptions=[-1, -2])[0] == UNSAT
    # UNSAT was under assumptions only: the solver stays reusable
    assert s.ok
    assert s.solve()[0] == SAT
    assert s.solve(assumptions=[1, 2])[0] == SAT


def test_cdcl_global_unsat_latches():
    cnf = CNF()
    cnf.n_vars = 1
    cnf.add(1)
    cnf.add(-1)
    s = CDCLSolver(cnf)
    assert s.solve()[0] == UNSAT
    assert not s.ok
    assert s.solve(assumptions=[1])[0] == UNSAT


def test_cdcl_add_clauses_between_solves():
    s = CDCLSolver()
    s.add_clauses([(1, 2)], n_vars=2)
    assert s.solve(assumptions=[-1])[0] == SAT
    s.add_clauses([(-2,)])
    st_, m = s.solve()
    assert st_ == SAT and m[0] and not m[1]
    assert s.solve(assumptions=[-1])[0] == UNSAT


def test_cdcl_retains_learned_clauses_across_assumption_solves():
    g = suite.get("gsm")
    sess = SolverSession(EncoderSession(g, CGRA(3, 3)), method="cdcl")
    seen = []
    for ii in range(2, 7):
        status, _, stats = sess.solve_complete(ii)
        seen.append((ii, status, stats.learned_retained, stats.conflicts))
    # the final SAT II starts with everything the UNSAT proofs derived
    assert seen[-1][1] == SAT
    retained = [r for (_, _, r, _) in seen]
    assert retained[0] == 0 and retained[-1] > 0
    assert retained == sorted(retained)   # never drops a learned clause


# ---------------------------------------------- projection == cold encode
@pytest.mark.parametrize("ii", [2, 3, 4, 5])
def test_projection_equals_cold_encoding_pairwise(ii):
    """With the pairwise AMO the per-II projection of the layered formula
    is *clause-for-clause identical* to the cold encoder's CNF (selector
    variables occur in no projected clause)."""
    g = running_example()
    ses = EncoderSession(g, CGRA(2, 2))
    inc = IncrementalEncoding(ses)
    a = sorted(tuple(sorted(c)) for c in inc.project(ii).clauses)
    b = sorted(tuple(sorted(c)) for c in ses.encode(ii).cnf.clauses)
    assert a == b


@pytest.mark.parametrize("amo", ["pairwise", "sequential"])
def test_assumption_statuses_match_cold_statuses(amo):
    g = running_example()
    sess = SolverSession(EncoderSession(g, CGRA(2, 2), amo), method="cdcl")
    for ii in (2, 3, 4, 5):
        st_inc, model, _ = sess.solve_complete(ii)
        st_cold, _ = solve(encode(g, CGRA(2, 2), ii, amo).cnf, "cdcl")
        assert st_inc == st_cold
        if st_inc == SAT:
            placement = sess.enc.decode(ii, model)
            assert len(placement) == g.n


# -------------------------------------- incremental == cold, per backend
def _statuses(res):
    return [(a.ii, a.status) for a in res.attempts]


@pytest.mark.parametrize("solver", ["cdcl", "auto", "z3", "portfolio",
                                    "walksat"])
def test_incremental_equals_cold_per_backend(solver):
    """Same final II, identical IIAttempt statuses, and a valid mapping —
    for every backend, incremental (default) vs cold (reference)."""
    if solver == "z3":
        pytest.importorskip("z3")
    cfg_inc = MapperConfig(solver=solver, timeout_s=90)
    cfg_cold = MapperConfig(solver=solver, timeout_s=90, incremental=False)
    for make in (running_example, lambda: suite.get("srand")):
        g = make()
        cgra = CGRA(2, 2) if g.name == "running_example" else CGRA(3, 3)
        ri = map_loop(make(), cgra, cfg_inc)
        rc = map_loop(make(), cgra, cfg_cold)
        assert ri.success and rc.success
        assert ri.ii == rc.ii
        assert _statuses(ri) == _statuses(rc)
        chk = verify_mapping(g, cgra, ri.placement, ri.ii, n_iters=6)
        assert chk.ok, chk.errors


@pytest.mark.parametrize("name", ["sha", "gsm", "nw"])
def test_incremental_equals_cold_on_suite_kernels(name):
    g = suite.get(name)
    cgra = CGRA(3, 3)
    ri = map_loop(g, cgra, MapperConfig(solver="auto", timeout_s=90))
    rc = map_loop(suite.get(name), cgra,
                  MapperConfig(solver="auto", timeout_s=90,
                               incremental=False))
    assert ri.ii == rc.ii and ri.success == rc.success
    assert _statuses(ri) == _statuses(rc)


def test_sweep_incremental_equals_sweep_cold():
    for name in ["gsm", "bitcount"]:
        cgra = CGRA(3, 3)
        ri = map_loop(suite.get(name), cgra,
                      MapperConfig(solver="auto", timeout_s=90),
                      sweep_width=3)
        rc = map_loop(suite.get(name), cgra,
                      MapperConfig(solver="auto", timeout_s=90,
                                   incremental=False), sweep_width=3)
        assert ri.ii == rc.ii
        assert ri.success and rc.success


def test_solve_window_with_session_matches_cold_statuses():
    g = running_example()
    enc_session = EncoderSession(g, CGRA(2, 2))
    sess = SolverSession(enc_session, method="cdcl")
    iis = [2, 3, 4]
    for ii in iis:
        sess.ensure_ii(ii)
    cnfs = [sess.project(ii) for ii in iis]
    res = solve_window(cnfs, method="cdcl", seed=0, session=sess, iis=iis)
    assert [r.status for r in res] == [UNSAT, SAT, SAT]
    for ii, r in zip(iis, res):
        if r.status == SAT:
            placement = sess.enc.decode(ii, r.model)
            assert len(placement) == g.n


# ----------------------------------------------------- reuse statistics
def test_iiattempt_surfaces_reuse_stats():
    r = map_loop(suite.get("nw"), CGRA(3, 3),
                 MapperConfig(solver="cdcl", timeout_s=90))
    assert r.success and len(r.attempts) >= 2
    for a in r.attempts:
        assert a.via == "cdcl"
        assert isinstance(a.learned_retained, int)
        assert isinstance(a.conflicts, int)
    # retention is cumulative across the II bumps
    assert r.attempts[-1].learned_retained >= r.attempts[0].learned_retained


def test_walksat_warm_start_reports_hamming():
    sess = SolverSession(EncoderSession(running_example(), CGRA(2, 2)),
                         method="walksat", walksat_steps=2000,
                         walksat_batch=16)
    st3, _, s3 = sess.solve_ii(3)
    st4, _, s4 = sess.solve_ii(4)
    assert st3 == SAT and st4 == SAT
    assert s3.warm_hamming is None          # nothing to warm-start from
    assert isinstance(s4.warm_hamming, int)  # seeded by II=3's model


# ------------------------------------------------- AMO encoding property
OPS = ["add", "sub", "mul", "xor", "and", "or"]


@st.composite
def small_dfg(draw):
    n = draw(st.integers(4, 9))
    g = DFG("rand")
    g.add("iv")
    g.add("const", imm=draw(st.integers(1, 50)))
    for i in range(2, n):
        op = draw(st.sampled_from(OPS))
        a = draw(st.integers(0, i - 1))
        b = draw(st.integers(0, i - 1))
        g.add(op, [(a, 0), (b, 0)])
    g.validate()
    return g


@settings(max_examples=10, deadline=None)
@given(small_dfg(), st.integers(1, 4))
def test_amo_encodings_agree_on_random_dfgs(g, ii):
    """Property: pairwise and Sinz-sequential AMO are equisatisfiable on
    the KMS encodings — identical SAT/UNSAT outcome at every II."""
    cgra = CGRA(2, 2)
    ra = solve(encode(g, cgra, ii, "pairwise").cnf, "cdcl")[0]
    rb = solve(encode(g, cgra, ii, "sequential").cnf, "cdcl")[0]
    assert ra == rb


@pytest.mark.parametrize("name", suite.names())
def test_amo_encodings_same_final_ii_on_suite(name):
    """Both AMO encodings drive the mapper to the identical final II on
    every suite kernel (incremental core active in both runs)."""
    cgra = CGRA(3, 3)
    rp = map_loop(suite.get(name), cgra,
                  MapperConfig(solver="auto", amo="pairwise", timeout_s=90))
    rs = map_loop(suite.get(name), cgra,
                  MapperConfig(solver="auto", amo="sequential",
                               timeout_s=90))
    assert rp.success == rs.success
    assert rp.ii == rs.ii

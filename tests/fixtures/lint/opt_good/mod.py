"""GOOD: runtime guard as a real raise — survives python -O."""


def take(queue):
    if queue is None:
        raise RuntimeError("queue not started")
    return queue.pop()

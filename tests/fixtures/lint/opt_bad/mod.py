"""BAD: runtime guard as a bare assert — vanishes under python -O."""


def take(queue):
    assert queue is not None, "queue not started"
    return queue.pop()

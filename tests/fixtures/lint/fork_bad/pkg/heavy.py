"""BAD: module-scope jax import, two hops from the fork entrypoint."""
import jax  # noqa: F401

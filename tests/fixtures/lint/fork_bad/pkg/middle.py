from . import heavy  # noqa: F401

"""Fork entrypoint: its module-scope import closure reaches jax."""
from .middle import something  # noqa: F401

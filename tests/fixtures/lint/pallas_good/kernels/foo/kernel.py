"""GOOD kernel file: static shapes, f32 throughout, 3-arg where."""
import jax.numpy as jnp


def body(x):
    mask = x > 0
    acc = jnp.where(mask, x, 0.0).astype(jnp.float32)
    return jnp.sum(acc)

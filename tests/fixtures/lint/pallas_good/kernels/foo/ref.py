"""Host-side reference: dynamic numpy ops are allowed in ref.py."""
import numpy as np


def body_ref(x):
    idx = np.nonzero(x)[0]
    return x[idx].sum()

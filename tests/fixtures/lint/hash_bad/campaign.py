"""BAD: canonical keys built from salted hash() / set iteration order."""


def canonical_key(dfg):
    return hash(tuple(dfg.edges))


def dfg_signature(dfg):
    parts = [str(n) for n in {0, 1, 2}]
    for e in set(dfg.edges):
        parts.append(str(e))
    return "|".join(parts)

"""Fork entrypoint with a jax-free module-scope closure."""
from .lazy import run_on_device  # noqa: F401

"""GOOD: the jax import lives inside a function — post-fork by construction."""


def run_on_device(x):
    import jax.numpy as jnp
    return jnp.asarray(x)

"""GOOD: canonical keys via sorted iteration and a keyed digest."""
import hashlib


def canonical_key(dfg):
    return hashlib.sha256(repr(sorted(dfg.edges)).encode()).hexdigest()


def dfg_signature(dfg):
    parts = [str(n) for n in sorted({0, 1, 2})]
    for e in sorted(set(dfg.edges)):
        parts.append(str(e))
    return "|".join(parts)

"""BAD kernel file: data-dependent shapes and float64."""
import jax.numpy as jnp


def body(x):
    idx = jnp.nonzero(x)
    pos = jnp.where(x > 0)
    acc = x.astype(jnp.float64)
    return idx, pos, acc

"""Sharding plans and spec helpers."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import given, settings, strategies as st

from repro.models.sharding import AttnPlan, pad_to, plan_attention

ASSIGNED = [(40, 8), (16, 16), (56, 8), (40, 40), (64, 8), (32, 8),
            (25, 5), (32, 32), (64, 8)]


@pytest.mark.parametrize("h,kv", ASSIGNED)
@pytest.mark.parametrize("tp", [1, 2, 4, 8, 16])
def test_assigned_archs_have_valid_plans(h, kv, tp):
    p = plan_attention(h, kv, tp)
    assert p.h_pad % tp == 0
    assert p.kv_virtual % tp == 0 or tp == 1
    assert p.h_pad == p.kv_virtual * p.group
    assert p.h_pad >= h
    assert p.pad_overhead <= 2.0


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.sampled_from([1, 2, 4, 8, 16]))
def test_plan_attention_properties(kv, gs, tp):
    """Property: for any (kv, group size, tp), the plan keeps each shard's
    q heads within a single kv head's group or whole groups per shard."""
    h = kv * gs
    p = plan_attention(h, kv, tp)
    hps = p.h_pad // tp
    gs_p = p.h_pad // (p.kv_virtual // p.repl)
    assert hps % gs_p == 0 or gs_p % hps == 0
    # original pairing embeds: slot (i//gs)*gs_p + i%gs stays in group i//gs
    for i in range(h):
        slot = (i // gs) * gs_p + (i % gs)
        assert slot < p.h_pad
        assert slot // gs_p == i // gs


def test_zero1_spec_picks_divisible_axis():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.optim.adamw import zero1_spec
    mesh = make_host_mesh()       # dp=1 -> unchanged
    sp = zero1_spec(P(None, "model"), (64, 32), mesh)
    assert sp == P(None, "model")


def test_spec_batch_fallback():
    from repro.launch.mesh import make_host_mesh
    from repro.models.sharding import spec
    mesh = make_host_mesh()
    s = spec(mesh, "batch", None, batch_size=1)
    # dp=1 divides everything; just ensure it returns a PartitionSpec
    assert len(s) == 2

"""End-to-end mapping: Fig. 3 loop, register allocation, simulator checks,
the SAT-vs-heuristic comparison (the paper's headline), and routing."""
import pytest

from repro.core import suite
from repro.core.baseline import BaselineConfig, map_heuristic
from repro.core.cgra import CGRA
from repro.core.dfg import running_example
from repro.core.mapper import MapperConfig, map_loop
from repro.core.regalloc import allocate
from repro.core.simulator import (emit_code, execute_mapping, static_check,
                                  verify_mapping)

# "auto" = z3 (the paper's solver) when importable, else the in-repo CDCL —
# the tests must run (and stay green) on hosts without z3 installed
FAST = MapperConfig(solver="auto", timeout_s=90)


def test_running_example_maps_at_ii3_on_2x2():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), FAST)
    assert r.success and r.ii == 3 == r.mii    # paper Fig. 2c


def test_mapping_validated_by_simulator():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), FAST)
    chk = verify_mapping(g, CGRA(2, 2), r.placement, r.ii, n_iters=8)
    assert chk.ok, chk.errors


def test_simulator_catches_bad_placement():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), FAST)
    bad = dict(r.placement)
    # move one node to a different cycle — some invariant must break
    n0 = next(iter(bad))
    p, c, it = bad[n0]
    bad[n0] = (p, (c + 1) % r.ii, it)
    chk = verify_mapping(g, CGRA(2, 2), bad, r.ii)
    assert not chk.ok


def test_regalloc_within_limit():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), FAST)
    ra = allocate(g, CGRA(2, 2), r.placement, r.ii)
    assert ra.ok
    assert ra.max_pressure <= 4


def test_regalloc_fails_with_zero_registers():
    g = running_example()
    cgra = CGRA(2, 2, n_regs=0)
    r = map_loop(g, cgra, FAST)
    # with zero local registers either a bypass-only mapping exists at a
    # larger II, or the mapper keeps iterating — II must grow past MII
    if r.success:
        assert r.ii >= r.mii


@pytest.mark.parametrize("name", ["srand", "bitcount", "gsm"])
def test_suite_kernels_map_on_3x3(name):
    g = suite.get(name)
    r = map_loop(g, CGRA(3, 3), FAST)
    assert r.success
    assert r.ii >= r.mii


def test_sat_not_worse_than_heuristic():
    """The paper's headline: SAT explores the space at least as well."""
    cgra = CGRA(4, 4)
    for name in ["sha", "srand", "nw"]:
        g = suite.get(name)
        rs = map_loop(g, cgra, FAST)
        rh = map_heuristic(g, cgra, BaselineConfig(n_restarts=10,
                                                   timeout_s=60))
        assert rs.success
        if rh.success:
            assert rs.ii <= rh.ii


def test_routing_insertion_can_reduce_ii():
    """Beyond-paper: splicing route nodes lifts the paper's limitation."""
    g = suite.get("gsm")
    cgra = CGRA(4, 4)
    base = map_loop(g, cgra, FAST)
    routed = map_loop(g, cgra, MapperConfig(
        solver="auto", routing=True, max_route_nodes=4, timeout_s=120))
    assert routed.success
    assert routed.ii <= base.ii


def test_emit_code_covers_all_nodes():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), FAST)
    code = emit_code(g, CGRA(2, 2), r.placement, r.ii)
    placed = [n for row in code.kernel for n in row if n is not None]
    assert sorted(placed) == sorted(g.nodes)
    assert code.n_stages == 2


def test_attempt_log_records_iterative_ii():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), FAST)
    assert [a.ii for a in r.attempts] == [3]
    assert r.attempts[-1].status == "SAT"

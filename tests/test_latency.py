"""Multi-cycle op latencies (PR 5): the per-op-class timing model.

Covers, per the acceptance criteria:
  * unit-latency parity — with every latency 1 the CNF is *bit-identical*
    (same clause lists, same variable numbering, cold and incremental) to
    the default fabric's on every suite kernel x {2x2, 3x3, 4x4} (33
    cells), and signatures/IIs are unchanged;
  * hand-computed ASAP/ALAP/RecMII with a multi-cycle mul inside a
    loop-carried cycle, plus the parallel-edge and enumeration-cap
    fixes in rec_mii;
  * the mapper's II respects the latency-aware RecMII and the simulator
    validates (and its static check *rejects* a mapping violating a
    2-cycle latency);
  * register-allocation lifetimes lengthen with producer latency;
  * res_mii's structured infeasibility (zero capable PEs) instead of a
    doomed sweep, surfaced as a clean compile() error;
  * the fabric grammar / signature / service-keying extensions.
"""
import pytest

from repro.core import suite
from repro.core.api import MapRequest, compile as compile_request
from repro.core.arch import ArchSpec, arch
from repro.core.cgra import CGRA, cgra_from_name
from repro.core.dfg import DFG, running_example
from repro.core.encode import EncoderSession
from repro.core.mapper import MapperConfig, map_loop
from repro.core.regalloc import allocate
from repro.core.sat.portfolio import SolverSession
from repro.core.schedule import (Infeasible, asap_alap, min_ii,
                                 node_latencies, rec_mii, res_mii)
from repro.core.service import MappingService, shape_signature
from repro.core.simulator import static_check, verify_mapping

_PARITY_SIZES = ["2x2", "3x3", "4x4"]
_UNIT_LAT = {"alu": 1, "mem": 1, "mul": 1}


def _loop_carried_mul() -> DFG:
    """iv -> add -> mul, with mul feeding add back at distance 1."""
    g = DFG("lcmul")
    iv = g.add("iv")
    acc = g.add("add", [(iv, 0), (iv, 0)], name="acc")
    m = g.add("mul", [(acc, 0), (acc, 0)], name="m")
    g.nodes[acc].ins = ((iv, 0), (m, 1))
    g.validate()
    return g


# ------------------------------------------------------ unit-latency parity
@pytest.mark.parametrize("name", suite.names())
def test_unit_latency_cnf_bit_identical_across_suite(name):
    """An explicit all-unit latency table must be a no-op: identical
    clause *lists* (not just multisets) and variable counts on every
    suite kernel x {2x2, 3x3, 4x4}, for both the cold per-II encoder and
    the incremental layered projection — so every pre-latency cache,
    session, and proven-UNSAT registry stays valid."""
    for size in _PARITY_SIZES:
        g = suite.get(name)
        plain, explicit = arch(size), arch(size, lat=dict(_UNIT_LAT))
        assert plain == explicit                     # normalises to None
        assert plain.signature() == explicit.signature()
        ii = min_ii(g, plain)
        assert ii == min_ii(g, explicit)
        a = EncoderSession(g, plain).encode(ii)
        b = EncoderSession(g, explicit).encode(ii)
        assert a.cnf.n_vars == b.cnf.n_vars
        assert a.cnf.clauses == b.cnf.clauses        # bit-identical, ordered
        assert a.stats == b.stats
        inc_a = SolverSession(EncoderSession(g, plain)).project(ii)
        inc_b = SolverSession(EncoderSession(g, explicit)).project(ii)
        assert inc_a.clauses == inc_b.clauses
        assert inc_a.n_vars == inc_b.n_vars


def test_unit_latency_identical_ii_cold_and_incremental():
    for name in ("sha", "nw", "bitcount"):
        for incremental in (True, False):
            cfg = MapperConfig(solver="auto", timeout_s=60,
                               incremental=incremental)
            r_plain = map_loop(suite.get(name), arch("3x3"), cfg)
            r_unit = map_loop(suite.get(name),
                              arch("3x3", lat=dict(_UNIT_LAT)), cfg)
            assert r_plain.success and r_unit.success
            assert r_plain.ii == r_unit.ii
            assert r_plain.mii == r_unit.mii


# ------------------------------------------------- hand-computed schedules
def test_asap_alap_with_two_cycle_mul_hand_computed():
    # chain iv -> mul -> add with a 2-cycle mul: add cannot issue before
    # t=3 and the schedule runs through the add's completion at t=4
    g = DFG("chain")
    iv = g.add("iv")
    m = g.add("mul", [(iv, 0), (iv, 0)])
    a = g.add("add", [(m, 0), (m, 0)])
    lat = node_latencies(g, arch("2x2:mul2"))
    assert lat == {iv: 1, m: 2, a: 1}
    asap, alap, L = asap_alap(g, lat)
    assert (asap[iv], asap[m], asap[a]) == (0, 1, 3)
    assert L == 4
    assert (alap[iv], alap[m], alap[a]) == (0, 1, 3)
    # unit latencies reproduce the old table exactly
    assert asap_alap(g) == asap_alap(g, {n: 1 for n in g.nodes})


def test_rec_mii_with_multicycle_mul_in_loop_carried_cycle():
    g = _loop_carried_mul()
    # cycle acc -> m -> acc at distance 1: unit latency sum 2
    assert rec_mii(g) == 2
    # 3-cycle mul: latency sum 1 + 3 = 4 over distance 1
    lat3 = node_latencies(g, arch("3x3:mul3"))
    assert rec_mii(g, lat3) == 4
    assert min_ii(g, arch("3x3:mul3")) == 4
    # paper running example: distance-1 cycle n10 -> n11 (both adds), so
    # mul latency does not touch it but alu latency does
    e = running_example()
    assert rec_mii(e, node_latencies(e, arch("4x4:mul4"))) == 2
    assert rec_mii(e, node_latencies(e, arch("4x4:alu2"))) == 4


def test_rec_mii_parallel_edges_each_contribute():
    # two edges between the same pair with different distances: the
    # distance-1 edge's cycle bound must survive the distance-3 edge
    g = DFG("par")
    iv = g.add("iv")
    a = g.add("add", [(iv, 0), (iv, 0)])
    b = g.add("add", [(a, 0), (a, 0)])
    g.nodes[a].ins = ((b, 3), (b, 1))
    g.validate()
    lat = {iv: 1, a: 2, b: 2}
    # a -> b (dist 0), b -> a closes at distance 1 (and 3): max bound is
    # ceil((2+2)/(0+1)) = 4, the distance-3 parallel edge gives only 2
    assert rec_mii(g, lat) == 4
    assert rec_mii(g) == 2
    # order independence: swapping the parallel-edge order changes nothing
    g.nodes[a].ins = ((b, 1), (b, 3))
    assert rec_mii(g, lat) == 4


def test_rec_mii_cycle_cap_falls_back_to_exact_bound():
    # a dense all-to-all accumulator graph has combinatorially many simple
    # cycles; with the enumeration capped at 1 the Bellman-Ford fallback
    # must still return the exact RecMII
    g = DFG("dense")
    n = 7
    ids = [g.add("iv")]
    for i in range(1, n):        # phi nodes admit any input arity
        ids.append(g.add("phi", [(ids[i - 1], 0)]))
    for i in range(1, n):        # back-edges from everything to everything
        for j in range(i, n):
            g.nodes[ids[i]].ins = g.nodes[ids[i]].ins + ((ids[j], 1),)
    g.validate()
    exact = rec_mii(g)                       # full enumeration
    capped = rec_mii(g, max_cycles=1)        # forced fallback
    assert capped == exact
    lat = {nid: 2 for nid in g.nodes}
    assert rec_mii(g, lat, max_cycles=1) == rec_mii(g, lat)


# ------------------------------------------------ mapper + simulator + CNF
def test_mapper_respects_latency_aware_recmii_and_simulator_validates():
    """Acceptance: a DFG with a 2-cycle op in a loop-carried cycle maps at
    an II >= the latency-aware RecMII and the produced mapping passes the
    latency-aware simulator (verify_mapping also runs inside map_loop)."""
    g = _loop_carried_mul()
    fabric = cgra_from_name("3x3:mul2")
    lat = node_latencies(g, fabric)
    assert rec_mii(g, lat) == 3
    r = map_loop(g, fabric, MapperConfig(solver="auto", timeout_s=60))
    assert r.success and r.ii >= 3 > rec_mii(g)
    chk = verify_mapping(g, fabric, r.placement, r.ii, n_iters=7)
    assert chk.ok, chk.errors
    # sweep engine agrees with the sequential reference
    rs = map_loop(_loop_carried_mul(), fabric,
                  MapperConfig(solver="auto", timeout_s=60), sweep_width=3)
    assert rs.success and rs.ii == r.ii


def test_static_check_rejects_two_cycle_latency_violation():
    # iv -> mul -> add chain on a 2-cycle-mul fabric: a placement where
    # the add issues only 1 cycle after the mul is illegal (span < lat)
    g = DFG("viol")
    iv = g.add("iv")
    m = g.add("mul", [(iv, 0), (iv, 0)])
    a = g.add("add", [(m, 0), (m, 0)])
    unit, mul2 = CGRA(2, 2), cgra_from_name("2x2:mul2")
    placement = {iv: (0, 0, 0), m: (0, 1, 0), a: (1, 2, 0)}
    assert static_check(g, unit, placement, 4).ok
    chk = static_check(g, mul2, placement, 4)
    assert not chk.ok
    assert any("lat 2" in e and "outside" in e for e in chk.errors)
    # pushing the consumer one cycle out satisfies the 2-cycle latency
    ok = dict(placement)
    ok[a] = (1, 3, 0)
    assert static_check(g, mul2, ok, 4).ok


def test_c3_window_shifts_by_producer_latency():
    from repro.core.sat import SAT, UNSAT, solve
    g = _loop_carried_mul()
    # the 2-cycle mul stretches the add's ASAP (result exists 2 cycles
    # after the mul issues) ...
    enc_u = EncoderSession(g, arch("3x3")).encode(2)
    enc_l = EncoderSession(g, arch("3x3:mul2")).encode(2)
    assert enc_l.kms.length > enc_u.kms.length
    # ... and II=2 — feasible under unit latencies — becomes UNSAT: the
    # acc -> mul -> acc recurrence now needs 3 cycles per iteration
    assert solve(enc_u.cnf, "auto")[0] == SAT
    assert solve(enc_l.cnf, "auto")[0] == UNSAT
    assert solve(EncoderSession(g, arch("3x3:mul2")).encode(3).cnf,
                 "auto")[0] == SAT


# --------------------------------------------------- regalloc under latency
def test_regalloc_lifetimes_track_completion_time():
    # mul m issues at kernel cycle 0, a const on the same PE writes the
    # output register at cycle 2, and m's consumer reads 3 cycles after
    # m's issue. Unit latency: m's value (written at 1) must survive the
    # const's write at 2 -> local register. 3-cycle mul: the write lands
    # at 3 and the read happens that same cycle -> pure bypass. Both
    # placements are write-clash free on both fabrics (completions 1,2 /
    # 3,2 on PE0) and pass the latency-aware static check.
    g = DFG("life")
    iv = g.add("iv")
    m = g.add("mul", [(iv, 0), (iv, 0)])
    x = g.add("const", imm=1)
    a = g.add("add", [(m, 0), (m, 0)])
    ii = 4
    placement = {iv: (1, 2, 0), m: (0, 0, 1), x: (0, 1, 1), a: (1, 3, 1)}
    unit = arch("2x2", regs=1)
    mul3 = arch("2x2:mul3", regs=1)
    assert static_check(g, unit, placement, ii).ok
    assert static_check(g, mul3, placement, ii).ok
    ra_u = allocate(g, unit, placement, ii)
    ra_l = allocate(g, mul3, placement, ii)
    assert ra_u.ok and ra_l.ok
    assert m in ra_u.regs and m not in ra_u.bypass
    assert m in ra_l.bypass and m not in ra_l.regs
    # zero registers on PE0: only the bypassing multi-cycle fabric fits
    assert not allocate(g, arch("2x2", regs=[0, 4, 4, 4]),
                        placement, ii).ok
    assert allocate(g, arch("2x2:mul3", regs=[0, 4, 4, 4]),
                    placement, ii).ok
    # end-to-end: a mapped multi-cycle kernel passes regalloc + simulator
    r = map_loop(suite.get("gsm"), cgra_from_name("3x3:mul2:mem2"),
                 MapperConfig(solver="auto", timeout_s=90))
    assert r.success and r.regalloc.ok


def test_output_register_write_clash_rejected_and_never_encoded():
    """Two mixed-latency nodes on one PE completing in the same kernel
    cycle double-write the single output register: static_check must
    reject it, and the encoder's write-port clauses must make such
    placements unsatisfiable (C2 alone cannot — the *issue* slots
    differ)."""
    from repro.core.sat import SAT, solve
    g = DFG("clash")
    iv = g.add("iv")
    m = g.add("mul", [(iv, 0), (iv, 0)])     # lat 2 on the mul2 fabric
    b = g.add("add", [(iv, 0), (iv, 0)])     # lat 1
    d = g.add("add", [(m, 0), (b, 0)])
    mul2 = cgra_from_name("2x2:mul2")
    ii = 4
    # on PE0, m issues at 1 (completes 1+2=3), b at 2 (completes 2+1=3):
    # an output-register write clash on the 2-cycle-mul fabric only
    bad = {iv: (1, 0, 0), m: (0, 1, 0), b: (0, 2, 0), d: (1, 3, 0)}
    chk = static_check(g, mul2, bad, ii)
    assert not chk.ok
    assert any("write clash" in e for e in chk.errors)
    assert static_check(g, CGRA(2, 2), bad, ii).ok   # unit: legal
    # every SAT model of the latency-aware encoding decodes to a
    # placement the latency-aware static check accepts
    enc = EncoderSession(g, mul2).encode(ii)
    status, model = solve(enc.cnf, "auto")
    assert status == SAT
    placement = enc.decode(model)
    assert static_check(g, mul2, placement, ii).ok
    # and the bad placement's literals are jointly forbidden by the CNF
    vm = enc.var_of[(m, 0, 1, 0)]
    vb = enc.var_of[(b, 0, 2, 0)]
    assert tuple(sorted((-vm, -vb))) in {tuple(sorted(c))
                                         for c in enc.cnf.clauses}
    # unit-latency fabrics emit zero write-port clauses (bit parity)
    sess = EncoderSession(g, CGRA(2, 2))
    assert not list(sess.c2w_clauses(ii))


# ----------------------------------------------- structured infeasibility
def test_res_mii_zero_supporters_is_structured_infeasibility():
    g = suite.get("sha")                     # contains loads/stores
    spec = arch("3x3", mem="none")
    with pytest.raises(Infeasible) as ei:
        res_mii(g, spec)
    assert ei.value.op_class == "mem" and ei.value.n_ops >= 1
    with pytest.raises(Infeasible):
        min_ii(g, spec)
    # engines return a structured verdict instead of a doomed sweep
    r = map_loop(g, spec, MapperConfig(solver="auto", timeout_s=10))
    assert not r.success and r.infeasible and not r.attempts
    assert "mem" in r.infeasible
    rs = map_loop(suite.get("sha"), spec,
                  MapperConfig(solver="auto", timeout_s=10), sweep_width=3)
    assert not rs.success and rs.infeasible
    # ... and compile() surfaces it as a clean front-door error
    with pytest.raises(Infeasible, match="mem"):
        compile_request(MapRequest(dfg=suite.get("sha"), arch=spec,
                                   timeout_s=10))
    # feasible classes still get finite bounds
    assert res_mii(running_example(), spec) >= 1


# ------------------------------------------------ grammar / keying / API
def test_latency_grammar_and_signature():
    a = arch("4x4-torus:r8:mul2:mem2")
    assert a.interconnect == "torus" and a.pe_regs[0] == 8
    assert a.lat("mul") == 2 and a.lat("mem") == 2 and a.lat("alu") == 1
    assert a.lat_of("div") == 2 and a.lat_of("add") == 1
    assert not a.unit_latency
    # explicit lat= wins over the name suffix
    assert arch("4x4:mul2", lat={"mul": 3}).lat("mul") == 3
    # unit table normalises away: signature and equality unchanged
    assert arch("4x4").signature() == arch("4x4:mul1").signature()
    assert arch("4x4").unit_latency and arch("4x4:mul1").unit_latency
    # non-unit latencies key differently (service pools must not mix)
    assert arch("4x4").signature() != arch("4x4:mul2").signature()
    assert cgra_from_name("4x4:mul2").signature() == \
        arch("4x4:mul2").signature()
    with pytest.raises(ValueError):
        ArchSpec(2, 2, op_lat=(("mul", 0),))
    with pytest.raises(ValueError):
        ArchSpec(2, 2, op_lat=(("fpu", 2),))


def test_shape_signature_distinguishes_latency_classes():
    def build(op):
        g = DFG("shape")
        x = g.add("iv")
        g.add(op, [(x, 0), (x, 0)])
        return g
    g_add, g_mul = build("add"), build("mul")
    hom = arch("3x3")
    lat = arch("3x3:mul2")
    # homogeneous unit fabric: add/mul still share a shape class
    assert shape_signature(g_add, hom) == shape_signature(g_mul, hom)
    # 2-cycle muls: identical allowed-PE sets but different C3 windows
    assert shape_signature(g_add, lat) != shape_signature(g_mul, lat)


def test_service_pools_latency_fabrics_separately():
    svc = MappingService()
    g = _loop_carried_mul()
    r_unit = svc.map(g, arch("3x3"), MapperConfig(solver="auto",
                                                  timeout_s=60))
    r_lat = svc.map(_loop_carried_mul(), arch("3x3:mul3"),
                    MapperConfig(solver="auto", timeout_s=60))
    assert r_unit.success and r_lat.success
    assert r_lat.ii >= 4 > r_unit.ii
    assert svc.n_sessions == 2               # no cross-latency session reuse
    warm = svc.map(_loop_carried_mul(), arch("3x3:mul3"),
                   MapperConfig(solver="auto", timeout_s=60),
                   use_cache=False)
    assert warm.service.session_reused and warm.ii == r_lat.ii


def test_compile_maprequest_lat_field():
    r = compile_request(MapRequest(dfg=_loop_carried_mul(), arch="3x3",
                                   lat={"mul": 3}, timeout_s=60))
    assert r.success and r.ii >= 4
    with pytest.raises(ValueError):
        MapRequest(dfg=_loop_carried_mul(), arch=arch("3x3"),
                   lat={"mul": 3}).resolved_arch()

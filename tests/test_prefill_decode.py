"""Prefill -> decode cache handoff: continuation must be identical to
token-by-token decode from scratch (KV ring buffer, SSM state, hybrid)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM


@pytest.mark.parametrize("arch", ["qwen1_5_32b", "mamba2_370m",
                                  "hymba_1_5b"])
def test_prefill_then_decode_matches_scratch(arch):
    cfg = get_config(arch).smoke().replace(dtype="float32")
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    B, S, EXTRA, W = 2, 10, 4, 16
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA),
                                  0, cfg.vocab)
        lg, cache = jax.jit(
            lambda p, t: lm.prefill_with_cache(p, t, window=W)
        )(params, toks[:, :S])
        dec = jax.jit(lm.decode_step)
        outs_a = []
        for t in range(S, S + EXTRA):
            lg, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
            outs_a.append(lg)
        cache_b = lm.init_cache(B, W)
        outs_b = []
        for t in range(S + EXTRA):
            lgb, cache_b = dec(params, cache_b, toks[:, t:t + 1],
                               jnp.int32(t))
            if t >= S:
                outs_b.append(lgb)
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_allclose(np.asarray(a[:, :, :cfg.vocab]),
                                   np.asarray(b[:, :, :cfg.vocab]),
                                   atol=2e-3, rtol=2e-3)

pytestmark = pytest.mark.slow


def test_prefill_cache_with_kv_quant():
    cfg = get_config("qwen1_5_32b").smoke().replace(dtype="float32",
                                                    kv_quant=True)
    mesh = make_host_mesh()
    lm = LM(cfg, mesh)
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        lg, cache = lm.prefill_with_cache(params, toks, window=12)
        assert cache["k"].dtype == jnp.int8
        lg2, cache = jax.jit(lm.decode_step)(
            params, cache, toks[:, -1:], jnp.int32(8))
        assert np.isfinite(np.asarray(lg2, np.float32)).all()

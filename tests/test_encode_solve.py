"""SAT encoding + solver backends."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import given, settings, strategies as st

from repro.core.cgra import CGRA
from repro.core.cnf import CNF
from repro.core.dfg import DFG, running_example
from repro.core.encode import EncoderSession, encode
from repro.core.sat import SAT, UNKNOWN, UNSAT, solve
from repro.core.sat.cdcl import CDCLSolver
from repro.core.schedule import min_ii


def test_running_example_sat_at_paper_ii():
    g = running_example()
    enc = encode(g, CGRA(2, 2), 3)
    st_, model = solve(enc.cnf, "auto")
    assert st_ == SAT
    placement = enc.decode(model)
    assert len(placement) == g.n


def test_running_example_unsat_below_mii():
    g = running_example()
    enc = encode(g, CGRA(2, 2), 2)
    assert solve(enc.cnf, "auto")[0] == UNSAT
    assert solve(enc.cnf, "cdcl")[0] == UNSAT


def test_clause_family_counts():
    g = running_example()
    enc = encode(g, CGRA(2, 2), 3)
    st_ = enc.stats
    assert st_["c1"] > 0 and st_["c2"] > 0 and st_["c3"] > 0
    assert st_["c1"] + st_["c2"] + st_["c3"] == st_["clauses"]


def test_amo_encodings_equisatisfiable():
    g = running_example()
    for ii in (2, 3):
        a = EncoderSession(g, CGRA(2, 2), "pairwise").encode(ii)
        b = EncoderSession(g, CGRA(2, 2), "sequential").encode(ii)
        ra = solve(a.cnf, "auto")[0]
        rb = solve(b.cnf, "auto")[0]
        assert ra == rb


@st.composite
def random_cnf(draw):
    n_vars = draw(st.integers(3, 12))
    n_clauses = draw(st.integers(1, 40))
    clauses = []
    for _ in range(n_clauses):
        k = draw(st.integers(1, 3))
        cl = []
        for _ in range(k):
            v = draw(st.integers(1, n_vars))
            cl.append(v if draw(st.booleans()) else -v)
        clauses.append(tuple(cl))
    cnf = CNF()
    cnf.n_vars = n_vars
    for cl in clauses:
        cnf.add_clause(cl)
    return cnf


@settings(max_examples=60, deadline=None)
@given(random_cnf())
def test_cdcl_agrees_with_z3(cnf):
    """Property: our CDCL and Z3 agree on SAT/UNSAT; SAT models check out."""
    pytest.importorskip("z3")
    rz, _ = solve(cnf, "z3")
    rc, model = solve(cnf, "cdcl")
    assert rz == rc
    if rc == SAT:
        assert cnf.check(model)


@settings(max_examples=20, deadline=None)
@given(random_cnf())
def test_walksat_models_are_models(cnf):
    st_, model = solve(cnf, "walksat", walksat_steps=512, walksat_batch=8)
    if st_ == SAT:
        assert cnf.check(model)


def test_cdcl_empty_clause_unsat():
    cnf = CNF()
    cnf.n_vars = 2
    cnf.add_clause([])
    assert CDCLSolver(cnf).solve()[0] == UNSAT


def test_portfolio_solves():
    g = running_example()
    enc = encode(g, CGRA(2, 2), 3)
    st_, model = solve(enc.cnf, "portfolio")
    assert st_ == SAT
    enc.decode(model)

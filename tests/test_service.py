"""Persistent mapping service: topology-keyed solver pool, canonical-DFG
mapping cache, UNSAT-core II pruning, budget-vs-UNSAT distinction, and the
bounded learnt-clause database."""
import copy

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import given, settings, strategies as st

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.cnf import CNF
from repro.core.dfg import DFG, running_example
from repro.core.encode import EncoderSession
from repro.core.mapper import MapperConfig, map_loop
from repro.core.sat import SAT, UNKNOWN, UNSAT
from repro.core.sat.cdcl import CDCLSolver
from repro.core.sat.portfolio import SolverSession
from repro.core.service import (MappingService, dfg_signature, get_service,
                                reset_service, shape_signature,
                                topology_signature)
from repro.core.simulator import verify_mapping

CFG = MapperConfig(solver="auto", timeout_s=90)


# ------------------------------------------------------- request signatures
def test_signatures_distinguish_topology_and_structure():
    g1, g2 = suite.get("sha"), suite.get("gsm")
    assert topology_signature(CGRA(3, 3)) != topology_signature(CGRA(4, 4))
    assert topology_signature(CGRA(3, 3)) != topology_signature(
        CGRA(3, 3, topology="torus"))
    assert shape_signature(g1) != shape_signature(g2)
    assert dfg_signature(g1) != dfg_signature(g2)
    # re-built copies of the same kernel are canonically identical
    assert dfg_signature(g1) == dfg_signature(suite.get("sha"))
    assert shape_signature(g1) == shape_signature(suite.get("sha"))


def test_shape_signature_ignores_ops_and_imms():
    """The SAT encoding never reads opcodes/immediates, so same-shape DFGs
    with different arithmetic share one pooled session; the full request
    signature still tells them apart (the verified result differs)."""
    def build(op, imm):
        g = DFG("shape")
        a = g.add("const", imm=imm)
        b = g.add("iv")
        g.add(op, [(a, 0), (b, 0)])
        return g
    g_add, g_mul = build("add", 3), build("mul", 7)
    assert shape_signature(g_add) == shape_signature(g_mul)
    assert dfg_signature(g_add) != dfg_signature(g_mul)


# ------------------------------------------------------------ mapping cache
def test_cache_hit_determinism():
    svc = MappingService()
    cgra = CGRA(3, 3)
    r1 = svc.map(suite.get("sha"), cgra, CFG)
    r2 = svc.map(suite.get("sha"), cgra, CFG)
    assert r1.success and r2.success
    assert r2.service.via == "cache" and r2.service.cache_hit
    assert r1.service.via == "cold" and not r1.service.cache_hit
    assert (r1.ii, r1.mii, r1.placement) == (r2.ii, r2.mii, r2.placement)
    assert [(a.ii, a.status) for a in r1.attempts] == \
        [(a.ii, a.status) for a in r2.attempts]
    assert svc.stats.cache_hits == 1 and svc.stats.requests == 2


def test_cache_keyed_on_config_and_topology():
    svc = MappingService()
    g = suite.get("gsm")
    svc.map(g, CGRA(3, 3), CFG)
    r_other_topo = svc.map(suite.get("gsm"), CGRA(4, 4), CFG)
    assert not r_other_topo.service.cache_hit
    r_other_cfg = svc.map(suite.get("gsm"), CGRA(3, 3),
                          MapperConfig(solver="auto", timeout_s=90,
                                       amo="sequential"))
    assert not r_other_cfg.service.cache_hit
    r_same = svc.map(suite.get("gsm"), CGRA(3, 3), CFG)
    assert r_same.service.cache_hit


# ----------------------------------------------------------- session pool
def test_topology_pool_reuse_across_suite_kernels():
    """Two suite kernels on one topology: each owns a pooled session; a
    second round of requests reuses both sessions warm (use_cache=False
    forces a real solve through the pool)."""
    svc = MappingService()
    cgra = CGRA(3, 3)
    first = {name: svc.map(suite.get(name), cgra, CFG)
             for name in ("sha", "gsm")}
    assert svc.n_sessions == 2
    assert all(not r.service.session_reused for r in first.values())
    second = {name: svc.map(suite.get(name), cgra, CFG, use_cache=False)
              for name in ("sha", "gsm")}
    assert svc.n_sessions == 2          # no new sessions created
    for name, r in second.items():
        assert r.service.session_reused and r.service.via == "warm"
        assert r.ii == first[name].ii
    assert svc.stats.sessions_reused == 2


def test_same_shape_requests_share_one_session():
    svc = MappingService()
    cgra = CGRA(2, 2)

    def build(op):
        g = DFG("shape")
        a = g.add("const", imm=5)
        b = g.add("iv")
        c = g.add(op, [(a, 0), (b, 0)])
        g.add("xor", [(c, 0), (b, 0)])
        return g
    r_add = svc.map(build("add"), cgra, CFG)
    r_sub = svc.map(build("sub"), cgra, CFG)
    assert not r_sub.service.cache_hit        # different request...
    assert r_sub.service.session_reused       # ...same pooled formula
    assert svc.n_sessions == 1
    assert r_add.ii == r_sub.ii
    for r, g in ((r_add, build("add")), (r_sub, build("sub"))):
        chk = verify_mapping(g, cgra, r.placement, r.ii, n_iters=6)
        assert chk.ok, chk.errors


def test_session_pool_is_lru_bounded():
    svc = MappingService(max_sessions=2)
    for size in ("2x2", "3x3", "4x4"):
        r, c = (int(x) for x in size.split("x"))
        svc.map(suite.get("gsm"), CGRA(r, c), CFG)
    assert svc.n_sessions == 2
    assert svc.stats.session_evictions == 1


# --------------------------------------------------- UNSAT-core II pruning
def test_warm_pass_prunes_proven_unsat_iis():
    """sha on 3x3 proves II=6 UNSAT before mapping at 7: the warm second
    pass must replay that refutation from the recorded core (via="core",
    zero solve time) and land on the same II."""
    svc = MappingService()
    cgra = CGRA(3, 3)
    r1 = svc.map(suite.get("sha"), cgra, CFG)
    assert r1.ii is not None and r1.ii > r1.mii   # at least one UNSAT II
    r2 = svc.map(suite.get("sha"), cgra, CFG, use_cache=False)
    assert r2.ii == r1.ii
    pruned = [a for a in r2.attempts if a.via == "core"]
    assert len(pruned) == r1.ii - r1.mii >= 1
    assert all(a.status == UNSAT and a.solve_time == 0.0 for a in pruned)
    assert r2.service.iis_pruned == len(pruned)
    assert svc.stats.iis_pruned >= 1


def test_proven_lower_bound_jumps_refuted_prefix():
    """After one sweep, the session can *prove* an II lower bound: every
    II below the found minimum is a recorded core, so the bound equals
    the minimum (and all_unsat collapses it immediately)."""
    sess = SolverSession(EncoderSession(suite.get("sha"), CGRA(3, 3)),
                         method="cdcl")
    r = map_loop(suite.get("sha"), CGRA(3, 3),
                 MapperConfig(solver="cdcl", timeout_s=90), session=sess)
    assert r.success and r.ii > r.mii
    assert sess.proven_lower_bound(r.mii) == r.ii
    assert sess.proven_lower_bound(r.ii) == r.ii   # SAT II is not refuted


def test_sweep_through_service_prunes_and_agrees():
    svc = MappingService()
    cgra = CGRA(3, 3)
    r1 = svc.map(suite.get("sha"), cgra, CFG)
    r2 = svc.map(suite.get("sha"), cgra, CFG, sweep_width=3,
                 use_cache=False)
    assert r2.ii == r1.ii
    assert any(a.via == "core" for a in r2.attempts)


@pytest.mark.parametrize("size", ["2x2", "3x3", "4x4"])
def test_service_ii_parity_across_suite(size):
    """For every suite kernel, the service's warm pass returns the same
    minimal II as a standalone map_loop — core pruning only ever replays
    proven refutations, it can never change the answer."""
    rows, cols = (int(x) for x in size.split("x"))
    cgra = CGRA(rows, cols)
    svc = MappingService()
    for name in suite.names():
        ref = map_loop(suite.get(name), cgra, CFG)
        svc.map(suite.get(name), cgra, CFG)                   # first pass
        warm = svc.map(suite.get(name), cgra, CFG, use_cache=False)
        assert warm.service.session_reused
        assert warm.ii == ref.ii and warm.success == ref.success, name
        if warm.success and warm.ii > warm.mii:
            # every UNSAT II of the first pass is now a recorded core
            assert warm.service.iis_pruned == warm.ii - warm.mii, name


def test_unmappable_dfg_latches_all_unsat():
    """A memory node with no memory-capable PE gives an empty C1 clause:
    the very first solve returns an *empty* failed-assumption core and
    the session latches all_unsat. The mapping engines never even get
    there any more — res_mii reports the zero-supporter class as a
    structured infeasibility, so map_loop returns the reason with *zero*
    solver attempts instead of a doomed sweep."""
    g = DFG("nomem")
    iv = g.add("iv")
    g.add("load", [(iv, 0)], imm=0)
    cgra = CGRA(2, 2, mem_pes=())
    sess = SolverSession(EncoderSession(g, cgra, "pairwise"),
                         method="cdcl")
    st_, _, stats = sess.solve_complete(2)
    assert st_ == UNSAT and stats.core == []
    assert sess.all_unsat and sess.is_proven_unsat(99)
    r = map_loop(g, cgra, MapperConfig(solver="cdcl", timeout_s=30),
                 session=sess)
    assert not r.success
    assert r.infeasible and "mem" in r.infeasible
    assert not r.attempts
    # the engines' all_unsat branch stays covered: a *feasible-looking*
    # DFG whose session carries an empty core is pruned in one attempt
    g2 = suite.get("bitcount")
    plain = CGRA(2, 2)
    sess2 = SolverSession(EncoderSession(g2, plain, "pairwise"),
                          method="cdcl")
    sess2.note_core(2, [])
    assert sess2.all_unsat
    r2 = map_loop(g2, plain, MapperConfig(solver="cdcl", timeout_s=30),
                  session=sess2)
    assert not r2.success and not r2.infeasible
    assert len(r2.attempts) == 1 and r2.attempts[0].via == "core"


# ------------------------------------------- budget-vs-UNSAT distinction
def _hard_unsat_cnf() -> CNF:
    """Pigeonhole PHP(7,6): UNSAT, needs thousands of conflicts."""
    P, H = 7, 6
    cnf = CNF()
    var = {(p, h): cnf.new_var() for p in range(P) for h in range(H)}
    for p in range(P):
        cnf.add_clause([var[p, h] for h in range(H)])
    for h in range(H):
        for p1 in range(P):
            for p2 in range(p1 + 1, P):
                cnf.add(-var[p1, h], -var[p2, h])
    return cnf


def test_budget_exhaustion_is_unknown_never_proven_unsat():
    cnf = _hard_unsat_cnf()
    s = CDCLSolver(cnf)
    status, _ = s.solve(max_conflicts=5, assumptions=[1])
    assert status == UNKNOWN
    assert s.last_core is None           # no refutation was produced
    assert s.last_limit == "conflicts"
    assert s.ok                          # solver still usable
    status2, _ = s.solve(assumptions=[1])   # full solve: the real verdict
    assert status2 == UNSAT
    assert s.last_core is not None and s.last_limit is None


def test_stop_is_unknown_never_proven_unsat():
    s = CDCLSolver(_hard_unsat_cnf())
    status, _ = s.solve(stop=lambda: True, assumptions=[1])
    assert status == UNKNOWN
    assert s.last_core is None and s.last_limit == "stop"


def test_session_never_records_core_on_budget_unknown():
    """Even if the sweep's complete leg gets cancelled mid-II, the session
    must not mark that II proven-UNSAT."""
    sess = SolverSession(EncoderSession(running_example(), CGRA(2, 2)),
                         method="cdcl")
    st_, _, stats = sess.solve_complete(2, stop=lambda: True)
    assert st_ == UNKNOWN and stats.core is None
    assert not sess.is_proven_unsat(2)
    st2, _, stats2 = sess.solve_complete(2)   # real solve still works
    assert st2 == UNSAT and stats2.core is not None
    assert sess.is_proven_unsat(2)


# ------------------------------------------------- failed-assumption cores
def test_core_is_subset_of_assumptions():
    cnf = CNF()
    cnf.n_vars = 4
    cnf.add(1, 2)
    cnf.add(-2, 3)
    s = CDCLSolver(cnf)
    status, _ = s.solve(assumptions=[4, -1, -3])
    assert status == UNSAT
    assert set(s.last_core) <= {4, -1, -3}
    assert 4 not in s.last_core          # x4 is irrelevant to the conflict
    # the core alone must already be UNSAT on a fresh solver
    s2 = CDCLSolver(cnf)
    assert s2.solve(assumptions=list(s.last_core))[0] == UNSAT


def test_core_on_globally_unsat_formula_is_empty():
    cnf = CNF()
    cnf.n_vars = 1
    cnf.add(1)
    cnf.add(-1)
    s = CDCLSolver(cnf)
    assert s.solve(assumptions=[1])[0] == UNSAT
    assert s.last_core == []


# --------------------------------------------- learnt-clause DB reduction
def test_reduce_db_bounds_retention_and_stays_correct():
    cnf = _hard_unsat_cnf()
    capped = CDCLSolver(cnf, max_learnt=60)
    assert capped.solve()[0] == UNSAT
    assert capped.evicted_total > 0
    assert capped.learnt_db_size <= 60
    # same verdict as the unbounded reference
    assert CDCLSolver(cnf).solve()[0] == UNSAT


@st.composite
def random_cnf(draw):
    n_vars = draw(st.integers(8, 40))
    n_clauses = draw(st.integers(2 * n_vars, 5 * n_vars))
    cnf = CNF()
    cnf.n_vars = n_vars
    for _ in range(n_clauses):
        k = draw(st.integers(2, 3))
        lits = []
        for _ in range(k):
            v = draw(st.integers(1, n_vars))
            lits.append(v if draw(st.booleans()) else -v)
        cnf.add_clause(lits)
    return cnf


@settings(max_examples=12, deadline=None)
@given(random_cnf(), st.integers(10, 80))
def test_reduce_db_property_matches_unbounded_solver(cnf, cap):
    """Property: eviction only drops redundant lemmas — the capped solver
    agrees with the unbounded one on every instance, any model it returns
    satisfies the formula, and retention respects the cap."""
    ref_status, _ = CDCLSolver(cnf).solve()
    s = CDCLSolver(cnf, max_learnt=cap)
    status, model = s.solve()
    assert status == ref_status
    if status == SAT:
        assert cnf.check(model)
    assert s.learnt_db_size <= cap


def test_session_cap_reaches_backend_and_attempts():
    cfg = MapperConfig(solver="cdcl", timeout_s=90, max_learnt=64)
    r = map_loop(suite.get("sha"), CGRA(3, 3), cfg)
    assert r.success
    # the cap reached the persistent CDCL: retention stayed bounded even
    # if this small kernel never actually overflows it
    sess_cap = 64
    assert all(a.learned_retained is None or a.learned_retained >= 0
               for a in r.attempts)
    s = CDCLSolver(_hard_unsat_cnf(), max_learnt=sess_cap)
    s.solve()
    assert s.learnt_db_size <= sess_cap


# ----------------------------------------------------- consumer plumbing
def test_map_loop_service_param_routes_through_service():
    svc = MappingService()
    cgra = CGRA(3, 3)
    r1 = map_loop(suite.get("nw"), cgra, CFG, service=svc)
    r2 = map_loop(suite.get("nw"), cgra, CFG, service=svc)
    assert r1.service is not None and r2.service.cache_hit
    assert r1.ii == r2.ii
    assert svc.stats.requests == 2


def test_run_suite_through_service():
    svc = MappingService()
    cgra = CGRA(3, 3)
    first = suite.run_suite(cgra, CFG, names_subset=["gsm", "srand"],
                            service=svc)
    second = suite.run_suite(cgra, CFG, names_subset=["gsm", "srand"],
                             service=svc)
    for name in ("gsm", "srand"):
        assert second[name].service.cache_hit
        assert first[name].ii == second[name].ii


def test_get_service_is_process_wide_singleton():
    reset_service()
    try:
        a, b = get_service(), get_service()
        assert a is b
    finally:
        reset_service()


def test_cached_results_are_isolated_copies():
    svc = MappingService()
    r1 = svc.map(suite.get("bitcount"), CGRA(3, 3), CFG)
    r2 = svc.map(suite.get("bitcount"), CGRA(3, 3), CFG)
    # shallow copies: mutating the returned wrapper must not corrupt the
    # cache entry's identity fields
    r2_ii = r2.ii
    r2.ii = None
    r3 = svc.map(suite.get("bitcount"), CGRA(3, 3), CFG)
    assert r3.ii == r2_ii == r1.ii


def test_service_results_deepcopyable():
    """Results carry RequestStats; they must survive copy.deepcopy (the
    serving layer snapshots reports)."""
    svc = MappingService()
    r = svc.map(suite.get("srand"), CGRA(3, 3), CFG)
    rc = copy.deepcopy(r)
    assert rc.ii == r.ii and rc.service.via == r.service.via

"""Disk store + arena serialisation: property tests.

Two invariants carry the whole persistence tier:

  1. ``ClauseArena -> bytes -> ClauseArena`` is **stream-exact** — the
     round-tripped arena holds the identical CSR ``(lits, offs)`` pair,
     including zero-length (empty) clauses and the selector-guard
     literals of incremental layers. Session signatures, the UNSAT
     registry, and WalkSAT packs all key on the exact clause stream, so
     "semantically equal" is not good enough.
  2. A damaged ``store.log`` must never crash or silently serve garbage:
     torn tails (writer died mid-append) are truncated away and the
     complete prefix survives; complete-but-corrupt bytes quarantine the
     log (renamed aside, store restarts empty).
"""
import os
import struct

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import HealthCheck, given, settings, strategies as st

from repro.core.cnf import (ArenaFormatError, ClauseArena, CNF,
                            IncrementalCNF)
from repro.core.mapper import MappingResult
from repro.core.store import (MappingStore, _HEAD, _MAGIC, canonical_bytes,
                              key_hash)


# --------------------------------------------------------------- strategies

@st.composite
def random_arena(draw):
    """Random CSR arenas: mixed-width clauses, empty clauses included,
    positive and negative literals."""
    arena = ClauseArena()
    n = draw(st.integers(0, 30))
    for _ in range(n):
        width = draw(st.integers(0, 6))   # 0 = empty clause (UNSAT core)
        lits = []
        for _ in range(width):
            v = draw(st.integers(1, 400))
            lits.append(-v if draw(st.booleans()) else v)
        arena.add(lits)
    return arena


def assert_stream_exact(a: ClauseArena, b: ClauseArena) -> None:
    assert len(a) == len(b)
    assert a.n_lits == b.n_lits
    assert np.array_equal(a.lits_view(), b.lits_view())
    assert np.array_equal(a.offs_view(), b.offs_view())
    assert a.lits_view().dtype == b.lits_view().dtype == np.int32


# ------------------------------------------------------ arena serialisation

@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_arena())
def test_arena_bytes_roundtrip_stream_exact(arena):
    assert_stream_exact(arena, ClauseArena.from_bytes(arena.to_bytes()))


def test_arena_roundtrip_empty_and_empty_clause():
    empty = ClauseArena()
    assert_stream_exact(empty, ClauseArena.from_bytes(empty.to_bytes()))
    a = ClauseArena()
    a.add([])                 # the empty clause, alone
    a.add([3, -1])
    a.add([])
    rt = ClauseArena.from_bytes(a.to_bytes())
    assert_stream_exact(a, rt)
    assert rt.clause(0) == () and rt.clause(2) == ()


def test_arena_roundtrip_guarded_layers():
    """Selector-guarded incremental layers survive byte round-trips: the
    guard literals are ordinary arena literals and must come back in the
    exact positions the encoder appended them."""
    inc = IncrementalCNF()
    a, b = inc.new_var(), inc.new_var()
    inc.add(a, b)
    for ii in (2, 3):
        inc.begin_layer(ii)
        x = inc.new_var()
        inc.add(x, -a)
        inc.add(-x, b)
        inc.end_layer()
    arena = inc.clauses._arena
    rt = ClauseArena.from_bytes(arena.to_bytes())
    assert_stream_exact(arena, rt)
    # the guard literal of each layer appears in the round-tripped stream
    for ii in (2, 3):
        sel = inc.selector(ii)
        assert any(-sel in rt.clause(i) for i in range(len(rt)))


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_arena(), st.integers(0, 3))
def test_arena_rejects_damage(arena, mode):
    """Any single corruption — magic, version, truncation, bit flip —
    raises ArenaFormatError; never a silent wrong arena."""
    blob = bytearray(arena.to_bytes())
    if mode == 0:
        blob[0] ^= 0xFF                      # magic
    elif mode == 1:
        blob[4] ^= 0x01                      # version
    elif mode == 2:
        blob = blob[:max(1, len(blob) // 2)]  # truncation
    else:
        blob[len(blob) // 2] ^= 0x40         # payload/CRC bit flip
    with pytest.raises(ArenaFormatError):
        ClauseArena.from_bytes(bytes(blob))


def test_arena_rejects_inconsistent_csr():
    arena = ClauseArena()
    arena.add([1, -2])
    blob = bytearray(arena.to_bytes())
    # offs live right after the 24-byte header; make them non-monotone
    struct.pack_into("<q", blob, 24 + 8, -1)
    import zlib
    body = bytes(blob[:-4])
    blob[-4:] = struct.pack("<I", zlib.crc32(body[24:]) & 0xFFFFFFFF)
    with pytest.raises(ArenaFormatError):
        ClauseArena.from_bytes(bytes(blob))


# ----------------------------------------------------------- canonical keys

def test_canonical_bytes_deterministic_and_injective_enough():
    k1 = ("topo", (3, 3), 1.5, True, None, b"x", frozenset({2, 1}))
    assert canonical_bytes(k1) == canonical_bytes(
        ("topo", (3, 3), 1.5, True, None, b"x", frozenset({1, 2})))
    assert key_hash(k1) != key_hash(("topo", (3, 3), 1.5, True, None,
                                     b"x", frozenset({1, 3})))
    # type confusion must not collide: 1 vs True vs "1"
    assert len({key_hash((1,)), key_hash((True,)), key_hash(("1",))}) == 3
    with pytest.raises(TypeError):
        canonical_bytes({"dict": "not canonical"})


# ------------------------------------------------------------ store basics

def _mk_result(ii: int) -> MappingResult:
    return MappingResult(success=True, ii=ii, mii=2,
                         placement={0: (0, 0, 0), 1: (1, 0, 0)})


def test_store_mapping_roundtrip_across_reopen(tmp_path):
    path = str(tmp_path / "store")
    s1 = MappingStore(path)
    key = ("topo", "shape", ("cfg", 1))
    assert s1.get_mapping(key) is None
    assert s1.put_mapping(key, _mk_result(4))
    got = s1.get_mapping(key)
    assert got.ii == 4 and got.placement == _mk_result(4).placement
    # a later write under the same key wins
    assert s1.put_mapping(key, _mk_result(5))
    s2 = MappingStore(path)                      # fresh process, cold index
    assert s2.get_mapping(key).ii == 5
    assert s2.n_mappings == 1
    assert s2.stats.quarantined == 0


def test_store_arena_roundtrip(tmp_path):
    s = MappingStore(str(tmp_path / "store"))
    arena = ClauseArena()
    arena.add([1, -2, 3])
    arena.add([])
    assert s.put_arena(("arena", 7), 9, arena)
    n_vars, rt = s.get_arena(("arena", 7))
    assert n_vars == 9
    assert_stream_exact(arena, rt)
    assert s.get_arena(("absent",)) is None


def test_store_core_registry_and_witness_verification(tmp_path):
    s = MappingStore(str(tmp_path / "store"))
    skey = ("session", "key")
    unsat = CNF()
    x = unsat.new_var()
    unsat.add(x)
    unsat.add(-x)
    sat = CNF()
    y = sat.new_var()
    sat.add(y)
    assert s.put_core(skey, 3, (7, -9), witness=unsat)
    assert s.put_core(skey, 4, (), witness=None)
    assert s.put_core(skey, 5, (2,), witness=sat)   # wrong verdict on disk
    s2 = MappingStore(str(tmp_path / "store"))
    assert s2.cores_for(skey) == {3: (7, -9), 4: (), 5: (2,)}
    assert s2.cores_for(("other",)) == {}
    # self-certification: the stored projection re-solves to the verdict
    assert s2.verify_core(skey, 3) is True
    assert s2.verify_core(skey, 4) is None          # no witness attached
    assert s2.verify_core(skey, 5) is False         # caught lying
    nv, arena = s2.core_witness(skey, 3)
    assert nv == 1 and len(arena) == 2


# --------------------------------------------------- damage: torn vs corrupt

def test_store_torn_tail_truncated_not_fatal(tmp_path):
    path = str(tmp_path / "store")
    s = MappingStore(path)
    key = ("ok",)
    s.put_mapping(key, _mk_result(3))
    good_size = os.path.getsize(s.log_path)
    # a writer died mid-append: half a record of trailing garbage
    with open(s.log_path, "ab") as f:
        f.write(_HEAD.pack(_MAGIC, 1, b"\x00" * 32, 10_000, 0))
        f.write(b"\x7f" * 12)
    s2 = MappingStore(path)
    assert s2.stats.torn_tail_truncated == 1
    assert s2.stats.quarantined == 0
    assert s2.get_mapping(key).ii == 3               # prefix survives
    # the next append truncates the torn tail before writing
    assert s2.put_mapping(("new",), _mk_result(6))
    assert os.path.getsize(s2.log_path) > good_size
    s3 = MappingStore(path)
    assert s3.get_mapping(("new",)).ii == 6
    assert s3.stats.torn_tail_truncated == 0


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 1), st.integers(1, 1_000_000))
def test_store_corruption_quarantined_not_fatal(mode, where):
    """Complete-but-invalid bytes (flipped payload bit, garbled magic)
    must quarantine the log — renamed aside, store restarts empty and
    writable — never crash, never serve the garbled record."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "store")
        s = MappingStore(path)
        s.put_mapping(("a",), _mk_result(2))
        s.put_mapping(("b",), _mk_result(3))
        size = os.path.getsize(s.log_path)
        with open(s.log_path, "r+b") as f:
            if mode == 0:
                f.seek(where % 4)                    # record 0's magic
            else:
                f.seek(_HEAD.size + (where % 8))     # record 0's payload
            byte = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([byte[0] ^ 0x20]))
        s2 = MappingStore(path)
        assert s2.stats.quarantined == 1
        assert s2.get_mapping(("a",)) is None
        assert s2.get_mapping(("b",)) is None
        quarantined = [p for p in os.listdir(path)
                       if p.startswith("store.log.corrupt-")]
        assert quarantined, "corrupt log not kept for post-mortem"
        assert os.path.getsize(os.path.join(path, quarantined[0])) == size
        # the store stays writable after quarantine
        assert s2.put_mapping(("c",), _mk_result(4))
        assert s2.get_mapping(("c",)).ii == 4


def test_store_readonly_never_appends(tmp_path):
    path = str(tmp_path / "store")
    MappingStore(path).put_mapping(("k",), _mk_result(2))
    ro = MappingStore(path, readonly=True)
    assert ro.get_mapping(("k",)).ii == 2
    assert not ro.put_mapping(("k2",), _mk_result(3))
    assert ro.get_mapping(("k2",)) is None


def test_store_sees_concurrent_writer_appends(tmp_path):
    """A reader indexes records another store instance (process) appended
    after the reader opened — the get-miss refresh path."""
    path = str(tmp_path / "store")
    reader = MappingStore(path)
    writer = MappingStore(path)
    writer.put_mapping(("late",), _mk_result(7))
    assert reader.get_mapping(("late",)).ii == 7
    d = reader.describe()
    assert d["mappings"] == 1 and d["refreshes"] >= 2


# --------------------------------------------------------------- compaction

@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.integers(0, 5), min_size=1, max_size=24),
       st.integers(2, 9))
def test_store_compaction_preserves_every_lookup(key_picks, n_cores):
    """Random overwrite-heavy write sequence -> compact -> every current
    key->value lookup (mappings, arenas, cores, witnesses) answers
    identically, and the dead versions' bytes are reclaimed."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        s = MappingStore(os.path.join(td, "store"))
        latest: dict = {}
        for i, k in enumerate(key_picks):
            key = ("map", k)
            assert s.put_mapping(key, _mk_result(i + 2))
            latest[key] = i + 2
        arena = ClauseArena()
        arena.add([1, -2])
        arena.add([])
        assert s.put_arena(("ar", 0), 5, arena)
        assert s.put_arena(("ar", 0), 7, arena)      # overwrite: 7 wins
        unsat = CNF()
        x = unsat.new_var()
        unsat.add(x)
        unsat.add(-x)
        skey = ("sess",)
        for ii in range(3, 3 + n_cores):
            assert s.put_core(skey, ii, (ii,), witness=unsat)
        assert s.put_core(skey, 3, (-1,), witness=unsat)  # latest per II wins
        before = os.path.getsize(s.log_path)

        cst = s.compact()
        assert cst["bytes_before"] == before
        assert cst["bytes_after"] == os.path.getsize(s.log_path)
        overwrites = (len(key_picks) - len(latest)) + 1 + 1
        assert cst["records_dropped"] == overwrites
        if overwrites:
            assert cst["bytes_after"] < cst["bytes_before"]

        for reader in (s, MappingStore(os.path.join(td, "store"))):
            for key, ii in latest.items():
                assert reader.get_mapping(key).ii == ii
            nv, rt = reader.get_arena(("ar", 0))
            assert nv == 7
            assert_stream_exact(arena, rt)
            cores = reader.cores_for(skey)
            assert set(cores) == set(range(3, 3 + n_cores))
            assert cores[3] == (-1,)
            # witness blobs survive at their re-derived offsets and still
            # self-certify the recorded UNSAT verdict
            for ii in range(3, 3 + n_cores):
                assert reader.verify_core(skey, ii) is True
            assert reader.stats.quarantined == 0


def test_store_compaction_idempotent_and_readonly_noop(tmp_path):
    path = str(tmp_path / "store")
    s = MappingStore(path)
    s.put_mapping(("a",), _mk_result(2))
    s.put_mapping(("a",), _mk_result(3))
    first = s.compact()
    assert first["records_dropped"] == 1
    second = s.compact()                 # nothing left to reclaim
    assert second["records_dropped"] == 0
    assert second["bytes_after"] == first["bytes_after"]
    assert s.get_mapping(("a",)).ii == 3
    assert s.stats.compactions == 2
    ro = MappingStore(path, readonly=True)
    assert ro.compact() == {"bytes_before": 0, "bytes_after": 0,
                            "records_kept": 0, "records_dropped": 0}
    assert ro.get_mapping(("a",)).ii == 3


def test_store_compaction_quarantines_corrupt_log(tmp_path):
    """Compaction of a log with complete-but-invalid bytes behaves exactly
    like refresh: quarantine (renamed aside, store restarts empty), never
    a crash, never a compacted log built from garbled records."""
    path = str(tmp_path / "store")
    s = MappingStore(path)
    s.put_mapping(("a",), _mk_result(2))
    s.put_mapping(("b",), _mk_result(3))
    with open(s.log_path, "r+b") as f:
        f.seek(_HEAD.size + 4)                        # record 0's payload
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0x20]))
    s2 = MappingStore.__new__(MappingStore)
    s2.__init__(path)                                 # scans -> quarantines
    s3 = MappingStore(path)
    out = s3.compact()
    assert out["records_kept"] == 0
    # quarantined log kept aside; compacted store stays empty but writable
    assert any(p.startswith("store.log.corrupt-") for p in os.listdir(path))
    assert s3.put_mapping(("c",), _mk_result(4))
    assert s3.get_mapping(("c",)).ii == 4


def test_store_compaction_truncates_torn_tail(tmp_path):
    path = str(tmp_path / "store")
    s = MappingStore(path)
    s.put_mapping(("keep",), _mk_result(4))
    with open(s.log_path, "ab") as f:                 # writer died mid-append
        f.write(_HEAD.pack(_MAGIC, 1, b"\x00" * 32, 10_000, 0))
        f.write(b"\x7f" * 8)
    out = s.compact()
    assert out["records_kept"] == 1
    s2 = MappingStore(path)
    assert s2.get_mapping(("keep",)).ii == 4
    assert s2.stats.torn_tail_truncated == 0          # tail gone for good

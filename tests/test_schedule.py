"""Schedules (ASAP/ALAP/MS/KMS) — validated against the paper's Fig. 4/5."""
import pytest

from repro.core.cgra import CGRA
from repro.core.dfg import running_example
from repro.core.schedule import (KMS, asap_alap, build_kms, min_ii,
                                 mobility_schedule, rec_mii, res_mii)


def names(g):
    return {n.id: n.name for n in g.nodes.values()}


def test_fig4_asap_alap():
    g = running_example()
    asap, alap, L = asap_alap(g)
    nm = names(g)
    assert L == 5
    by_asap = {}
    for nid, t in asap.items():
        by_asap.setdefault(t, set()).add(nm[nid])
    assert by_asap[0] == {"n1", "n2", "n3", "n4"}
    assert by_asap[1] == {"n5", "n7", "n10"}
    assert by_asap[2] == {"n6", "n11"}
    assert by_asap[3] == {"n8"}
    assert by_asap[4] == {"n9"}
    by_alap = {}
    for nid, t in alap.items():
        by_alap.setdefault(t, set()).add(nm[nid])
    assert by_alap[0] == {"n3"}
    assert by_alap[1] == {"n4", "n5"}
    assert by_alap[2] == {"n1", "n6", "n7"}
    assert by_alap[3] == {"n2", "n8", "n10"}
    assert by_alap[4] == {"n9", "n11"}


def test_fig4_mobility_schedule():
    g = running_example()
    nm = names(g)
    ms = mobility_schedule(g)
    rows = [sorted(nm[n] for n in row) for row in ms]
    assert rows[0] == sorted(["n1", "n2", "n3", "n4"])
    assert rows[1] == sorted(["n1", "n2", "n4", "n5", "n7", "n10"])
    assert rows[2] == sorted(["n1", "n2", "n6", "n7", "n10", "n11"])
    assert rows[3] == sorted(["n2", "n8", "n10", "n11"])
    assert rows[4] == sorted(["n9", "n11"])


def test_fig5_kms_folding():
    g = running_example()
    kms = build_kms(g, 3)
    assert kms.n_folds == 2            # ceil(5/3), as in the paper
    # every candidate (c, it) reconstructs a flat time within the window
    for nid, cands in kms.candidates.items():
        for c, it in cands:
            t = kms.flat_time(c, it)
            assert kms.asap[nid] <= t <= kms.alap[nid]
            assert 0 <= c < 3
    # rows partition all (node, window-slot) pairs
    total = sum(len(r) for r in kms.rows())
    expect = sum(kms.alap[n] - kms.asap[n] + 1 for n in g.nodes)
    assert total == expect


def test_mii_running_example():
    g = running_example()
    assert res_mii(g, CGRA(2, 2)) == 3   # 11 nodes / 4 PEs
    assert rec_mii(g) == 2               # cycle n10 -> n11 -> n10, dist 1
    assert min_ii(g, CGRA(2, 2)) == 3    # paper's II for the 2x2 example


def test_mem_constrained_res_mii():
    g = running_example()
    cgra = CGRA(2, 2, mem_pes=(0,))
    assert res_mii(g, cgra) >= 3

"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # container has no hypothesis
    from _propshim import given, settings, strategies as st

from repro.kernels.clause_eval import true_counts, true_counts_window
from repro.kernels.clause_eval.ref import (true_counts_ref,
                                           true_counts_window_ref)
from repro.kernels.flip_update import flip_update
from repro.kernels.flip_update.ref import flip_update_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


# ------------------------------------------------------------ clause_eval
@pytest.mark.parametrize("c,l,v,b", [
    (17, 3, 33, 4), (333, 7, 97, 11), (1025, 2, 250, 1), (64, 12, 64, 16),
])
def test_clause_eval_matches_ref(c, l, v, b):
    rng = np.random.RandomState(c + l)
    cvars = jnp.asarray(rng.randint(0, v + 1, (c, l)), jnp.int32)
    csign = jnp.asarray(rng.rand(c, l) > 0.5)
    assign = jnp.asarray(rng.rand(b, v + 1) > 0.5)
    got = true_counts(cvars, csign, assign)
    want = true_counts_ref(cvars, csign, assign)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clause_eval_on_real_instance():
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import encode
    from repro.core.sat.walksat_jax import pack_cnf
    enc = encode(running_example(), CGRA(2, 2), 3)
    packed = pack_cnf(enc.cnf)
    rng = np.random.RandomState(0)
    assign = jnp.asarray(rng.rand(4, enc.cnf.n_vars + 1) > 0.5)
    got = true_counts(packed.cvars, packed.csign.astype(bool), assign)
    want = true_counts_ref(packed.cvars, packed.csign.astype(bool), assign)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------- clause_eval window + flip_update
_COMPILED = jax.default_backend() in ("tpu", "gpu")


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(2, 4),
       st.integers(2, 40), st.integers(1, 9), st.integers(0, 10_000))
def test_clause_eval_window_matches_ref_property(k, c, l, v, b, seed):
    """The window kernel (interpret) is bit-identical to the jnp oracle
    across arbitrary (K, C, L, V, B) shapes — including the padding the
    ops wrapper adds to reach the block grid."""
    rng = np.random.RandomState(seed)
    cvars = jnp.asarray(rng.randint(0, v + 1, (k, c, l)), jnp.int32)
    csign = jnp.asarray(rng.rand(k, c, l) > 0.5)
    assign = jnp.asarray(rng.rand(k, b, v + 1) > 0.5)
    got = true_counts_window(cvars, csign, assign, interpret=True)
    want = true_counts_window_ref(cvars, csign, assign)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clause_eval_window_on_real_packed_window():
    """Bucketed padded shapes from the real packer, tautology-padded
    clause rows included: the kernel must count the (v1 or not v1) padding
    rows as exactly one true literal like the oracle does."""
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import EncoderSession
    from repro.core.sat.walksat_jax import pack_cnf_window
    sess = EncoderSession(running_example(), CGRA(2, 2))
    cnfs = [sess.encode(ii).cnf for ii in (2, 3, 4)]
    p = pack_cnf_window(cnfs)
    # every window has tautology padding (clause counts differ across IIs)
    assert any(c.n_clauses < p.n_clauses for c in cnfs)
    rng = np.random.RandomState(1)
    assign = jnp.asarray(rng.rand(3, 4, p.n_vars + 1) > 0.5)
    got = true_counts_window(p.cvars, p.csign.astype(bool), assign)
    want = true_counts_window_ref(p.cvars, p.csign.astype(bool), assign)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # padded rows are tautologies: exactly one true literal, never unsat
    for i, cnf in enumerate(cnfs):
        pad = np.asarray(got)[i, :, cnf.n_clauses:]
        np.testing.assert_array_equal(pad, 1)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 9), st.integers(2, 30),
       st.integers(1, 12), st.integers(1, 6), st.integers(0, 10_000))
def test_flip_update_matches_ref_property(k, b, v, c, o, seed):
    """The fused flip+tc-update kernel (interpret) is bit-identical to
    the occurrence-list oracle, including -1 occ padding and the dummy
    var-0 no-op flip of already-solved chains."""
    rng = np.random.RandomState(seed)
    assign = jnp.asarray(rng.rand(k, b, v + 1) > 0.5)
    tc = jnp.asarray(rng.randint(0, 4, (k, b, c)), jnp.int32)
    v_flip = jnp.asarray(rng.randint(0, v + 1, (k, b)), jnp.int32)
    occ_c = jnp.asarray(
        np.where(rng.rand(k, b, o) < 0.3, -1, rng.randint(0, c, (k, b, o))),
        jnp.int32)
    occ_s = jnp.asarray(rng.rand(k, b, o) > 0.5)
    new_val = jnp.asarray(rng.rand(k, b) > 0.5)
    ga, gt = flip_update(assign, tc, v_flip, occ_c, occ_s, new_val,
                         interpret=True)
    wa, wt = flip_update_ref(assign, tc, v_flip, occ_c, occ_s, new_val)
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt))


def test_flip_update_keeps_true_counts_consistent():
    """Walking a real packed window with flip_update must keep the carried
    incremental counts equal to a fresh recount — the invariant both
    walksat engines rely on for the solved flag."""
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import EncoderSession
    from repro.core.sat.walksat_jax import pack_cnf_window
    sess = EncoderSession(running_example(), CGRA(2, 2))
    p = pack_cnf_window([sess.encode(ii).cnf for ii in (3, 4)])
    rng = np.random.RandomState(7)
    K, B = 2, 4
    assign = jnp.asarray(rng.rand(K, B, p.n_vars + 1) > 0.5)
    tc = true_counts_window_ref(p.cvars, p.csign.astype(bool), assign)
    kk = jnp.arange(K)[:, None]
    for step in range(5):
        v_flip = jnp.asarray(rng.randint(0, p.n_vars + 1, (K, B)), jnp.int32)
        # a flip always *negates* the current value (the incremental
        # update's contract; probSAT never "re-sets" a var to itself)
        new_val = ~jnp.take_along_axis(assign, v_flip[..., None],
                                       axis=-1)[..., 0]
        occ_c = p.ovars[kk, v_flip]
        occ_s = p.osign[kk, v_flip]
        assign, tc = flip_update(assign, tc, v_flip, occ_c, occ_s, new_val)
        recount = true_counts_window_ref(p.cvars, p.csign.astype(bool),
                                         assign)
        np.testing.assert_array_equal(np.asarray(tc), np.asarray(recount))


@pytest.mark.skipif(not _COMPILED,
                    reason="Pallas compiled mode needs TPU/GPU; interpret "
                           "mode is covered on CPU")
def test_kernels_compiled_match_interpret():
    """On real accelerators the compiled lowering (Mosaic/Triton) must be
    bit-identical to interpret mode for both SAT kernels."""
    rng = np.random.RandomState(0)
    k, c, l, v, b, o = 2, 37, 3, 50, 8, 4
    cvars = jnp.asarray(rng.randint(0, v + 1, (k, c, l)), jnp.int32)
    csign = jnp.asarray(rng.rand(k, c, l) > 0.5)
    assign = jnp.asarray(rng.rand(k, b, v + 1) > 0.5)
    np.testing.assert_array_equal(
        np.asarray(true_counts_window(cvars, csign, assign,
                                      interpret=False)),
        np.asarray(true_counts_window(cvars, csign, assign,
                                      interpret=True)))
    tc = jnp.asarray(rng.randint(0, 4, (k, b, c)), jnp.int32)
    v_flip = jnp.asarray(rng.randint(0, v + 1, (k, b)), jnp.int32)
    occ_c = jnp.asarray(rng.randint(-1, c, (k, b, o)), jnp.int32)
    occ_s = jnp.asarray(rng.rand(k, b, o) > 0.5)
    new_val = jnp.asarray(rng.rand(k, b) > 0.5)
    got = flip_update(assign, tc, v_flip, occ_c, occ_s, new_val,
                      interpret=False)
    want = flip_update(assign, tc, v_flip, occ_c, occ_s, new_val,
                       interpret=True)
    for a, b_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,window", [
    (2, 4, 2, 256, 256, 64, 0),
    (1, 2, 1, 200, 200, 32, 0),      # unaligned seq -> padding path
    (2, 4, 4, 128, 384, 64, 0),      # decode-ish: kv longer than q
    (1, 2, 2, 256, 256, 64, 64),     # sliding window
    (1, 8, 2, 128, 128, 128, 0),     # GQA group 4
])
def test_flash_matches_ref(b, hq, hkv, sq, sk, d, window):
    rng = np.random.RandomState(hq * sq)
    q = jnp.asarray(rng.randn(b, hq, sq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, sk, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, sk, d), jnp.float32)
    off = sk - sq
    got = flash_attention(q, k, v, causal=True, window=window, q_offset=off)
    want = attention_ref(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    got = flash_attention(q, k, v)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 3, 16, 8, 64),
    (1, 128, 2, 8, 4, 128),
    (1, 200, 1, 4, 4, 64),           # unaligned seq -> padding path
    (2, 64, 4, 32, 16, 16),
])
def test_ssd_scan_matches_sequential_ref(b, s, h, p, n, chunk):
    rng = np.random.RandomState(s + h)
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5, jnp.float32)
    A_log = jnp.asarray(rng.rand(h), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    D = jnp.asarray(rng.rand(h), jnp.float32)
    got = ssd_scan(x, dt, A_log, B, C, D, chunk=chunk)
    want = ssd_ref(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=2e-3, rtol=2e-3)


def test_layers_ssd_chunked_matches_sequential_ref():
    from repro.models.layers import ssd_chunked
    rng = np.random.RandomState(3)
    b, s, h, p, n = 2, 96, 2, 8, 8
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5, jnp.float32)
    A_log = jnp.asarray(rng.rand(h), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    D = jnp.asarray(rng.rand(h), jnp.float32)
    got = ssd_chunked(x, dt, A_log, B, C, D, chunk=32)   # 96 % 32 == 0
    want = ssd_ref(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=2e-3, rtol=2e-3)

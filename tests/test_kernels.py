"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.clause_eval import true_counts
from repro.kernels.clause_eval.ref import true_counts_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


# ------------------------------------------------------------ clause_eval
@pytest.mark.parametrize("c,l,v,b", [
    (17, 3, 33, 4), (333, 7, 97, 11), (1025, 2, 250, 1), (64, 12, 64, 16),
])
def test_clause_eval_matches_ref(c, l, v, b):
    rng = np.random.RandomState(c + l)
    cvars = jnp.asarray(rng.randint(0, v + 1, (c, l)), jnp.int32)
    csign = jnp.asarray(rng.rand(c, l) > 0.5)
    assign = jnp.asarray(rng.rand(b, v + 1) > 0.5)
    got = true_counts(cvars, csign, assign)
    want = true_counts_ref(cvars, csign, assign)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clause_eval_on_real_instance():
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import encode
    from repro.core.sat.walksat_jax import pack_cnf
    enc = encode(running_example(), CGRA(2, 2), 3)
    packed = pack_cnf(enc.cnf)
    rng = np.random.RandomState(0)
    assign = jnp.asarray(rng.rand(4, enc.cnf.n_vars + 1) > 0.5)
    got = true_counts(packed.cvars, packed.csign.astype(bool), assign)
    want = true_counts_ref(packed.cvars, packed.csign.astype(bool), assign)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,window", [
    (2, 4, 2, 256, 256, 64, 0),
    (1, 2, 1, 200, 200, 32, 0),      # unaligned seq -> padding path
    (2, 4, 4, 128, 384, 64, 0),      # decode-ish: kv longer than q
    (1, 2, 2, 256, 256, 64, 64),     # sliding window
    (1, 8, 2, 128, 128, 128, 0),     # GQA group 4
])
def test_flash_matches_ref(b, hq, hkv, sq, sk, d, window):
    rng = np.random.RandomState(hq * sq)
    q = jnp.asarray(rng.randn(b, hq, sq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, sk, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, sk, d), jnp.float32)
    off = sk - sq
    got = flash_attention(q, k, v, causal=True, window=window, q_offset=off)
    want = attention_ref(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    got = flash_attention(q, k, v)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 3, 16, 8, 64),
    (1, 128, 2, 8, 4, 128),
    (1, 200, 1, 4, 4, 64),           # unaligned seq -> padding path
    (2, 64, 4, 32, 16, 16),
])
def test_ssd_scan_matches_sequential_ref(b, s, h, p, n, chunk):
    rng = np.random.RandomState(s + h)
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5, jnp.float32)
    A_log = jnp.asarray(rng.rand(h), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    D = jnp.asarray(rng.rand(h), jnp.float32)
    got = ssd_scan(x, dt, A_log, B, C, D, chunk=chunk)
    want = ssd_ref(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=2e-3, rtol=2e-3)


def test_layers_ssd_chunked_matches_sequential_ref():
    from repro.models.layers import ssd_chunked
    rng = np.random.RandomState(3)
    b, s, h, p, n = 2, 96, 2, 8, 8
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5, jnp.float32)
    A_log = jnp.asarray(rng.rand(h), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    D = jnp.asarray(rng.rand(h), jnp.float32)
    got = ssd_chunked(x, dt, A_log, B, C, D, chunk=32)   # 96 % 32 == 0
    want = ssd_ref(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=2e-3, rtol=2e-3)

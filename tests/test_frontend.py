"""jaxpr -> DFG frontend."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: fall back to the local shim
    from _propshim import given, settings, strategies as st

from repro.core.cgra import CGRA
from repro.core.frontend import trace_loop_body
from repro.core.mapper import MapperConfig, map_loop


def test_trace_simple_body_semantics():
    def body(i, acc):
        x = i * 3 + acc
        y = x ^ (x >> 2)
        return (y & 0x7FFF,)

    g, cm = trace_loop_body(body, n_carry=1)
    hist, _ = g.execute(6)
    acc = 0
    for i in range(6):
        x = i * 3 + acc
        acc = (x ^ (x >> 2)) & 0x7FFF
        assert hist[i][cm[0]] == acc


def test_trace_select_and_compare():
    def body(i, acc):
        c = i > 3
        v = jnp.where(c, acc + 1, acc - 1)
        return (v,)

    g, cm = trace_loop_body(body, n_carry=1)
    hist, _ = g.execute(8)
    acc = 0
    for i in range(8):
        acc = acc + 1 if i > 3 else acc - 1
        assert hist[i][cm[0]] == acc


def test_trace_with_loads_and_store():
    def body(i, a):   # a is a per-iteration loaded value
        return a * 2 + i,   # single non-carry output -> store

    g, _ = trace_loop_body(body, n_carry=0, loads=1)
    ops = [n.op for n in g.nodes.values()]
    assert "load" in ops and "store" in ops
    mem = {100 + i: i + 5 for i in range(4)}   # load base is 100
    hist, out_mem = g.execute(4, mem=mem)
    for i in range(4):
        assert out_mem[1000 + i] == (i + 5) * 2 + i


def test_traced_body_maps_to_cgra():
    def body(i, acc):
        return ((acc + i) & 0xFF,)

    g, _ = trace_loop_body(body, n_carry=1)
    r = map_loop(g, CGRA(2, 2), MapperConfig(solver="auto", timeout_s=30))
    assert r.success


def test_unsupported_primitive_raises():
    def body(i, acc):
        return (jnp.sin(acc.astype(jnp.float32)).astype(jnp.int32),)

    with pytest.raises(NotImplementedError):
        trace_loop_body(body, n_carry=1)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 7), st.integers(0, 63), st.integers(1, 5))
def test_property_trace_matches_python(mul, mask, sh):
    def body(i, acc):
        x = i * mul + acc
        return ((x >> sh) & mask,)

    g, cm = trace_loop_body(body, n_carry=1)
    hist, _ = g.execute(5)
    acc = 0
    for i in range(5):
        acc = ((i * mul + acc) >> sh) & mask
        assert hist[i][cm[0]] == acc

"""Tests for `repro.analysis`: the lint rule engine (fixture trees under
tests/fixtures/lint/), the CLI gate, the fork-safety contract as an
actual subprocess sys.modules check, and the CNF-auditor regression that
the whole suite encodes audit-clean in both emitter modes."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import LintConfig, load_baseline, run_lint
from repro.analysis.lint import write_baseline

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "lint"


def lint_tree(name):
    return run_lint(LintConfig(root=FIXTURES / name))


# ------------------------------------------------------------ rule engine


@pytest.mark.parametrize("tree,rule,min_findings", [
    ("fork_bad", "fork-safety", 1),
    ("opt_bad", "opt-safety", 1),
    ("hash_bad", "hash-determinism", 3),
    ("pallas_bad", "pallas-constraints", 3),
])
def test_bad_fixture_trips_rule(tree, rule, min_findings):
    findings = [f for f in lint_tree(tree) if f.rule == rule]
    assert len(findings) >= min_findings
    # fingerprints are unique even when the same token repeats
    fps = [f.fingerprint for f in findings]
    assert len(fps) == len(set(fps))


@pytest.mark.parametrize("tree", ["fork_good", "opt_good", "hash_good",
                                  "pallas_good"])
def test_good_fixture_is_clean(tree):
    assert lint_tree(tree) == []


def test_fork_bad_reports_the_chain():
    (f,) = [f for f in lint_tree("fork_bad") if f.rule == "fork-safety"]
    assert "pkg.workers" in f.message and "pkg.middle" in f.message
    assert f.path == "pkg/heavy.py"


def test_hash_good_sorted_wrappers_not_flagged():
    # sorted(set(...)) / sorted({...}) is the sanctioned pattern; the
    # rule must only flag *raw* unordered iteration
    assert all(f.rule != "hash-determinism" for f in lint_tree("hash_good"))


def test_pallas_ref_may_use_dynamic_numpy():
    # ref.py in the good tree calls np.nonzero — allowed: the
    # dynamic-shape checks bind to kernel.py/ops.py only
    assert lint_tree("pallas_good") == []


def test_baseline_suppresses_and_roundtrips(tmp_path):
    findings = lint_tree("opt_bad")
    assert findings
    path = tmp_path / "baseline.txt"
    write_baseline(path, findings)
    baseline = load_baseline(path)
    assert {f.fingerprint for f in findings} <= baseline
    # and an absent/None baseline suppresses nothing
    assert load_baseline(None) == set()
    assert load_baseline(tmp_path / "missing.txt") == set()


def test_repo_lints_clean_against_checked_in_baseline():
    findings = run_lint(LintConfig(root=REPO))
    baseline = load_baseline(REPO / "src" / "repro" / "analysis"
                             / "lint_baseline.txt")
    fresh = [f for f in findings if f.fingerprint not in baseline]
    assert fresh == [], "\n".join(f.render() for f in fresh)


# -------------------------------------------------------------------- CLI


def _cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_cli_exits_zero_on_repo():
    proc = _cli("--check")
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.parametrize("tree", ["fork_bad", "opt_bad", "hash_bad",
                                  "pallas_bad"])
def test_cli_nonzero_on_each_injected_violation(tree):
    proc = _cli("--check", "--root", str(FIXTURES / tree))
    assert proc.returncode != 0, proc.stdout + proc.stderr
    assert "FAIL" in proc.stdout


@pytest.mark.parametrize("tree", ["fork_good", "opt_good", "hash_good",
                                  "pallas_good"])
def test_cli_zero_on_good_fixture(tree):
    proc = _cli("--check", "--root", str(FIXTURES / tree))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_baseline_override_suppresses(tmp_path):
    base = tmp_path / "b.txt"
    findings = lint_tree("opt_bad")
    write_baseline(base, findings)
    proc = _cli("--check", "--root", str(FIXTURES / "opt_bad"),
                "--baseline", str(base))
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------- fork-safety, for real


def test_workers_import_closure_is_jax_free_subprocess():
    """The contract the fork-safety lint rule models, checked directly:
    importing the worker module must not pull jax into sys.modules."""
    code = ("import sys\n"
            "import repro.core.workers\n"
            "bad = [m for m in sys.modules\n"
            "       if m.split('.')[0] in ('jax', 'jaxlib', 'optax')]\n"
            "sys.exit(1 if bad else 0)\n")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


# --------------------------------------------- converted runtime guards


def test_worker_map_unstarted_raises():
    from repro.core.workers import _worker_map, _worker_stats
    with pytest.raises(RuntimeError, match="not initialised"):
        _worker_map(None, None, None, 1, True)
    with pytest.raises(RuntimeError, match="not initialised"):
        _worker_stats()


def test_front_door_unstarted_raises():
    import asyncio

    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.launch.serve import CompileFrontDoor
    door = CompileFrontDoor(pool=None)
    with pytest.raises(RuntimeError, match="not started"):
        asyncio.run(door.compile(running_example(), CGRA(2, 2)))


def test_portfolio_session_window_needs_iis():
    from repro.core.cgra import CGRA
    from repro.core.dfg import running_example
    from repro.core.encode import EncoderSession
    from repro.core.sat.portfolio import SolverSession, solve_window
    sess = SolverSession(EncoderSession(running_example(), CGRA(2, 2)),
                         method="cdcl")
    cnfs = [sess.project(3)]
    with pytest.raises(ValueError, match="candidate II"):
        solve_window(cnfs, method="cdcl", use_walksat=False,
                     session=sess, iis=None)


# ------------------------------------------------- CNF audit regression


FABRICS = None  # default: all three suite fabrics


@pytest.mark.parametrize("emitters", ["vector", "legacy"])
def test_suite_audits_clean(emitters):
    from repro.analysis import audit_suite
    names = None if emitters == "vector" else ["sha", "nw", "srand",
                                               "hotspot"]
    reports = audit_suite(names=names, emitters=emitters)
    bad = [r for r in reports if not r.ok()]
    assert bad == [], "\n".join(r.summary() for r in bad)
    # every cold report carries all four families, and the actual clause
    # counts equal the closed-form analytic expectations
    for r in reports:
        if r.mode == "cold":
            assert set(r.family_counts) == {"c1", "c2", "c2w", "c3"}
            for fam, (actual, expected) in r.family_counts.items():
                assert actual == expected, (r.cell, fam, actual, expected)
            assert r.family_counts["c1"][0] > 0
            assert r.family_counts["c3"][0] > 0


def test_audit_sequential_amo_clean():
    from repro.analysis import audit_suite
    reports = audit_suite(names=["sha", "nw"], amo="sequential")
    assert all(r.ok() for r in reports)

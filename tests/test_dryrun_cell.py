"""Integration: one real dry-run cell (512 host devices, production mesh)
in a subprocess — proves the multi-pod lowering path end to end without
polluting this process's jax device state."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
rec = run_cell("mamba2_370m", "decode_32k", True)   # multi-pod 2x16x16
print("JSON:" + json.dumps({k: rec[k] for k in
    ("status", "mesh", "kind") if k in rec}))
assert rec["status"] == "ok", rec
assert rec["collectives"]["count"] >= 0
assert rec["memory"]["total_bytes"] > 0
"""


@pytest.mark.slow
def test_multipod_dryrun_cell_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")][0]
    rec = json.loads(line[5:])
    assert rec["status"] == "ok"
    assert rec["mesh"] == "2x16x16"

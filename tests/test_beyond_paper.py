"""Beyond-paper extensions: warm-start, DSE topologies, portfolio helper."""
import pytest

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.mapper import MapperConfig, map_loop


def test_warm_start_finds_same_ii():
    g = suite.get("srand")
    cgra = CGRA(3, 3)
    cold = map_loop(g, cgra, MapperConfig(solver="cdcl", timeout_s=60))
    warm = map_loop(g, cgra, MapperConfig(solver="cdcl", timeout_s=60,
                                          warm_start=True))
    assert cold.success and warm.success
    assert warm.ii == cold.ii


@pytest.mark.parametrize("topology", ["mesh", "torus", "diag"])
def test_topologies_map(topology):
    g = suite.get("bitcount")
    cgra = CGRA(3, 3, topology=topology)
    r = map_loop(g, cgra, MapperConfig(solver="auto", timeout_s=60))
    assert r.success
    # richer connectivity can never hurt the II
    if topology != "mesh":
        mesh_r = map_loop(g, CGRA(3, 3),
                          MapperConfig(solver="auto", timeout_s=60))
        assert r.ii <= mesh_r.ii


def test_fewer_registers_never_lowers_ii():
    g = suite.get("srand")
    r2 = map_loop(g, CGRA(3, 3, n_regs=2),
                  MapperConfig(solver="auto", timeout_s=60))
    r8 = map_loop(g, CGRA(3, 3, n_regs=8),
                  MapperConfig(solver="auto", timeout_s=60))
    assert r8.success
    if r2.success:
        assert r8.ii <= r2.ii

"""`python -O` smoke of the mapper suite — catches assert-stripping bugs.

Under -O every bare ``assert`` vanishes, so any correctness guard that
matters must be a real raise. This script exercises the mapper end to end
(sequential + sweep), the walksat engines, and the structured non-model
guard, using explicit checks only (this file itself must work under -O,
so it cannot use ``assert`` either).

Run:  PYTHONPATH=src python -O tests/optimized_smoke.py
"""
import sys


def check(ok: bool, what: str) -> None:
    if not ok:
        print(f"FAIL: {what}")
        sys.exit(1)
    print(f"ok: {what}")


def main() -> None:
    check(not __debug__, "running under python -O (asserts stripped)")

    from repro.core import suite
    from repro.core.cgra import CGRA
    from repro.core.cnf import CNF
    from repro.core.dfg import running_example
    from repro.core.encode import EncoderSession
    from repro.core.mapper import MapperConfig, map_loop
    from repro.core.sat import SAT
    from repro.core.sat.walksat_jax import (NonModelError,
                                            solve_walksat_window)
    from repro.core.simulator import verify_mapping

    # mapper end to end, sequential and sweep, on the paper's example
    cfg = MapperConfig(solver="auto", timeout_s=90)
    seq = map_loop(running_example(), CGRA(2, 2), cfg)
    check(seq.success and seq.ii == 3, "sequential maps running example")
    swp = map_loop(running_example(), CGRA(2, 2), cfg, sweep_width=3)
    check(swp.success and swp.ii == seq.ii, "sweep agrees with sequential")
    chk = verify_mapping(swp.dfg, CGRA(2, 2), swp.placement, swp.ii,
                         n_iters=6)
    check(chk.ok, "sweep mapping verifies in the simulator")

    # one real suite kernel through both walksat engines
    g = suite.get("srand")
    sess = EncoderSession(g, CGRA(3, 3))
    cnfs = [sess.encode(ii).cnf for ii in (4, 5)]
    rh = solve_walksat_window(cnfs, seed=5, steps=800, batch=4,
                              engine="host")
    rd = solve_walksat_window(cnfs, seed=5, steps=800, batch=4,
                              engine="device")
    check(rh == rd, "host and device engines agree under -O")
    check(any(s == SAT for s, _ in rd), "walksat certifies a suite cell")

    # the non-model guard must SURVIVE -O: it used to be a bare assert,
    # which -O silently stripped — a miscompiled kernel could then return
    # a non-model as SAT
    class LyingCNF(CNF):
        def check(self, assignment):
            return False

    lying = LyingCNF()
    for _ in range(cnfs[0].n_vars):
        lying.new_var()
    for cl in cnfs[0].clauses:
        lying.add_clause(list(cl))
    try:
        solve_walksat_window([lying], seed=5, steps=800, batch=4)
    except NonModelError:
        check(True, "non-model guard raises under -O")
    else:
        check(False, "non-model guard raises under -O")

    # the empty-clause guard in CNF.add must also SURVIVE -O: it used to
    # be a bare assert, so `python -O` would append an empty clause
    # WITHOUT setting trivially_unsat — silently corrupting UNSAT
    # detection downstream (walksat scans for empty clauses, but cold
    # solvers trust the flag)
    from repro.core.cnf import EmptyClauseError, IncrementalCNF
    for ctor in (CNF, IncrementalCNF):
        try:
            ctor().add()
        except EmptyClauseError:
            check(True, f"{ctor.__name__}.add() raises under -O")
        else:
            check(False, f"{ctor.__name__}.add() raises under -O")

    # the guards converted from bare asserts by the analysis PR must all
    # SURVIVE -O: an uninitialised worker shard, a front door used before
    # start(), and a flip_update shape-contract violation
    from repro.core.workers import _worker_map
    try:
        _worker_map(None, None, None, 1, True)
    except RuntimeError:
        check(True, "uninitialised worker shard raises under -O")
    else:
        check(False, "uninitialised worker shard raises under -O")

    import asyncio

    from repro.launch.serve import CompileFrontDoor
    try:
        asyncio.run(CompileFrontDoor(pool=None).compile(running_example(),
                                                        CGRA(2, 2)))
    except RuntimeError:
        check(True, "unstarted front door raises under -O")
    else:
        check(False, "unstarted front door raises under -O")

    import jax.numpy as jnp

    from repro.kernels.flip_update import flip_update
    good = dict(assign=jnp.zeros((1, 2, 5), bool),
                tc=jnp.zeros((1, 2, 3), jnp.int32),
                v_flip=jnp.zeros((1, 2), jnp.int32),
                occ_c=jnp.full((1, 2, 4), -1, jnp.int32),
                occ_s=jnp.zeros((1, 2, 4), bool),
                new_val=jnp.zeros((1, 2), bool))
    bad = dict(good, tc=jnp.zeros((1, 3, 3), jnp.int32))
    flip_update(**good)
    try:
        flip_update(**bad)
    except ValueError:
        check(True, "flip_update shape contract raises under -O")
    else:
        check(False, "flip_update shape contract raises under -O")

    print("optimized smoke OK")


if __name__ == "__main__":
    main()

"""Parallel II-sweep engine: equivalence with the sequential reference,
incremental-encoding correctness, window-solver behaviour, determinism."""
import pytest

from repro.core import suite
from repro.core.cgra import CGRA
from repro.core.dfg import running_example
from repro.core.encode import EncoderSession, encode
from repro.core.mapper import MapperConfig, map_loop
from repro.core.sat import SAT, UNSAT
from repro.core.sat.portfolio import CANCELLED, solve_window
from repro.core.schedule import min_ii
from repro.core.simulator import verify_mapping

CFG = MapperConfig(solver="auto", timeout_s=90)


# ------------------------------------------------------- incremental encoding
def _clause_set(cnf):
    return sorted(tuple(sorted(c)) for c in cnf.clauses)


@pytest.mark.parametrize("amo", ["pairwise", "sequential"])
def test_session_encodings_match_fresh_encoder(amo):
    """One session's encode(ii) must equal a fresh single-II encoder for
    every II — the shared C1/layout prefix must not leak state across IIs."""
    g = running_example()
    cgra = CGRA(2, 2)
    session = EncoderSession(g, cgra, amo)
    for ii in (2, 3, 4, 5):
        a = session.encode(ii)
        b = encode(g, cgra, ii, amo)
        assert a.stats == b.stats
        assert _clause_set(a.cnf) == _clause_set(b.cnf)
    # and out-of-order re-encoding is stable (no mutation by later calls)
    again = session.encode(3)
    assert _clause_set(again.cnf) == _clause_set(encode(g, cgra, 3, amo).cnf)


def test_session_var_numbering_is_ii_independent():
    g = suite.get("sha")
    session = EncoderSession(g, CGRA(3, 3))
    e6, e8 = session.encode(6), session.encode(8)
    # same (node, pe, flat-time) -> same var id regardless of II
    inv6 = {v: (l.node, l.pe, l.iteration * 6 + l.cycle)
            for v, l in e6.info.items()}
    inv8 = {v: (l.node, l.pe, l.iteration * 8 + l.cycle)
            for v, l in e8.info.items()}
    assert inv6 == inv8


# ------------------------------------------------------------- window solver
def test_solve_window_statuses_match_sequential_solves():
    g = running_example()
    session = EncoderSession(g, CGRA(2, 2))
    encs = [session.encode(ii) for ii in (2, 3, 4)]
    res = solve_window([e.cnf for e in encs], method="cdcl", seed=0)
    assert [r.status for r in res] == [UNSAT, SAT, SAT]
    for e, r in zip(encs, res):
        if r.status == SAT:
            assert e.cnf.check(r.model)


def test_solve_window_accept_cancels_higher_candidates():
    g = running_example()
    session = EncoderSession(g, CGRA(2, 2))
    encs = [session.encode(ii) for ii in (3, 4, 5, 6)]
    res = solve_window([e.cnf for e in encs], method="cdcl", seed=0,
                       accept=lambda i, model: True)
    assert res[0].status == SAT
    # everything above the accepted lowest-II winner was cancelled or had
    # already finished; nothing below it may be cancelled
    assert all(r.status in (SAT, CANCELLED) for r in res[1:])
    assert any(r.status == CANCELLED for r in res[1:])


def test_batched_walksat_window_certifies_sat():
    """The vmapped multi-CNF walksat must certify the SAT members of a
    window (and only ever answer SAT/UNKNOWN for non-trivial CNFs)."""
    from repro.core.sat.walksat_jax import solve_walksat_window
    g = running_example()
    session = EncoderSession(g, CGRA(2, 2))
    encs = [session.encode(ii) for ii in (2, 3, 4)]
    res = solve_walksat_window([e.cnf for e in encs], seed=3, steps=1500,
                               batch=8)
    assert res[0][0] in ("UNKNOWN",)           # II=2 is UNSAT: never claimed
    for (status, model), e in zip(res[1:], encs[1:]):
        assert status == SAT                    # II=3,4 are easy SAT
        assert e.cnf.check(model)


def test_window_racer_with_zero_delay_still_correct():
    g = running_example()
    session = EncoderSession(g, CGRA(2, 2))
    encs = [session.encode(ii) for ii in (2, 3)]
    res = solve_window([e.cnf for e in encs], method="cdcl", seed=0,
                       use_walksat=True, walksat_delay=0.0)
    assert [r.status for r in res] == [UNSAT, SAT]


# ------------------------------------------------------- sweep == sequential
@pytest.mark.parametrize("name", suite.names())
def test_sweep_equals_sequential_on_suite(name):
    """Equivalence: sweep_width>1 returns the same outcome — and, when a
    mapping exists, the same II — as the k=1 reference on every suite
    kernel (some kernels genuinely don't map on a 3x3 within the II budget;
    both modes must agree on that too)."""
    g = suite.get(name)
    cgra = CGRA(3, 3)
    seq = map_loop(g, cgra, CFG)
    swp = map_loop(suite.get(name), cgra, CFG, sweep_width=3)
    assert swp.success == seq.success
    # the engine's hard guarantee is sweep II <= sequential II (a WalkSAT
    # model can only *improve* on the complete solver's regalloc verdict,
    # never worsen it); on the suite kernels the two are exactly equal
    assert swp.ii == seq.ii
    assert swp.mii == seq.mii
    if swp.success:
        chk = verify_mapping(swp.dfg, cgra, swp.placement, swp.ii, n_iters=6)
        assert chk.ok, chk.errors


def test_run_suite_exercises_both_modes():
    """suite.run_suite is the batch entry point for seq-vs-sweep runs."""
    cgra = CGRA(3, 3)
    subset = ["srand", "nw"]
    seq = suite.run_suite(cgra, CFG, sweep_width=1, names_subset=subset)
    swp = suite.run_suite(cgra, CFG, sweep_width=3, names_subset=subset)
    assert set(seq) == set(swp) == set(subset)
    for name in subset:
        assert seq[name].success and swp[name].success
        assert seq[name].ii == swp[name].ii


def test_sweep_attempt_log_covers_window_ascending():
    g = suite.get("sha")
    cgra = CGRA(3, 3)
    r = map_loop(g, cgra, CFG, sweep_width=4)
    assert r.success
    iis = [a.ii for a in r.attempts]
    assert iis == sorted(iis)
    assert iis[0] == r.mii
    assert r.attempts[-1].ii >= r.ii


def test_sweep_width_one_is_sequential_reference():
    g = running_example()
    r1 = map_loop(g, CGRA(2, 2), CFG)
    rk = map_loop(g, CGRA(2, 2), CFG, sweep_width=1)
    assert rk.ii == r1.ii == 3
    assert [a.ii for a in rk.attempts] == [a.ii for a in r1.attempts]


def test_sweep_rejects_routing():
    from repro.core.sweep import map_sweep
    with pytest.raises(ValueError):
        map_sweep(running_example(), CGRA(2, 2),
                  MapperConfig(routing=True), sweep_width=2)


def test_map_loop_routing_keeps_sequential_path():
    g = running_example()
    r = map_loop(g, CGRA(2, 2), MapperConfig(solver="auto", routing=True),
                 sweep_width=4)
    assert r.success and r.ii == 3


def test_map_loop_routing_downgrade_is_a_structured_warning():
    """routing=True cannot run the parallel sweep; the downgrade to the
    sequential path must be *reported*, not silent — and only when a
    wider sweep was actually requested."""
    g = running_example()
    r = map_loop(g, CGRA(2, 2), MapperConfig(solver="auto", routing=True),
                 sweep_width=4)
    assert len(r.warnings) == 1
    w = r.warnings[0]
    assert w["kind"] == "routing_forces_sequential"
    assert w["requested_sweep_width"] == 4
    assert w["effective_sweep_width"] == 1
    # no warning when nothing was downgraded
    for cfg, width in ((MapperConfig(solver="auto", routing=True), 1),
                       (MapperConfig(solver="auto"), 4)):
        assert map_loop(running_example(), CGRA(2, 2), cfg,
                        sweep_width=width).warnings == []


# ----------------------------------------------------------------- determinism
def test_portfolio_fixed_seed_is_deterministic():
    """The per-instance portfolio (walksat then complete fallback) must give
    identical placements across runs for a fixed seed. Uses the paper's
    running example, whose first feasible II is MII (the walksat leg
    certifies it directly, so the portfolio's fast path is what's pinned)."""
    cfg = MapperConfig(solver="portfolio", seed=7, timeout_s=90)
    r1 = map_loop(running_example(), CGRA(2, 2), cfg)
    r2 = map_loop(running_example(), CGRA(2, 2), cfg)
    assert r1.success and r2.success
    assert r1.ii == r2.ii == 3
    assert r1.placement == r2.placement


def test_sweep_ii_deterministic_across_runs():
    """The sweep's *II* is deterministic even though the walksat/CDCL race
    may produce different models run-to-run."""
    g = suite.get("bitcount")
    cgra = CGRA(4, 4)
    iis = {map_loop(suite.get("bitcount"), cgra, CFG, sweep_width=3).ii
           for _ in range(2)}
    assert len(iis) == 1


def test_min_ii_unchanged_by_sweep():
    for name in ["sha", "nw"]:
        g = suite.get(name)
        assert map_loop(g, CGRA(3, 3), CFG, sweep_width=2).mii == \
            min_ii(g, CGRA(3, 3))
